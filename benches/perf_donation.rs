//! §Perf L2 ablation: donated (input/output-aliased) vs non-donated
//! train_step executables for the same model.
//!
//! `aot.py` donates params/m/v by default and, for configs with
//! `emit_undonated`, also writes `train_step_nodonate.hlo.txt`. This bench
//! loads both lowering variants of one artifact and reports wall time per
//! optimizer step. Recorded in EXPERIMENTS.md §Perf L2.
//!
//! Run: `cargo bench --bench perf_donation -- [--model lm_hyena_s] [--iters 8]`

use std::time::Instant;

use anyhow::Result;
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::{runtime, Manifest, ModelState, Tensor};
use hyena::util::cli::Args;
use hyena::util::stats::Summary;

fn bench_variant(
    man: &Manifest,
    hlo: &str,
    params: &[Tensor],
    batches: &mut LmBatches,
    iters: usize,
) -> Result<Summary> {
    let rt = runtime();
    let exe = rt.load(&man.dir.join(hlo))?;
    // Assemble literals: params + m + v (zeros) + step + batch.
    let p_lits: Vec<xla::Literal> = params
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;
    let zeros: Vec<xla::Literal> = man
        .params
        .iter()
        .map(|s| Tensor::zeros(s.dtype, &s.shape).to_literal())
        .collect::<Result<_>>()?;
    let step = Tensor::from_f32(&[], vec![0.0])?.to_literal()?;

    let mut s = Summary::new();
    for i in 0..iters + 1 {
        let batch = batches.next_batch();
        let b_lits: Vec<xla::Literal> = batch
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(p_lits.iter());
        args.extend(zeros.iter());
        args.extend(zeros.iter());
        args.push(&step);
        args.extend(b_lits.iter());
        let t0 = Instant::now();
        let outs = exe.run_literals_ref(&args)?;
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), 3 * man.params.len() + 1);
        if i > 0 {
            s.push(dt); // first iteration is warmup
        }
    }
    Ok(s)
}

fn main() -> Result<()> {
    let args = Args::parse(&["bench"]);
    let name = args.get_or("model", "lm_hyena_s").to_string();
    let iters = args.get_usize("iters", 8);

    let dir = hyena::artifact(&name);
    let man = Manifest::load(&dir)?;
    let model = ModelState::load(&dir, 0)?;
    let params = model.params_host()?;
    let corpus = generate(&CorpusConfig::default(), 150);
    let (b, l, v) = (man.batch()?, man.seqlen()?, man.vocab()?);

    let mut table = Table::new(
        &format!("§Perf L2 — donation ablation ({name})"),
        &["variant", "p50 ms/step", "mean ms/step"],
    );
    for (label, hlo) in [
        ("donated (input_output_alias)", "train_step.hlo.txt"),
        ("non-donated", "train_step_nodonate.hlo.txt"),
    ] {
        if !dir.join(hlo).exists() {
            println!("skip {label}: {hlo} missing (build with emit_undonated)");
            continue;
        }
        let mut batches = LmBatches::new(&corpus.train, b, l, 0).with_vocab(v);
        let s = bench_variant(&man, hlo, &params, &mut batches, iters)?;
        println!("{label:>32}: p50 {:.1} ms/step", s.p50() * 1e3);
        table.row(vec![
            label.to_string(),
            format!("{:.1}", s.p50() * 1e3),
            format!("{:.1}", s.mean() * 1e3),
        ]);
    }
    table.emit("perf_donation");
    Ok(())
}
