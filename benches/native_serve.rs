//! Serving-path bench: what a single request costs under (a) the PR-2
//! serving path (`forward_cached` over the full compiled `(batch, L)` pad,
//! building a training-grade activation cache it immediately discards),
//! (b) the zero-alloc inference forward at the full length, and (c) the
//! shape-bucketed inference forward at the smallest plan covering the
//! prompt. This is the CPU serving reproduction of the paper's efficiency
//! claim: the subquadratic long conv only pays off at serve time if short
//! requests stop being padded to the compiled window.
//!
//! Correctness is asserted while timing: bucketed logits must agree with
//! the full-pad logits at every prompt position (f32 round-off — the FFT
//! sizes differ between plans, so bitwise equality is only defined at the
//! largest bucket, which *is* asserted), and the greedy next token must
//! match exactly.
//!
//! Results print as a table and persist into `BENCH_native.json` (key
//! `serve`) next to the FFTConv/train-step numbers (EXPERIMENTS.md §Perf
//! Native).
//!
//! Run: `cargo bench --bench native_serve -- [--model op_hyena_L1024]
//!        [--iters 16] [--threads N] [--out BENCH_native.json] [--smoke]`
//!
//! `--smoke` (the `scripts/check.sh serve-smoke` perf gate) uses the small
//! LM config and fails hard if a ≤ L/8 prompt served through its bucket is
//! not faster than the full-pad inference path.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use hyena::backend::native::{NativeConfig, NativeModel};
use hyena::coordinator::generation::argmax;
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;
use hyena::util::pool;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

fn time_runs<F: FnMut() -> f32>(iters: usize, mut f: F) -> Summary {
    let mut s = Summary::new();
    let mut sink = 0.0f32;
    for i in 0..=iters {
        let t0 = Instant::now();
        sink += f();
        let dt = t0.elapsed().as_secs_f64();
        if i > 0 {
            s.push(dt); // first run is warmup
        }
    }
    assert!(sink.is_finite() || sink.is_nan());
    s
}

fn main() -> Result<()> {
    let args = Args::parse(&["smoke"]);
    let smoke = args.flag("smoke");
    let default_model = if smoke { "lm_hyena_s" } else { "op_hyena_L1024" };
    let name = args.get_or("model", default_model).to_string();
    let iters = args.get_usize("iters", if smoke { 6 } else { 16 });
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    let cfg = NativeConfig::builtin(&name)
        .ok_or_else(|| anyhow!("no built-in native config named {name:?}"))?;
    let (bcomp, l, v) = (cfg.batch, cfg.seqlen, cfg.vocab);

    // Same seed → identical parameters; only the plan ladders differ.
    let mut bucketed = NativeModel::new(cfg.clone(), 0)?;
    bucketed.set_threads(threads);
    let mut fullpad = NativeModel::new(cfg, 0)?;
    fullpad.set_threads(threads);
    fullpad.set_bucket_levels(1);
    let buckets = bucketed.bucket_lens();
    println!("{name}: L={l}, compiled batch {bcomp}, buckets {buckets:?}, {threads} threads");

    let mut rng = Pcg::new(0);
    let mut table = Table::new(
        "§Perf Native — serving: full-pad vs shape-bucketed inference (1 request)",
        &[
            "prompt",
            "bucket",
            "cached fwd p50 ms",
            "full-pad p50 ms",
            "bucketed p50 ms",
            "bucketed/full-pad",
        ],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut smoke_ok = true;

    let prompt_lens = [(l / 8).max(1), (l / 4).max(1), (l / 2).max(1), l - 1];
    for &plen in &prompt_lens {
        let prompt: Vec<i32> = (0..plen).map(|_| rng.usize_below(v) as i32).collect();

        // (a) PR-2 serving path: full (batch, L) pad + activation cache.
        let mut padded = vec![0i32; bcomp * l];
        padded[..plen].copy_from_slice(&prompt);
        let cached = time_runs(iters, || {
            let (logits, _cache) = fullpad.forward_cached(&padded, bcomp).unwrap();
            logits[plen * v - 1]
        });

        // (b) zero-alloc inference forward, full-length plan.
        let mut out_full = Vec::new();
        let full = time_runs(iters, || {
            fullpad.forward_infer_into(&prompt, 1, plen, &mut out_full).unwrap();
            out_full[plen * v - 1]
        });

        // (c) shape-bucketed inference forward.
        let mut out_bkt = Vec::new();
        let mut bucket_len = 0usize;
        let bkt = time_runs(iters, || {
            bucket_len = bucketed.forward_infer_into(&prompt, 1, plen, &mut out_bkt).unwrap();
            out_bkt[plen * v - 1]
        });

        // Correctness: every prompt position agrees within f32 round-off,
        // and the greedy next token agrees exactly. At the largest bucket
        // the logits must be bitwise identical (same plan, same kernels).
        let mut max_rel = 0.0f32;
        for (a, b) in out_bkt.iter().zip(out_full.iter()) {
            max_rel = max_rel.max((a - b).abs() / (1.0 + a.abs().max(b.abs())));
        }
        assert!(max_rel < 2e-3, "bucketed logits diverged at plen={plen}: {max_rel}");
        let last = (plen - 1) * v;
        assert_eq!(
            argmax(&out_bkt[last..last + v]),
            argmax(&out_full[last..last + v]),
            "greedy next token diverged at plen={plen}"
        );
        if bucket_len == l {
            assert_eq!(out_bkt, out_full, "largest bucket is not bitwise-stable");
        }

        let ratio = bkt.p50() / full.p50().max(1e-12);
        println!(
            "prompt {plen:>6} -> bucket {bucket_len:>6}: cached {:>9.3} ms  \
             full-pad {:>9.3} ms  bucketed {:>9.3} ms  ({:.2}x of full-pad)",
            cached.p50() * 1e3,
            full.p50() * 1e3,
            bkt.p50() * 1e3,
            ratio,
        );
        table.row(vec![
            plen.to_string(),
            bucket_len.to_string(),
            format!("{:.3}", cached.p50() * 1e3),
            format!("{:.3}", full.p50() * 1e3),
            format!("{:.3}", bkt.p50() * 1e3),
            format!("{ratio:.3}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("prompt_len", Json::num(plen as f64)),
            ("bucket_len", Json::num(bucket_len as f64)),
            ("cached_fwd_ms", Json::num(cached.p50() * 1e3)),
            ("fullpad_ms", Json::num(full.p50() * 1e3)),
            ("bucketed_ms", Json::num(bkt.p50() * 1e3)),
            ("speedup_vs_fullpad", Json::num(full.p50() / bkt.p50().max(1e-12))),
            ("speedup_vs_cached", Json::num(cached.p50() / bkt.p50().max(1e-12))),
            ("max_rel_err", Json::num(max_rel as f64)),
        ]));

        // The gate: a short prompt must win through its bucket.
        if plen <= l / 8 && bucket_len < l && bkt.p50() >= full.p50() {
            smoke_ok = false;
        }
    }

    table.emit("native_serve");
    let stats = bucketed.serve_stats();
    merge_bench_json(
        Path::new(&out_path),
        "serve",
        Json::obj(vec![
            ("model", Json::str(&name)),
            ("seqlen", Json::num(l as f64)),
            ("threads", Json::num(threads as f64)),
            ("buckets", Json::Arr(buckets.iter().map(|&b| Json::num(b as f64)).collect())),
            ("rows", Json::Arr(json_rows)),
            (
                "serve_arena_hiwater_bytes",
                Json::num(stats.arena.hiwater_bytes as f64),
            ),
            ("serve_arena_allocs", Json::num(stats.arena.allocs as f64)),
            ("spec_cache_bytes", Json::num(stats.spec_bytes as f64)),
        ]),
    )?;
    println!(
        "bench ledger -> {out_path} (key: serve); serve arena hiwater {} KiB, \
         {} allocs over {} inference forwards",
        stats.arena.hiwater_bytes / 1024,
        stats.arena.allocs,
        stats.forwards
    );

    if smoke && !smoke_ok {
        bail!("serve-smoke gate: a ≤ L/8 prompt was not faster through its bucket");
    }
    Ok(())
}
