//! Streaming-decode bench: what one generated token costs under (a) the
//! PR-3 decode loop (full-prefix recompute through the bucketed
//! `Backend::infer` every round — O(L log L) per token) and (b) the PR-4
//! session path (`decode_begin` prefill once, then `decode_step` serving
//! each token as O(L) time-domain dots against per-session recurrence
//! state — DESIGN.md §Decode). This is the CPU reproduction of the
//! "fast autoregressive inference" the paper defers to future work:
//! convolutional LMs decode at constant state, not constant prefix.
//!
//! Correctness is asserted while timing: the greedy token streams of the
//! two paths must be identical at every length.
//!
//! Results print as a table and persist into `BENCH_native.json` (key
//! `decode`) next to the FFTConv/train-step/serve numbers (EXPERIMENTS.md
//! §Perf Native).
//!
//! Run: `cargo bench --bench native_decode -- [--iters 8] [--gen 32]
//!        [--threads N] [--out BENCH_native.json] [--smoke]`
//!
//! `--smoke` (the `scripts/check.sh decode-smoke` / `kernel-smoke` perf
//! gates) shrinks the run and fails hard if (a) streamed decode is not
//! ≥ 2× faster per token than full-recompute decode on the large
//! (L = 4096) case, or (b) batched stepping (`decode_step_batch` at
//! occupancy 4 — one stacked dense pass per block per round) does not beat
//! serial per-session stepping by ≥ 1.1× per token at L = 1024.
//!
//! A greedy-stream fingerprint (FNV-1a over every token of every measured
//! stream) is printed at the end; `kernel-smoke` compares it across
//! `HYENA_KERNEL=scalar|simd` runs to pin cross-kernel greedy
//! token-identity end-to-end.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use hyena::backend::native::{kernels, NativeBackend, NativeConfig};
use hyena::backend::{Backend, DecodeSession};
use hyena::coordinator::generation::{argmax, decode_batch, decode_batch_recompute, Sampling};
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;
use hyena::util::pool;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

/// The op_hyena shape (paper E2 testbed) at an arbitrary window length.
fn config_at(l: usize) -> Result<NativeConfig> {
    let base = NativeConfig::builtin("op_hyena_L1024")
        .ok_or_else(|| anyhow!("missing builtin op_hyena_L1024"))?;
    Ok(NativeConfig { name: format!("op_hyena_L{l}"), seqlen: l, ..base })
}

fn time_runs<F: FnMut() -> i32>(iters: usize, mut f: F) -> Summary {
    let mut s = Summary::new();
    let mut sink = 0i64;
    for i in 0..=iters {
        let t0 = Instant::now();
        sink += f() as i64;
        let dt = t0.elapsed().as_secs_f64();
        if i > 0 {
            s.push(dt); // first run is warmup
        }
    }
    assert!(sink > i64::MIN);
    s
}

/// FNV-1a running fold over a token stream (the cross-kernel fingerprint).
fn fnv_fold(h: &mut u64, toks: &[i32]) {
    for &t in toks {
        *h ^= t as u32 as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

/// Serial occupancy-`occ` stepping: begin one session per prompt, then
/// `gen − 1` rounds of per-session `decode_step`. Returns the greedy
/// streams and the measured ms per generated token (steps only — the
/// prefill cost is identical on both sides and excluded).
fn occupancy_serial(
    backend: &NativeBackend,
    prompts: &[Vec<i32>],
    gen: usize,
    iters: usize,
) -> (Vec<Vec<i32>>, f64) {
    let occ = prompts.len();
    let mut streams: Vec<Vec<i32>> = Vec::new();
    let mut s = Summary::new();
    let mut logits = Vec::new();
    for i in 0..=iters {
        let mut sessions: Vec<DecodeSession> = Vec::with_capacity(occ);
        let mut toks: Vec<i32> = Vec::with_capacity(occ);
        streams = vec![Vec::new(); occ];
        for (r, p) in prompts.iter().enumerate() {
            sessions.push(backend.decode_begin(p, &mut logits).unwrap());
            toks.push(argmax(&logits));
            streams[r].push(toks[r]);
        }
        let t0 = Instant::now();
        for _ in 1..gen {
            for r in 0..occ {
                backend.decode_step(&mut sessions[r], toks[r], &mut logits).unwrap();
                toks[r] = argmax(&logits);
                streams[r].push(toks[r]);
            }
        }
        let per = t0.elapsed().as_secs_f64() / ((gen - 1) * occ) as f64;
        for sess in sessions {
            backend.decode_end(sess);
        }
        if i > 0 {
            s.push(per);
        }
    }
    (streams, s.p50() * 1e3)
}

/// Batched occupancy-`occ` stepping: the same rounds through one
/// `decode_step_batch` call each.
fn occupancy_batched(
    backend: &NativeBackend,
    prompts: &[Vec<i32>],
    gen: usize,
    iters: usize,
) -> (Vec<Vec<i32>>, f64) {
    let occ = prompts.len();
    let v = backend.manifest().vocab().unwrap();
    let mut streams: Vec<Vec<i32>> = Vec::new();
    let mut s = Summary::new();
    let mut logits = Vec::new();
    let mut packed = Vec::new();
    for i in 0..=iters {
        let mut sessions: Vec<DecodeSession> = Vec::with_capacity(occ);
        let mut toks: Vec<i32> = Vec::with_capacity(occ);
        streams = vec![Vec::new(); occ];
        for (r, p) in prompts.iter().enumerate() {
            sessions.push(backend.decode_begin(p, &mut logits).unwrap());
            toks.push(argmax(&logits));
            streams[r].push(toks[r]);
        }
        let t0 = Instant::now();
        for _ in 1..gen {
            let results = {
                let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
                backend.decode_step_batch(&mut refs, &toks, &mut packed)
            };
            for (r, res) in results.into_iter().enumerate() {
                res.unwrap();
                toks[r] = argmax(&packed[r * v..(r + 1) * v]);
                streams[r].push(toks[r]);
            }
        }
        let per = t0.elapsed().as_secs_f64() / ((gen - 1) * occ) as f64;
        for sess in sessions {
            backend.decode_end(sess);
        }
        if i > 0 {
            s.push(per);
        }
    }
    (streams, s.p50() * 1e3)
}

fn main() -> Result<()> {
    let args = Args::parse(&["smoke"]);
    let smoke = args.flag("smoke");
    let iters = args.get_usize("iters", if smoke { 3 } else { 8 });
    let gen = args.get_usize("gen", if smoke { 8 } else { 32 }).max(2);
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    let mut table = Table::new(
        "§Perf Native — decode: full-recompute vs streamed sessions (1 request)",
        &[
            "L",
            "prompt",
            "new",
            "recompute ms/tok",
            "streamed ms/tok",
            "step-only p50 ms",
            "recompute/streamed",
        ],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut batch_rows: Vec<Json> = Vec::new();
    let mut smoke_ok = true;
    let mut batch_gate_ok = true;
    // FNV-1a over every measured greedy stream: kernel-smoke compares this
    // across HYENA_KERNEL=scalar|simd runs (cross-kernel token identity).
    let mut fp = 0xcbf2_9ce4_8422_2325u64;

    let active = kernels::active();
    println!("kernel dispatch: {} ({})", active.name, active.isa);

    for &l in &[1024usize, 4096] {
        let cfg = config_at(l)?;
        let v = cfg.vocab;
        let mut backend =
            NativeBackend::from_config(cfg, &PathBuf::from("artifacts").join("bench"), 0)?;
        backend.model_mut().set_threads(threads);
        let buckets = backend.model().bucket_lens();

        let plen = l / 2;
        let mut rng = Pcg::new(7);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.usize_below(v) as i32).collect();
        println!(
            "op_hyena_L{l}: prompt {plen}, {gen} new tokens, buckets {buckets:?}, \
             {threads} threads"
        );

        // (a) PR-3 path: every round re-runs the growing prefix.
        let mut out_rec: Vec<Vec<i32>> = Vec::new();
        let rec = time_runs(iters, || {
            let mut rng = Pcg::new(0);
            out_rec = decode_batch_recompute(
                &backend,
                std::slice::from_ref(&prompt),
                &[gen],
                Sampling::Greedy,
                &mut rng,
            )
            .unwrap();
            out_rec[0][gen - 1]
        });

        // (b) streamed sessions end-to-end (prefill + steps).
        let mut out_str: Vec<Vec<i32>> = Vec::new();
        let str_total = time_runs(iters, || {
            let mut rng = Pcg::new(0);
            out_str = decode_batch(
                &backend,
                std::slice::from_ref(&prompt),
                &[gen],
                Sampling::Greedy,
                &mut rng,
            )
            .unwrap();
            out_str[0][gen - 1]
        });
        assert_eq!(
            out_rec, out_str,
            "greedy decode diverged between recompute and streamed at L={l}"
        );

        // Step-only latency: the steady-state per-token cost with the
        // prefill amortized away (what a long generation converges to).
        let mut logits = Vec::new();
        let mut step_s = Summary::new();
        for i in 0..=iters {
            let mut sess = backend.decode_begin(&prompt, &mut logits).unwrap();
            let mut tok = argmax(&logits);
            let t0 = Instant::now();
            for _ in 1..gen {
                backend.decode_step(&mut sess, tok, &mut logits).unwrap();
                tok = argmax(&logits);
            }
            let per = t0.elapsed().as_secs_f64() / (gen - 1) as f64;
            backend.decode_end(sess);
            if i > 0 {
                step_s.push(per); // first run is warmup
            }
        }
        let step_ms = step_s.p50() * 1e3;

        let rec_tok_ms = rec.p50() * 1e3 / gen as f64;
        let str_tok_ms = str_total.p50() * 1e3 / gen as f64;
        let ratio = rec_tok_ms / str_tok_ms.max(1e-12);
        println!(
            "  recompute {rec_tok_ms:>9.3} ms/tok   streamed {str_tok_ms:>9.3} ms/tok   \
             step-only {step_ms:>9.3} ms   ({ratio:.1}x)"
        );
        table.row(vec![
            l.to_string(),
            plen.to_string(),
            gen.to_string(),
            format!("{rec_tok_ms:.3}"),
            format!("{str_tok_ms:.3}"),
            format!("{step_ms:.3}"),
            format!("{ratio:.2}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("seqlen", Json::num(l as f64)),
            ("prompt_len", Json::num(plen as f64)),
            ("new_tokens", Json::num(gen as f64)),
            ("recompute_ms_per_tok", Json::num(rec_tok_ms)),
            ("streamed_ms_per_tok", Json::num(str_tok_ms)),
            ("step_only_ms", Json::num(step_ms)),
            ("speedup", Json::num(ratio)),
        ]));

        // The gate: on the large case the streamed path must win ≥ 2×.
        if l == 4096 && ratio < 2.0 {
            smoke_ok = false;
        }
        for stream in &out_rec {
            fnv_fold(&mut fp, stream);
        }

        // Batched decode stepping at occupancy 4: the server's token round
        // as one decode_step_batch call vs a per-session loop.
        let occ = 4usize;
        let prompts4: Vec<Vec<i32>> = (0..occ)
            .map(|r| {
                let mut p = prompt.clone();
                p[0] = ((r * 13 + 1) % v) as i32;
                p
            })
            .collect();
        let (serial_streams, serial_ms) = occupancy_serial(&backend, &prompts4, gen, iters);
        let (batched_streams, batched_ms) = occupancy_batched(&backend, &prompts4, gen, iters);
        assert_eq!(
            serial_streams, batched_streams,
            "batched stepping diverged from serial at L={l}"
        );
        for stream in &batched_streams {
            fnv_fold(&mut fp, stream);
        }
        let bratio = serial_ms / batched_ms.max(1e-12);
        println!(
            "  occupancy {occ}: serial {serial_ms:>9.3} ms/tok   batched \
             {batched_ms:>9.3} ms/tok   ({bratio:.2}x, token-identical)"
        );
        table.row(vec![
            l.to_string(),
            plen.to_string(),
            format!("{gen} (occ {occ})"),
            format!("{serial_ms:.3} (serial steps)"),
            format!("{batched_ms:.3} (batched)"),
            "-".to_string(),
            format!("{bratio:.2}"),
        ]);
        batch_rows.push(Json::obj(vec![
            ("seqlen", Json::num(l as f64)),
            ("occupancy", Json::num(occ as f64)),
            ("prompt_len", Json::num(plen as f64)),
            ("new_tokens", Json::num(gen as f64)),
            ("serial_ms_per_tok", Json::num(serial_ms)),
            ("batched_ms_per_tok", Json::num(batched_ms)),
            ("speedup", Json::num(bratio)),
        ]));
        // The kernel-smoke gate: at the dense-dominated length the batched
        // round must beat per-session stepping at occupancy 4.
        if l == 1024 && bratio < 1.1 {
            batch_gate_ok = false;
        }

        // Session accounting must balance: every begin ended, state freed.
        let stats = backend.model().serve_stats();
        assert_eq!(
            stats.decode_sessions_live, 0,
            "decode sessions leaked at L={l}: {}",
            stats.decode_sessions_live
        );
        assert!(stats.decode_steps > 0, "no streamed steps recorded at L={l}");
        if l == 4096 {
            merge_bench_json(
                Path::new(&out_path),
                "decode",
                Json::obj(vec![
                    ("model", Json::str("op_hyena_L{1024,4096}")),
                    ("threads", Json::num(threads as f64)),
                    ("rows", Json::Arr(std::mem::take(&mut json_rows))),
                    ("decode_sessions_total", Json::num(stats.decode_sessions_total as f64)),
                    ("decode_steps", Json::num(stats.decode_steps as f64)),
                    ("decode_state_bytes", Json::num(stats.decode_state_bytes as f64)),
                    (
                        "serve_arena_hiwater_bytes",
                        Json::num(stats.arena.hiwater_bytes as f64),
                    ),
                    ("serve_arena_allocs", Json::num(stats.arena.allocs as f64)),
                ]),
            )?;
        }
    }

    merge_bench_json(
        Path::new(&out_path),
        "decode_batch",
        Json::obj(vec![
            ("kernel", Json::str(active.name)),
            ("threads", Json::num(threads as f64)),
            ("rows", Json::Arr(batch_rows)),
        ]),
    )?;
    table.emit("native_decode");
    println!("greedy fingerprint: {fp:016x}");
    println!("bench ledger -> {out_path} (keys: decode, decode_batch)");

    if smoke && !smoke_ok {
        bail!("decode-smoke gate: streamed decode was not ≥ 2× faster per token at L=4096");
    }
    if smoke && !batch_gate_ok {
        bail!(
            "kernel-smoke gate: batched decode_step_batch was not ≥ 1.1× serial \
             stepping at occupancy 4, L=1024"
        );
    }
    Ok(())
}
