//! Native FFTConv micro-bench: direct O(L²) causal convolution vs the
//! radix-2 FFT path of `hyena::backend::fft` across sequence lengths —
//! the CPU reproduction of the paper's runtime scaling story (Sec. 4.4 /
//! Fig. 4.3: subquadratic mixing is what makes 64K-token contexts viable).
//! The FFT path must win from L ≈ 8K at the latest; at 64K the gap is
//! orders of magnitude. Recorded in EXPERIMENTS.md §Perf Native.
//!
//! Run: `cargo bench --bench native_fftconv -- [--max-l 65536] [--iters N]`

use std::time::Instant;

use anyhow::Result;
use hyena::backend::fft::{causal_conv_direct, random_signal, CausalConv};
use hyena::report::Table;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

fn time_runs<F: FnMut() -> f32>(iters: usize, mut f: F) -> Summary {
    let mut s = Summary::new();
    let mut sink = 0.0f32;
    for i in 0..=iters {
        let t0 = Instant::now();
        sink += f();
        let dt = t0.elapsed().as_secs_f64();
        if i > 0 {
            s.push(dt); // first run is warmup
        }
    }
    // Keep the optimizer from eliding the work.
    assert!(sink.is_finite() || sink.is_nan());
    s
}

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let max_l = args.get_usize("max-l", 65536);
    let iters_cap = args.get_usize("iters", 32);

    let mut rng = Pcg::new(0);
    let mut table = Table::new(
        "§Perf Native — causal conv: direct O(L²) vs FFT O(L log L)",
        &["L", "direct p50 ms", "fft p50 ms", "speedup", "fft plan ms"],
    );

    for l in [1024usize, 8192, 65536] {
        if l > max_l {
            continue;
        }
        let h = random_signal(&mut rng, l);
        let v = random_signal(&mut rng, l);

        // Direct conv cost grows with L²: keep total work roughly constant.
        let direct_iters = (((1usize << 24) + l * l - 1) / (l * l)).clamp(1, iters_cap);
        let direct = time_runs(direct_iters, || causal_conv_direct(&h, &v)[l - 1]);

        let t0 = Instant::now();
        let plan = CausalConv::new(l);
        let plan_ms = t0.elapsed().as_secs_f64() * 1e3;
        let fft_iters = ((1usize << 22) / l).clamp(4, 4 * iters_cap.max(1));
        let fft = time_runs(fft_iters, || plan.conv(&h, &v)[l - 1]);

        // Cross-check while we are here: the two paths must agree.
        let a = causal_conv_direct(&h, &v);
        let b = plan.conv(&h, &v);
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs() / (1.0 + x.abs()))
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-2, "FFT and direct conv disagree at L={l}: {max_err}");

        let speedup = direct.p50() / fft.p50().max(1e-12);
        println!(
            "L={l:>6}: direct {:>10.3} ms  fft {:>8.4} ms  speedup {speedup:>8.1}x",
            direct.p50() * 1e3,
            fft.p50() * 1e3,
        );
        table.row(vec![
            l.to_string(),
            format!("{:.3}", direct.p50() * 1e3),
            format!("{:.4}", fft.p50() * 1e3),
            format!("{speedup:.1}"),
            format!("{plan_ms:.2}"),
        ]);
    }

    table.emit("native_fftconv");
    Ok(())
}
