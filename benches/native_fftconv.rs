//! Native FFTConv micro-bench: direct O(L²) causal convolution vs the PR-1
//! full-complex FFT path vs the real-FFT (rfft) workspace path of
//! `hyena::backend::fft`, plus the row-parallel engine at 1 vs N threads —
//! the CPU reproduction of the paper's runtime scaling story (Sec. 4.4 /
//! Fig. 4.3: subquadratic mixing is what makes 64K-token contexts viable).
//! The FFT paths must win from L ≈ 8K at the latest; at 64K the gap is
//! orders of magnitude, and the real-FFT path must beat the complex one.
//!
//! Results print as a table and persist machine-readably into
//! `BENCH_native.json` (key `fftconv`) so the perf trajectory is tracked
//! across PRs (EXPERIMENTS.md §Perf Native).
//!
//! Run: `cargo bench --bench native_fftconv -- [--max-l 65536] [--iters N]
//!        [--threads N] [--rows 16] [--out BENCH_native.json] [--smoke]`
//!
//! `--smoke` is the CI gate (`scripts/check.sh bench-smoke`): small sizes,
//! and a hard failure if the real-FFT path is not faster than direct at 8K.
//!
//! `--longctx` switches to the long-context axis (`scripts/check.sh
//! longctx-smoke`): stream `--max-l` samples (64K default, 1M capable)
//! through the chunked overlap-save plan at `--chunk`-sized blocks and
//! gate it ≤ 1e-4 relative against the monolithic O(L log L) plan, timing
//! both and recording the O(chunk)-vs-O(L) working-set gap under the
//! `longctx` key of the ledger.

use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};
use hyena::backend::fft::{
    causal_conv_direct, random_signal, CausalConv, ChunkedCausalConv, ComplexCausalConv,
    ConvWorkspace, Spectrum,
};
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;
use hyena::util::pool::{self, SharedMut, WorkerPool};
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

fn time_runs<F: FnMut() -> f32>(iters: usize, mut f: F) -> Summary {
    let mut s = Summary::new();
    let mut sink = 0.0f32;
    for i in 0..=iters {
        let t0 = Instant::now();
        sink += f();
        let dt = t0.elapsed().as_secs_f64();
        if i > 0 {
            s.push(dt); // first run is warmup
        }
    }
    // Keep the optimizer from eliding the work.
    assert!(sink.is_finite() || sink.is_nan());
    s
}

/// One batch of row convolutions through the workspace path — the shape of
/// the model's (batch × channel) hot loop. Writes row r of `out`.
fn conv_rows(
    pool: &WorkerPool,
    plan: &CausalConv,
    spec_h: &Spectrum,
    vs: &[Vec<f32>],
    out: &mut [f32],
    ws_pool: &Mutex<Vec<ConvWorkspace>>,
) {
    let l = plan.len();
    let ov = SharedMut::new(out);
    pool.par_for_with(
        vs.len(),
        || ws_pool.lock().unwrap().pop().unwrap_or_else(|| plan.workspace()),
        |ws, r| {
            // SAFETY: each index owns output row r exclusively.
            let orow = unsafe { ov.slice(r * l, l) };
            let mut sv = ws.take_spectrum();
            plan.spectrum_into(&vs[r], ws, &mut sv);
            plan.conv_spec_into(spec_h, &sv, ws, orow);
            ws.put_spectrum(sv);
        },
        |ws| ws_pool.lock().unwrap().push(ws),
    );
}

/// The `--longctx` axis: chunked overlap-save streaming vs the monolithic
/// plan over one long channel — the single-channel core of the engine's
/// chunked prefill (`forward_infer_chunked`). The ≤ 1e-4 relative bound is
/// the same contract the engine's unit tests and the numpy mirror
/// (`python/tests/test_overlap_save.py`) pin; here it gates a real 64K+
/// signal, and the run fails hard if the bound breaks.
fn run_longctx(args: &Args) -> Result<()> {
    let l = args.get_usize("max-l", 65536);
    let chunk = args.get_usize("chunk", 8192).clamp(2, l);
    let iters = args.get_usize("iters", 3).max(1);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    let mut rng = Pcg::new(7);
    // Engine geometry: filter support == chunk == the compiled seqlen, so
    // every block beyond the first carries filter-1 samples of history.
    let h = random_signal(&mut rng, chunk);
    let v = random_signal(&mut rng, l);
    let plan_c = ChunkedCausalConv::new(chunk, chunk);
    let nchunks = (l + chunk - 1) / chunk;

    // Timed chunked stream: plan, workspace, scratch and carry all live
    // outside the loop — zero allocation per block, like the engine.
    let mut ws = plan_c.workspace();
    let mut hs = ws.take_spectrum();
    plan_c.filter_spectrum_slices_into(&h, &mut ws, &mut hs.re, &mut hs.im);
    let mut buf = vec![0.0f32; plan_c.fft_size()];
    let mut carry: Vec<f32> = Vec::with_capacity(plan_c.carry_len());
    let mut y_chunked = vec![0.0f32; l];
    let chunked = time_runs(iters, || {
        carry.clear();
        let mut g0 = 0usize;
        while g0 < l {
            let cl = chunk.min(l - g0);
            plan_c.process_chunk_slices_into(
                &hs.re,
                &hs.im,
                &carry,
                &v[g0..g0 + cl],
                &mut ws,
                &mut buf,
                &mut y_chunked[g0..g0 + cl],
            );
            plan_c.update_carry(&mut carry, &v[g0..g0 + cl]);
            g0 += cl;
        }
        y_chunked[l - 1]
    });

    // Monolithic oracle: one transform at next_pow2(2L) with the filter
    // zero-extended to full support.
    let plan_m = CausalConv::new(l);
    let mut h_full = vec![0.0f32; l];
    h_full[..chunk].copy_from_slice(&h);
    let mut wsm = plan_m.workspace();
    let mut sh = wsm.take_spectrum();
    let mut sv = wsm.take_spectrum();
    let mut y_mono = vec![0.0f32; l];
    let mono = time_runs(iters, || {
        plan_m.spectrum_into(&h_full, &mut wsm, &mut sh);
        plan_m.spectrum_into(&v, &mut wsm, &mut sv);
        plan_m.conv_spec_into(&sh, &sv, &mut wsm, &mut y_mono);
        y_mono[l - 1]
    });

    let max_rel = y_chunked
        .iter()
        .zip(&y_mono)
        .map(|(x, y)| (x - y).abs() / (1.0 + x.abs().max(y.abs())))
        .fold(0.0f32, f32::max);

    // Working-set estimate (bytes): FFT-sized scratch + spectra + signal
    // buffers each path needs *beyond the input/output rows themselves* —
    // the O(chunk) vs O(L) gap the chunked prefill exists to open.
    let chunked_work = 4 * (4 * plan_c.fft_size() + plan_c.carry_len() + chunk);
    let mono_work = 4 * (4 * plan_m.fft_size() + l);

    println!(
        "longctx L={l}: chunked ({nchunks} x {chunk}) {:.3} ms vs monolithic {:.3} ms, \
         max rel err {max_rel:.2e}, working set {} KiB vs {} KiB",
        chunked.p50() * 1e3,
        mono.p50() * 1e3,
        chunked_work / 1024,
        mono_work / 1024,
    );
    let mut table = Table::new(
        "§Perf Longctx — chunked overlap-save vs monolithic FFT",
        &["L", "chunk", "chunks", "chunked p50 ms", "mono p50 ms", "max rel err",
          "chunked work KiB", "mono work KiB"],
    );
    table.row(vec![
        l.to_string(),
        chunk.to_string(),
        nchunks.to_string(),
        format!("{:.3}", chunked.p50() * 1e3),
        format!("{:.3}", mono.p50() * 1e3),
        format!("{max_rel:.2e}"),
        (chunked_work / 1024).to_string(),
        (mono_work / 1024).to_string(),
    ]);
    table.emit("native_fftconv_longctx");
    merge_bench_json(
        Path::new(&out_path),
        "longctx",
        Json::obj(vec![
            ("l", Json::num(l as f64)),
            ("chunk", Json::num(chunk as f64)),
            ("chunks", Json::num(nchunks as f64)),
            ("fft_size", Json::num(plan_c.fft_size() as f64)),
            ("chunked_ms", Json::num(chunked.p50() * 1e3)),
            ("monolithic_ms", Json::num(mono.p50() * 1e3)),
            ("max_rel_err", Json::num(max_rel as f64)),
            ("chunked_work_bytes", Json::num(chunked_work as f64)),
            ("monolithic_work_bytes", Json::num(mono_work as f64)),
        ]),
    )?;
    println!("bench ledger -> {out_path} (key: longctx)");

    if !(max_rel <= 1e-4) {
        bail!("longctx gate: chunked prefill diverged from monolithic ({max_rel:.2e} > 1e-4)");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(&["smoke", "longctx"]);
    if args.flag("longctx") {
        return run_longctx(&args);
    }
    let smoke = args.flag("smoke");
    let max_l = args.get_usize("max-l", if smoke { 8192 } else { 65536 });
    let iters_cap = args.get_usize("iters", if smoke { 8 } else { 32 });
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let n_rows = args.get_usize("rows", 16);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    let pool_1 = WorkerPool::new(1);
    let pool_n = WorkerPool::new(threads);

    let mut rng = Pcg::new(0);
    let col_1t = format!("rows x{n_rows} 1t ms");
    let col_nt = format!("rows x{n_rows} {threads}t ms");
    let mut table = Table::new(
        "§Perf Native — causal conv: direct O(L²) vs complex-FFT vs real-FFT",
        &[
            "L",
            "direct p50 ms",
            "cfft p50 ms",
            "rfft p50 ms",
            "rfft/direct",
            "rfft/cfft",
            &col_1t,
            &col_nt,
        ],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut smoke_ok = true;

    for l in [1024usize, 8192, 65536] {
        if l > max_l {
            continue;
        }
        let h = random_signal(&mut rng, l);
        let v = random_signal(&mut rng, l);

        // Direct conv cost grows with L²: keep total work roughly constant.
        let direct_iters = (((1usize << 24) + l * l - 1) / (l * l)).clamp(1, iters_cap);
        let direct = time_runs(direct_iters, || causal_conv_direct(&h, &v)[l - 1]);

        // PR-1 baseline: full complex FFTs.
        let cplan = ComplexCausalConv::new(l);
        let fft_iters = ((1usize << 22) / l).clamp(4, 4 * iters_cap.max(1));
        let cfft = time_runs(fft_iters, || cplan.conv(&h, &v)[l - 1]);

        // Real-FFT workspace path (the model's engine): plan + workspace
        // reused across calls, zero allocation inside the timed region.
        let plan = CausalConv::new(l);
        let mut ws = plan.workspace();
        let mut sh = ws.take_spectrum();
        let mut sv = ws.take_spectrum();
        let mut out = vec![0.0f32; l];
        let rfft = time_runs(fft_iters, || {
            plan.spectrum_into(&h, &mut ws, &mut sh);
            plan.spectrum_into(&v, &mut ws, &mut sv);
            plan.conv_spec_into(&sh, &sv, &mut ws, &mut out);
            out[l - 1]
        });

        // Cross-check while we are here: all paths must agree.
        let a = causal_conv_direct(&h, &v);
        let b = plan.conv(&h, &v);
        let c = cplan.conv(&h, &v);
        let max_err = a
            .iter()
            .zip(&b)
            .chain(a.iter().zip(&c))
            .map(|(x, y)| (x - y).abs() / (1.0 + x.abs()))
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-2, "FFT and direct conv disagree at L={l}: {max_err}");

        // Row-parallel engine: n_rows independent conv rows, 1 vs N threads.
        plan.spectrum_into(&h, &mut ws, &mut sh);
        let vrows: Vec<Vec<f32>> = (0..n_rows).map(|_| random_signal(&mut rng, l)).collect();
        let mut rows_out = vec![0.0f32; n_rows * l];
        let ws_pool: Mutex<Vec<ConvWorkspace>> = Mutex::new(Vec::new());
        let rows_iters = ((1usize << 22) / (l * n_rows).max(1)).clamp(2, iters_cap.max(2));
        let rows_1t = time_runs(rows_iters, || {
            conv_rows(&pool_1, &plan, &sh, &vrows, &mut rows_out, &ws_pool);
            rows_out[l - 1]
        });
        let serial_out = rows_out.clone();
        let rows_nt = time_runs(rows_iters, || {
            conv_rows(&pool_n, &plan, &sh, &vrows, &mut rows_out, &ws_pool);
            rows_out[l - 1]
        });
        assert_eq!(serial_out, rows_out, "thread count changed conv results at L={l}");

        let sp_direct = direct.p50() / rfft.p50().max(1e-12);
        let sp_cfft = cfft.p50() / rfft.p50().max(1e-12);
        let sp_rows = rows_1t.p50() / rows_nt.p50().max(1e-12);
        println!(
            "L={l:>6}: direct {:>10.3} ms  cfft {:>8.4} ms  rfft {:>8.4} ms  \
             (rfft {sp_direct:>8.1}x vs direct, {sp_cfft:>5.2}x vs cfft)  \
             rows x{n_rows}: {:>8.3} -> {:>8.3} ms ({sp_rows:.2}x @ {threads}t)",
            direct.p50() * 1e3,
            cfft.p50() * 1e3,
            rfft.p50() * 1e3,
            rows_1t.p50() * 1e3,
            rows_nt.p50() * 1e3,
        );
        table.row(vec![
            l.to_string(),
            format!("{:.3}", direct.p50() * 1e3),
            format!("{:.4}", cfft.p50() * 1e3),
            format!("{:.4}", rfft.p50() * 1e3),
            format!("{sp_direct:.1}"),
            format!("{sp_cfft:.2}"),
            format!("{:.3}", rows_1t.p50() * 1e3),
            format!("{:.3}", rows_nt.p50() * 1e3),
        ]);
        json_rows.push(Json::obj(vec![
            ("l", Json::num(l as f64)),
            ("direct_ms", Json::num(direct.p50() * 1e3)),
            ("complex_fft_ms", Json::num(cfft.p50() * 1e3)),
            ("real_fft_ms", Json::num(rfft.p50() * 1e3)),
            ("speedup_real_vs_direct", Json::num(sp_direct)),
            ("speedup_real_vs_complex", Json::num(sp_cfft)),
            ("rows", Json::num(n_rows as f64)),
            ("rows_1t_ms", Json::num(rows_1t.p50() * 1e3)),
            ("rows_nt_ms", Json::num(rows_nt.p50() * 1e3)),
            ("rows_thread_speedup", Json::num(sp_rows)),
        ]));

        if l >= 8192 && rfft.p50() >= direct.p50() {
            smoke_ok = false;
        }
    }

    table.emit("native_fftconv");
    merge_bench_json(
        Path::new(&out_path),
        "fftconv",
        Json::obj(vec![
            ("threads", Json::num(threads as f64)),
            ("rows", Json::Arr(json_rows)),
        ]),
    )?;
    println!("bench ledger -> {out_path} (key: fftconv)");

    if smoke && !smoke_ok {
        bail!("bench-smoke gate: real-FFT conv was not faster than direct at L ≥ 8192");
    }
    Ok(())
}
