//! Replica-parallel serving bench: N worker *processes* (the `replica`
//! subcommand, one engine each) behind the least-loaded router, driven
//! end-to-end over the HTTP/SSE wire. Measures aggregate decode
//! throughput at N=1 vs N=2 under identical offered load — the speedup a
//! second worker process buys once the engine, not the wire, is the
//! bottleneck — and proves greedy token-identity between the routed fleet
//! and a same-artifact in-process engine.
//!
//! Results print as a table and persist into `BENCH_native.json` (key
//! `router`) next to the other native ledgers (EXPERIMENTS.md §Perf
//! Native).
//!
//! Run: `cargo bench --bench native_router -- [--model golden_tiny]
//!        [--requests 4] [--max-new 12] [--worker-threads 1]
//!        [--out BENCH_native.json] [--smoke]`
//!
//! `--smoke` (part of `scripts/check.sh router-smoke`) fails hard unless
//! every stream completes, the routed streams are token-identical to the
//! in-process engine, no sessions leak, and N=2 delivers >= 1.7x the
//! aggregate tok/s of N=1.

use std::io::BufRead;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use hyena::backend::native::NativeConfig;
use hyena::backend::BackendKind;
use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{Engine, GenerateRequest, Server, StreamEvent};
use hyena::net::client::{run_loadgen, LoadGenConfig, LoadReport};
use hyena::net::router::{FleetConfig, FleetHandle};
use hyena::net::server::NetServer;
use hyena::net::{ChaosConfig, NetConfig};
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;

/// One spawned worker process. Dropping it closes stdin (the worker's
/// parent-death signal → self-drain) and reaps the child.
struct Worker {
    child: Child,
    addr: SocketAddr,
}

impl Drop for Worker {
    fn drop(&mut self) {
        drop(self.child.stdin.take());
        let mut waited = 0u64;
        loop {
            match self.child.try_wait() {
                Ok(Some(_)) => return,
                Ok(None) if waited < 5_000 => {
                    std::thread::sleep(Duration::from_millis(50));
                    waited += 50;
                }
                _ => {
                    let _ = self.child.kill();
                    let _ = self.child.wait();
                    return;
                }
            }
        }
    }
}

fn spawn_worker(name: &str, threads: usize) -> Result<Worker> {
    let mut child = Command::new(env!("CARGO_BIN_EXE_hyena"))
        .args([
            "replica",
            "--model",
            name,
            "--listen",
            "127.0.0.1:0",
            "--threads",
            &threads.to_string(),
            "--quiet",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .spawn()
        .context("spawn replica worker")?;
    let stdout = child.stdout.take().ok_or_else(|| anyhow!("worker has no stdout"))?;
    let mut rd = std::io::BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if rd.read_line(&mut line)? == 0 {
            bail!("replica worker exited before reporting its address");
        }
        if let Some(rest) = line.trim().strip_prefix("replica listening on ") {
            let tok = rest.split_whitespace().next().unwrap_or("");
            break tok.parse().map_err(|_| anyhow!("worker address {tok:?}"))?;
        }
    };
    // Keep draining worker stdout so it can never block on a full pipe.
    std::thread::spawn(move || {
        let mut line = String::new();
        while matches!(rd.read_line(&mut line), Ok(n) if n > 0) {
            line.clear();
        }
    });
    Ok(Worker { child, addr })
}

/// Routed fleet of `n` worker processes behind the HTTP front end; runs
/// the loadgen against it and returns (aggregate tok/s, loadgen report,
/// leaked sessions).
fn run_phase(
    name: &str,
    n: usize,
    threads: usize,
    lcfg: &LoadGenConfig,
) -> Result<(f64, LoadReport, u64)> {
    let workers: Vec<Worker> =
        (0..n).map(|_| spawn_worker(name, threads)).collect::<Result<_>>()?;
    let addrs: Vec<SocketAddr> = workers.iter().map(|w| w.addr).collect();
    let fleet =
        FleetHandle::connect(&addrs, FleetConfig { quiet: true, ..FleetConfig::default() })?;
    let net = NetServer::start_engine(
        Box::new(fleet.clone()),
        NetConfig {
            addr: "127.0.0.1:0".into(),
            conn_threads: lcfg.clients + 4,
            quiet: true,
            ..NetConfig::default()
        },
    )?;
    let addr = net.addr();
    let t0 = Instant::now();
    let r = run_loadgen(addr, lcfg);
    let wall = t0.elapsed().as_secs_f64();
    let report = net.finish()?;
    fleet.shutdown();
    drop(workers);
    Ok((r.tokens as f64 / wall.max(1e-9), r, report.leaked_sessions))
}

/// Greedy token-identity: routed streams across a 2-worker fleet must be
/// byte-identical to a same-artifact in-process engine. Returns the
/// number of diverging streams (0 = pass).
fn identity_check(name: &str, threads: usize) -> Result<usize> {
    let workers: Vec<Worker> =
        (0..2).map(|_| spawn_worker(name, threads)).collect::<Result<_>>()?;
    let addrs: Vec<SocketAddr> = workers.iter().map(|w| w.addr).collect();
    let fleet =
        FleetHandle::connect(&addrs, FleetConfig { quiet: true, ..FleetConfig::default() })?;
    let reference = Server::start_kind(
        BackendKind::Native,
        PathBuf::from(format!("artifacts/{name}")),
        0,
        Duration::from_millis(2),
        None,
        None,
        None,
    )?;
    let prompts: Vec<Vec<i32>> =
        (0..6).map(|i| vec![1 + i, 2 + i, 3, (i * 7) % 11 + 1]).collect();
    // Concurrent submissions so both replicas serve some of the streams.
    let subs: Vec<_> = prompts
        .iter()
        .map(|p| {
            let req = GenerateRequest {
                prompt: p.clone(),
                max_new: 8,
                sampling: Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            };
            fleet.try_submit_stream(req, 32, None)
        })
        .collect();
    let mut diverged = 0usize;
    for (p, sub) in prompts.iter().zip(subs) {
        let sub = sub.map_err(|e| anyhow!("fleet refused identity stream: {e:?}"))?;
        let mut got = Vec::new();
        let ok = loop {
            match sub.rx.recv_timeout(Duration::from_secs(30)) {
                Ok(StreamEvent::Token(t)) => got.push(t),
                Ok(StreamEvent::Done(_)) => break true,
                Ok(StreamEvent::Error { .. }) | Err(_) => break false,
            }
        };
        let want = reference.handle.generate(GenerateRequest {
            prompt: p.clone(),
            max_new: 8,
            sampling: Sampling::Greedy,
            deadline: None,
            trace_id: 0,
        })?;
        if !ok || got != want.tokens {
            eprintln!("identity: prompt {p:?} routed {got:?} != in-process {:?}", want.tokens);
            diverged += 1;
        }
    }
    fleet.shutdown();
    reference.stop();
    drop(workers);
    Ok(diverged)
}

fn main() -> Result<()> {
    let args = Args::parse(&["smoke"]);
    let smoke = args.flag("smoke");
    let name = args.get_or("model", "golden_tiny").to_string();
    let worker_threads = args.get_usize("worker-threads", 1).max(1);
    let requests = args.get_usize("requests", 4);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    let cfg = NativeConfig::builtin(&name)
        .ok_or_else(|| anyhow!("no built-in native config named {name:?}"))?;
    let (l, vocab) = (cfg.seqlen, cfg.vocab);
    let max_new = args.get_usize("max-new", (l / 4).clamp(4, 12));
    let prompt_len =
        args.get_usize("prompt-len", l / 8).clamp(1, l.saturating_sub(max_new + 1).max(1));

    // Size the offered load off the real per-worker capacity (one probe
    // worker), so the N=2 fleet is saturated too: less than 2x capacity in
    // concurrent clients and the second replica would idle, measuring the
    // loadgen rather than the fleet.
    let per_worker_capacity = {
        let probe = spawn_worker(&name, worker_threads)?;
        let fleet = FleetHandle::connect(
            &[probe.addr],
            FleetConfig { quiet: true, ..FleetConfig::default() },
        )?;
        let c = fleet.capacity();
        fleet.shutdown();
        c
    };
    let clients = args.get_usize("clients", (2 * per_worker_capacity + 2).clamp(6, 32));
    let total = clients * requests;
    println!(
        "{name}: L={l}, per-worker capacity {per_worker_capacity} \
         ({worker_threads} threads), {clients} clients x {requests} requests, \
         prompt {prompt_len} -> {max_new} tokens"
    );

    let lcfg = LoadGenConfig {
        clients,
        requests_per_client: requests,
        prompt_len,
        max_new,
        vocab,
        timeout_ms: 0, // perf run: no deadlines
        chaos: ChaosConfig::off(),
        burst: false,
        max_retries: 32,
        seed: 0,
        io_timeout_ms: 60_000,
    };

    let diverged = identity_check(&name, worker_threads)?;
    let (tok_s_1, r1, leaked_1) = run_phase(&name, 1, worker_threads, &lcfg)?;
    let (tok_s_2, r2, leaked_2) = run_phase(&name, 2, worker_threads, &lcfg)?;
    let speedup = tok_s_2 / tok_s_1.max(1e-9);

    let mut table = Table::new(
        "§Perf Native — replica-parallel serving: aggregate tok/s behind the router",
        &["replicas", "ok/total", "tok/s", "speedup", "identity"],
    );
    table.row(vec![
        "1".into(),
        format!("{}/{total}", r1.ok),
        format!("{tok_s_1:.0}"),
        "1.00".into(),
        "-".into(),
    ]);
    table.row(vec![
        "2".into(),
        format!("{}/{total}", r2.ok),
        format!("{tok_s_2:.0}"),
        format!("{speedup:.2}"),
        if diverged == 0 { "ok".into() } else { format!("{diverged} diverged") },
    ]);
    table.emit("native_router");

    merge_bench_json(
        Path::new(&out_path),
        "router",
        Json::obj(vec![
            ("model", Json::str(&name)),
            ("seqlen", Json::num(l as f64)),
            ("worker_threads", Json::num(worker_threads as f64)),
            ("per_worker_capacity", Json::num(per_worker_capacity as f64)),
            ("clients", Json::num(clients as f64)),
            ("requests", Json::num(total as f64)),
            ("prompt_len", Json::num(prompt_len as f64)),
            ("max_new", Json::num(max_new as f64)),
            ("ok_n1", Json::num(r1.ok as f64)),
            ("ok_n2", Json::num(r2.ok as f64)),
            ("tokens_per_s_n1", Json::num(tok_s_1)),
            ("tokens_per_s_n2", Json::num(tok_s_2)),
            ("speedup_n2", Json::num(speedup)),
            ("identity_diverged", Json::num(diverged as f64)),
            ("leaked_sessions", Json::num((leaked_1 + leaked_2) as f64)),
        ]),
    )?;
    println!("bench ledger -> {out_path} (key: router)");

    if smoke {
        if diverged > 0 {
            bail!("router-smoke gate: {diverged} routed streams diverged from in-process");
        }
        if r1.ok != total || r2.ok != total {
            bail!(
                "router-smoke gate: incomplete streams (N=1: {}/{total}, N=2: {}/{total})",
                r1.ok,
                r2.ok
            );
        }
        if leaked_1 + leaked_2 > 0 {
            bail!("router-smoke gate: {} decode sessions leaked", leaked_1 + leaked_2);
        }
        if speedup < 1.7 {
            bail!(
                "router-smoke gate: N=2 speedup {speedup:.2}x < 1.7x \
                 ({tok_s_1:.0} -> {tok_s_2:.0} tok/s)"
            );
        }
    }
    Ok(())
}
