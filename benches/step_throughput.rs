//! Training-step throughput bench — the compute side of Tab. 4.4 (Hyena
//! matches GPT perplexity with fewer FLOPs; here we measure wall-time per
//! optimizer step and tokens/s for the GPT vs Hyena pairs at both sizes,
//! plus the App. A.2 model-FLOP rate).
//!
//! Run: `cargo bench --bench step_throughput -- [--iters 5]`

use anyhow::Result;
use hyena::coordinator::experiment::bench_train_step;
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::ModelState;
use hyena::util::cli::Args;

const MODELS: &[&str] = &["lm_gpt_s", "lm_hyena_s", "lm_gpt_m", "lm_hyena_m"];

fn main() -> Result<()> {
    let args = Args::parse(&["bench"]);
    let iters = args.get_usize("iters", 5);
    let corpus = generate(&CorpusConfig::default(), 200);

    let mut table = Table::new(
        "train-step wall time and model-FLOP throughput",
        &["model", "params", "ms/step", "tok/s", "model GFLOP/s"],
    );
    for name in MODELS {
        let dir = hyena::artifact(name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        let mut model = ModelState::load(&dir, 0)?;
        let (b, l, v) = (
            model.manifest.batch()?,
            model.manifest.seqlen()?,
            model.manifest.vocab()?,
        );
        let flops = model.manifest.flops_per_step.unwrap_or(0.0);
        let mut batches = LmBatches::new(&corpus.train, b, l, 0).with_vocab(v);
        let mut src = move || batches.next_batch();
        let s = bench_train_step(&mut model, &mut src, 2, iters)?;
        let ms = s.p50() * 1e3;
        let tok_s = (b * l) as f64 / s.p50();
        let gflops = flops / s.p50() / 1e9;
        println!("{name:>12}: {ms:>8.1} ms/step  {tok_s:>8.0} tok/s  {gflops:>6.2} GFLOP/s");
        table.row(vec![
            name.to_string(),
            model.manifest.param_count.to_string(),
            format!("{ms:.1}"),
            format!("{tok_s:.0}"),
            format!("{gflops:.2}"),
        ]);
    }
    table.emit("step_throughput");
    Ok(())
}
