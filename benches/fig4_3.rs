//! E6 — Fig. 4.3: operator forward runtime vs sequence length —
//! Hyena (FFT path), Hyena (Pallas DFT-matmul path), exact attention,
//! flash-style chunked attention.
//!
//! Paper: batch 64 on A100 — Hyena crosses attention at L≈2048 and
//! FlashAttention between 4096–8196, reaching 100× at 64k. Testbed: batch 4
//! on one CPU core over compiled single-block artifacts; absolute ms are
//! not comparable but the *crossover structure* (attention's quadratic
//! growth overtaking Hyena's L log L) is the reproduced shape.
//!
//! Run: `cargo bench --bench fig4_3 -- [--iters 5] [--lens 256,...,8192]`

use anyhow::Result;
use hyena::coordinator::experiment::bench_forward;
use hyena::report::Table;
use hyena::runtime::{ModelState, Tensor};
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

const KINDS: &[&str] = &["hyena", "hyenapallas", "flash", "attn"];

fn main() -> Result<()> {
    let args = Args::parse(&["bench"]); // libtest passes --bench; swallow it
    let iters = args.get_usize("iters", 3);
    let lens: Vec<usize> = args
        .get_or("lens", "256,512,1024,2048,4096,8192")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    let mut table = Table::new(
        "Fig 4.3 — forward wall time (ms) vs sequence length (batch 4)",
        &["seqlen", "hyena", "hyena-pallas", "flash", "attn", "attn/hyena"],
    );
    let mut rng = Pcg::new(0);
    for &l in &lens {
        let mut cells = vec![l.to_string()];
        let mut hyena_ms = f64::NAN;
        let mut attn_ms = f64::NAN;
        for kind in KINDS {
            let name = format!("rt_{kind}_L{l}");
            let dir = hyena::artifact(&name);
            if !dir.join("manifest.json").exists() {
                cells.push("—".into());
                continue;
            }
            let model = ModelState::load(&dir, 0)?;
            let b = model.manifest.batch()?;
            let v = model.manifest.vocab()?;
            let toks: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
            let inputs = [Tensor::from_i32(&[b, l], toks)?];
            let s = bench_forward(&model, &inputs, 1, iters)?;
            let ms = s.p50() * 1e3;
            if *kind == "hyena" {
                hyena_ms = ms;
            }
            if *kind == "attn" {
                attn_ms = ms;
            }
            println!("{kind:>12} L={l:<5}: {ms:>9.2} ms (p50 of {iters})");
            cells.push(format!("{ms:.2}"));
        }
        cells.push(if hyena_ms.is_finite() && attn_ms.is_finite() {
            format!("{:.2}x", attn_ms / hyena_ms)
        } else {
            "—".into()
        });
        table.row(cells);
    }
    table.emit("fig4_3");
    Ok(())
}
