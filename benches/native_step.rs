//! Threaded training-step bench for the native backend: one full optimizer
//! step (forward + backward + AdamW) at 1 thread vs N threads on the same
//! fixed batch and seed. The row-parallel engine is write-disjoint with
//! serial per-row arithmetic, so the losses must agree bit-for-bit — the
//! bench asserts that while measuring the speedup.
//!
//! Results print as a table and persist into `BENCH_native.json` (key
//! `train_step`) next to the FFTConv numbers (EXPERIMENTS.md §Perf Native).
//!
//! Run: `cargo bench --bench native_step -- [--model lm_hyena_s]
//!        [--iters 5] [--threads N] [--out BENCH_native.json]`

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};
use hyena::backend::native::{NativeConfig, NativeModel};
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;
use hyena::util::pool;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

/// Train `iters + 1` steps (first is warmup) on a fixed batch; returns the
/// per-step wall-time summary and the last loss.
fn bench_steps(
    model: &mut NativeModel,
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    iters: usize,
) -> Result<(Summary, f32)> {
    let mut s = Summary::new();
    let mut last = 0.0f32;
    for i in 0..=iters {
        let t0 = Instant::now();
        last = model.train_step(tokens, targets, mask, b)?;
        if i > 0 {
            s.push(t0.elapsed().as_secs_f64());
        }
    }
    Ok((s, last))
}

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let name = args.get_or("model", "lm_hyena_s").to_string();
    let iters = args.get_usize("iters", 5);
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    let cfg = NativeConfig::builtin(&name)
        .ok_or_else(|| anyhow!("no built-in native config named {name:?}"))?;
    let (b, l, v) = (cfg.batch, cfg.seqlen, cfg.vocab);
    let mut rng = Pcg::new(0);
    let tokens: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
    let targets: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
    let mask = vec![1.0f32; b * l];

    let mut m1 = NativeModel::new(cfg.clone(), 0)?;
    m1.set_threads(1);
    let (s1, loss1) = bench_steps(&mut m1, &tokens, &targets, &mask, b, iters)?;

    let mut mn = NativeModel::new(cfg, 0)?;
    mn.set_threads(threads);
    let (sn, loss_n) = bench_steps(&mut mn, &tokens, &targets, &mask, b, iters)?;

    assert_eq!(loss1, loss_n, "thread count changed the training loss");
    assert_eq!(m1.params, mn.params, "thread count changed the parameters");

    let speedup = s1.p50() / sn.p50().max(1e-12);
    let tokens_per_step = (b * l) as f64;
    println!(
        "{name}: {b}x{l} step  1t {:>8.1} ms  {threads}t {:>8.1} ms  \
         speedup {speedup:.2}x  ({:.0} tok/s threaded)",
        s1.p50() * 1e3,
        sn.p50() * 1e3,
        tokens_per_step / sn.p50().max(1e-12),
    );

    let mut table = Table::new(
        "§Perf Native — threaded training step (1 vs N threads)",
        &["model", "batch x seqlen", "1t ms/step", "Nt ms/step", "threads", "speedup"],
    );
    table.row(vec![
        name.clone(),
        format!("{b} x {l}"),
        format!("{:.1}", s1.p50() * 1e3),
        format!("{:.1}", sn.p50() * 1e3),
        threads.to_string(),
        format!("{speedup:.2}"),
    ]);
    table.emit("native_step");

    merge_bench_json(
        Path::new(&out_path),
        "train_step",
        Json::obj(vec![
            ("model", Json::str(&name)),
            ("batch", Json::num(b as f64)),
            ("seqlen", Json::num(l as f64)),
            ("iters", Json::num(iters as f64)),
            ("threads", Json::num(threads as f64)),
            ("ms_per_step_1t", Json::num(s1.p50() * 1e3)),
            ("ms_per_step_nt", Json::num(sn.p50() * 1e3)),
            ("thread_speedup", Json::num(speedup)),
            ("tokens_per_s_nt", Json::num(tokens_per_step / sn.p50().max(1e-12))),
            ("final_loss", Json::num(loss_n as f64)),
        ]),
    )?;
    println!("bench ledger -> {out_path} (key: train_step)");
    Ok(())
}
