//! Threaded training-step bench + kernel micro-axes for the native backend.
//!
//! Two sections (DESIGN.md §Kernels, §Perf):
//!
//! 1. **Kernel micro-axes** — the dispatched microkernels (dense axpy, the
//!    decode dot, GELU, FFT butterfly sweep, spectrum pointwise product)
//!    timed directly against both dispatch tables (scalar vs SIMD) on
//!    identically seeded buffers (numeric agreement is pinned by the
//!    kernel property tests, not re-checked here). Persisted
//!    under `BENCH_native.json` key `kernels`. Under `--smoke` (the
//!    `scripts/check.sh kernel-smoke` gate) the SIMD table must beat scalar
//!    by ≥ 1.5× on the dense-axpy and decode-dot axes when the CPU has a
//!    SIMD table at all.
//! 2. **Full optimizer step** at 1 thread vs N threads on the same fixed
//!    batch and seed (bitwise-equal losses asserted; key `train_step`).
//!
//! The active dispatch table (what `HYENA_KERNEL` resolved to on this CPU)
//! is printed and — when forced via the environment — verified, so the gate
//! checks what actually ran rather than trusting the env var.
//!
//! Run: `cargo bench --bench native_step -- [--model lm_hyena_s]
//!        [--iters 5] [--threads N] [--out BENCH_native.json] [--smoke]`

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use hyena::backend::native::kernels::{self, Kernels};
use hyena::backend::native::{NativeConfig, NativeModel};
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;
use hyena::util::pool;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

/// Train `iters + 1` steps (first is warmup) on a fixed batch; returns the
/// per-step wall-time summary and the last loss.
fn bench_steps(
    model: &mut NativeModel,
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    iters: usize,
) -> Result<(Summary, f32)> {
    let mut s = Summary::new();
    let mut last = 0.0f32;
    for i in 0..=iters {
        let t0 = Instant::now();
        last = model.train_step(tokens, targets, mask, b)?;
        if i > 0 {
            s.push(t0.elapsed().as_secs_f64());
        }
    }
    Ok((s, last))
}

/// Median ns/op of `f` over `iters` timed passes of `reps` calls each
/// (first pass is warmup).
fn time_axis<F: FnMut()>(iters: usize, reps: usize, mut f: F) -> f64 {
    let mut s = Summary::new();
    for i in 0..=iters {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        if i > 0 {
            s.push(t0.elapsed().as_secs_f64() / reps as f64);
        }
    }
    s.p50() * 1e9
}

struct Axis {
    name: &'static str,
    len: usize,
    scalar_ns: f64,
    simd_ns: Option<f64>,
}

/// Time every microkernel under one table; returns (axis ns ops, sink).
fn run_table(k: &'static Kernels, iters: usize) -> Vec<(&'static str, usize, f64, f32)> {
    let mut rng = Pcg::new(42);
    let mut out = Vec::new();

    // dense-axpy: the dense microkernel's inner row update (dout = 1024).
    {
        let n = 1024usize;
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut y: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let ns = time_axis(iters, 2048, || (k.axpy)(&mut y, &w, 1.0000001));
        out.push(("dense-axpy", n, ns, y[0]));
    }
    // decode-dot: the streaming-decode reduction (history length 4096).
    {
        let n = 4096usize;
        let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut sink = 0.0f32;
        let ns = time_axis(iters, 1024, || sink += (k.dot)(&a, &b));
        out.push(("decode-dot", n, ns, sink));
    }
    // gelu: one ELEM_BLOCK-sized chunk.
    {
        let n = 4096usize;
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let (mut y, mut th) = (vec![0.0f32; n], vec![0.0f32; n]);
        let ns = time_axis(iters, 64, || (k.gelu_fwd)(&x, &mut y, &mut th));
        out.push(("gelu", n, ns, y[0]));
    }
    // butterfly: a full stage sweep at FFT size 4096 (one forward's worth
    // of butterfly passes, bit-reversal excluded).
    {
        let n = 4096usize;
        let mut tw_re = Vec::with_capacity(n / 2);
        let mut tw_im = Vec::with_capacity(n / 2);
        for j in 0..n / 2 {
            let ang = -2.0 * std::f64::consts::PI * j as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        let mut re: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut im: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let ns = time_axis(iters, 16, || {
            let mut len = 2usize;
            while len <= n {
                (k.butterfly_pass)(&mut re, &mut im, &tw_re, &tw_im, len, false);
                len <<= 1;
            }
        });
        out.push(("butterfly-4k", n, ns, re[0]));
    }
    // spec-mul: half-spectrum pointwise product at 2049 bins (L = 2048).
    {
        let n = 2049usize;
        let ar: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let ai: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let br: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let bi: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let (mut pr, mut pi) = (vec![0.0f32; n], vec![0.0f32; n]);
        let ns = time_axis(iters, 512, || (k.spec_mul)(&ar, &ai, &br, &bi, &mut pr, &mut pi));
        out.push(("spec-mul", n, ns, pr[0]));
    }
    out
}

fn bench_kernels(iters: usize) -> Vec<Axis> {
    let scalar = run_table(&kernels::SCALAR, iters);
    let simd = kernels::simd_table().map(|t| run_table(t, iters));
    scalar
        .into_iter()
        .enumerate()
        .map(|(i, (name, len, scalar_ns, _))| Axis {
            name,
            len,
            scalar_ns,
            simd_ns: simd.as_ref().map(|s| s[i].2),
        })
        .collect()
}

fn main() -> Result<()> {
    let args = Args::parse(&["smoke"]);
    let smoke = args.flag("smoke");
    let name = args.get_or("model", "lm_hyena_s").to_string();
    let iters = args.get_usize("iters", if smoke { 2 } else { 5 });
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    // Which dispatch table actually runs — and, when the environment forces
    // one, verify the dispatcher honoured it (the kernel-smoke contract).
    let active = kernels::active();
    println!("kernel dispatch: {} ({})", active.name, active.isa);
    match std::env::var("HYENA_KERNEL").ok().as_deref() {
        Some("scalar") if active.name != "scalar" => {
            bail!("HYENA_KERNEL=scalar but the {} table is active", active.name)
        }
        Some("simd") if kernels::simd_table().is_some() && active.name != "simd" => {
            bail!("HYENA_KERNEL=simd on a SIMD-capable CPU but the scalar table is active")
        }
        _ => {}
    }

    // -- kernel micro-axes ---------------------------------------------------
    let axes = bench_kernels(iters.max(3));
    let mut ktable = Table::new(
        "§Perf Native — kernel micro-axes (scalar vs SIMD dispatch)",
        &["axis", "len", "scalar ns/op", "simd ns/op", "speedup"],
    );
    let mut krows: Vec<Json> = Vec::new();
    let mut gate_ok = true;
    for ax in &axes {
        let (simd_s, speedup) = match ax.simd_ns {
            Some(ns) => (format!("{ns:.0}"), ax.scalar_ns / ns.max(1e-9)),
            None => ("-".to_string(), 1.0),
        };
        println!(
            "kernel {:>12}  len {:>5}  scalar {:>9.0} ns  simd {:>9} ns  ({speedup:.2}x)",
            ax.name, ax.len, ax.scalar_ns, simd_s
        );
        ktable.row(vec![
            ax.name.to_string(),
            ax.len.to_string(),
            format!("{:.0}", ax.scalar_ns),
            simd_s,
            format!("{speedup:.2}"),
        ]);
        krows.push(Json::obj(vec![
            ("axis", Json::str(ax.name)),
            ("len", Json::num(ax.len as f64)),
            ("scalar_ns", Json::num(ax.scalar_ns)),
            ("simd_ns", ax.simd_ns.map(Json::num).unwrap_or(Json::Null)),
            ("speedup", Json::num(speedup)),
        ]));
        // The kernel-smoke gate: the SIMD table must carry the dense and
        // decode-dot axes by ≥ 1.5× wherever a SIMD table exists.
        if ax.simd_ns.is_some()
            && (ax.name == "dense-axpy" || ax.name == "decode-dot")
            && speedup < 1.5
        {
            eprintln!("kernel-smoke: axis {} speedup {speedup:.2} < 1.5", ax.name);
            gate_ok = false;
        }
    }
    ktable.emit("native_kernels");
    merge_bench_json(
        Path::new(&out_path),
        "kernels",
        Json::obj(vec![
            ("active", Json::str(active.name)),
            ("isa", Json::str(active.isa)),
            ("simd_available", Json::Bool(kernels::simd_table().is_some())),
            ("axes", Json::Arr(krows)),
        ]),
    )?;

    // -- full optimizer step, 1 vs N threads ---------------------------------
    let cfg = NativeConfig::builtin(&name)
        .ok_or_else(|| anyhow!("no built-in native config named {name:?}"))?;
    let (b, l, v) = (cfg.batch, cfg.seqlen, cfg.vocab);
    let mut rng = Pcg::new(0);
    let tokens: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
    let targets: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
    let mask = vec![1.0f32; b * l];

    let mut m1 = NativeModel::new(cfg.clone(), 0)?;
    m1.set_threads(1);
    let (s1, loss1) = bench_steps(&mut m1, &tokens, &targets, &mask, b, iters)?;

    let mut mn = NativeModel::new(cfg, 0)?;
    mn.set_threads(threads);
    let (sn, loss_n) = bench_steps(&mut mn, &tokens, &targets, &mask, b, iters)?;

    assert_eq!(loss1, loss_n, "thread count changed the training loss");
    assert_eq!(m1.params, mn.params, "thread count changed the parameters");

    let speedup = s1.p50() / sn.p50().max(1e-12);
    let tokens_per_step = (b * l) as f64;
    println!(
        "{name}: {b}x{l} step  1t {:>8.1} ms  {threads}t {:>8.1} ms  \
         speedup {speedup:.2}x  ({:.0} tok/s threaded)",
        s1.p50() * 1e3,
        sn.p50() * 1e3,
        tokens_per_step / sn.p50().max(1e-12),
    );

    let mut table = Table::new(
        "§Perf Native — threaded training step (1 vs N threads)",
        &["model", "batch x seqlen", "1t ms/step", "Nt ms/step", "threads", "speedup"],
    );
    table.row(vec![
        name.clone(),
        format!("{b} x {l}"),
        format!("{:.1}", s1.p50() * 1e3),
        format!("{:.1}", sn.p50() * 1e3),
        threads.to_string(),
        format!("{speedup:.2}"),
    ]);
    table.emit("native_step");

    merge_bench_json(
        Path::new(&out_path),
        "train_step",
        Json::obj(vec![
            ("model", Json::str(&name)),
            ("kernel", Json::str(active.name)),
            ("batch", Json::num(b as f64)),
            ("seqlen", Json::num(l as f64)),
            ("iters", Json::num(iters as f64)),
            ("threads", Json::num(threads as f64)),
            ("ms_per_step_1t", Json::num(s1.p50() * 1e3)),
            ("ms_per_step_nt", Json::num(sn.p50() * 1e3)),
            ("thread_speedup", Json::num(speedup)),
            ("tokens_per_s_nt", Json::num(tokens_per_step / sn.p50().max(1e-12))),
            ("final_loss", Json::num(loss_n as f64)),
        ]),
    )?;
    println!("bench ledger -> {out_path} (keys: kernels, train_step)");

    if smoke && !gate_ok {
        bail!("kernel-smoke gate: SIMD did not win ≥ 1.5x on the dense/dot micro-axes");
    }
    Ok(())
}
