//! Observability-overhead bench: what the `HYENA_PROF=1` profiling hooks
//! cost on the serving hot path (DESIGN.md §Observability).
//!
//! The kernel dispatch table is chosen once at first dispatch (profiled
//! wrappers or the bare table), so a single process cannot honestly
//! measure both modes. This bench re-execs itself twice per round —
//! `HYENA_PROF=0` and `HYENA_PROF=1` — and each child measures the
//! steady-state batched-decode cost (occupancy 4, step-only, prefill
//! excluded) exactly like `benches/native_decode.rs`. Children also
//! assert the instrumentation contract: with profiling on, the kernel /
//! FFT / decode-round slots must all have ticked; off, they must all be
//! exactly zero (the ≈ 0-overhead path records nothing).
//!
//! Results print as a table and persist into `BENCH_native.json` (key
//! `obs`, EXPERIMENTS.md §Perf Native).
//!
//! Run: `cargo bench --bench native_obs -- [--iters 8] [--gen 32]
//!        [--rounds 3] [--threads N] [--out BENCH_native.json] [--smoke]`
//!
//! `--smoke` (the `scripts/check.sh obs-smoke` perf gate) shrinks the run
//! and fails hard if profiling-on decode is more than 3% slower than
//! profiling-off (min over rounds, so a scheduler hiccup in one round
//! cannot fail the gate by itself).

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use hyena::backend::native::{NativeBackend, NativeConfig};
use hyena::backend::{Backend, DecodeSession};
use hyena::coordinator::generation::argmax;
use hyena::obs::prof;
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;
use hyena::util::pool;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

const SEQLEN: usize = 1024;
const OCCUPANCY: usize = 4;

fn config() -> Result<NativeConfig> {
    let base = NativeConfig::builtin("op_hyena_L1024")
        .ok_or_else(|| anyhow!("missing builtin op_hyena_L1024"))?;
    Ok(NativeConfig { name: format!("op_hyena_L{SEQLEN}"), seqlen: SEQLEN, ..base })
}

/// Child mode: measure step-only batched decode ms/token in *this*
/// process (whose HYENA_PROF the parent fixed before exec), check the
/// slot contract, and print one machine-readable line for the parent.
fn run_measure(args: &Args) -> Result<()> {
    let iters = args.get_usize("iters", 8);
    let gen = args.get_usize("gen", 32).max(2);
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let prof_on = prof::enabled(); // resolves HYENA_PROF before first dispatch
    let cfg = config()?;
    let v = cfg.vocab;
    let mut backend =
        NativeBackend::from_config(cfg, &PathBuf::from("artifacts").join("bench"), 0)?;
    backend.model_mut().set_threads(threads);
    let mut rng = Pcg::new(7);
    let prompts: Vec<Vec<i32>> = (0..OCCUPANCY)
        .map(|r| {
            let mut p: Vec<i32> =
                (0..SEQLEN / 2).map(|_| rng.usize_below(v) as i32).collect();
            p[0] = ((r * 13 + 1) % v) as i32;
            p
        })
        .collect();

    let mut s = Summary::new();
    let mut logits = Vec::new();
    let mut packed = Vec::new();
    let mut fp = 0i64;
    for i in 0..=iters {
        let mut sessions: Vec<DecodeSession> = Vec::with_capacity(OCCUPANCY);
        let mut toks: Vec<i32> = Vec::with_capacity(OCCUPANCY);
        for p in &prompts {
            sessions.push(backend.decode_begin(p, &mut logits)?);
            toks.push(argmax(&logits));
        }
        let t0 = Instant::now();
        for _ in 1..gen {
            let results = {
                let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
                backend.decode_step_batch(&mut refs, &toks, &mut packed)
            };
            for (r, res) in results.into_iter().enumerate() {
                res.map_err(|e| anyhow!("decode_step_batch: {e}"))?;
                toks[r] = argmax(&packed[r * v..(r + 1) * v]);
                fp += toks[r] as i64;
            }
        }
        let per = t0.elapsed().as_secs_f64() / ((gen - 1) * OCCUPANCY) as f64;
        for sess in sessions {
            backend.decode_end(sess);
        }
        if i > 0 {
            s.push(per); // first run is warmup
        }
    }
    assert!(fp > i64::MIN);

    let kernel_calls: u64 =
        prof::KERNELS.iter().map(|sl| sl.calls.load(Ordering::Relaxed)).sum();
    let fft_calls = prof::FFT.calls.load(Ordering::Relaxed);
    let decode_rounds = prof::DECODE_BATCH.calls.load(Ordering::Relaxed);
    if prof_on {
        // The instrumented path must actually instrument: prefill runs the
        // FFT, decode rounds hit the wrapped kernels and the batch hook.
        if kernel_calls == 0 || fft_calls == 0 || decode_rounds == 0 {
            bail!(
                "HYENA_PROF=1 but slots did not tick (kernel {kernel_calls}, \
                 fft {fft_calls}, decode {decode_rounds})"
            );
        }
    } else if kernel_calls + fft_calls + decode_rounds != 0 {
        bail!(
            "HYENA_PROF=0 but slots ticked (kernel {kernel_calls}, \
             fft {fft_calls}, decode {decode_rounds}) — the off path is \
             supposed to record nothing"
        );
    }
    // The parent greps this line; keep the spelling.
    println!(
        "obs-measure ms_per_tok={:.6} kernel_calls={kernel_calls} \
         fft_calls={fft_calls} decode_rounds={decode_rounds}",
        s.p50() * 1e3
    );
    Ok(())
}

#[derive(Debug, Default, Clone, Copy)]
struct Measure {
    ms_per_tok: f64,
    kernel_calls: u64,
    fft_calls: u64,
    decode_rounds: u64,
}

/// Re-exec this bench binary in `--measure` mode with HYENA_PROF pinned.
fn spawn_measure(on: bool, iters: usize, gen: usize, threads: usize) -> Result<Measure> {
    let exe = std::env::current_exe().context("current_exe")?;
    let out = std::process::Command::new(&exe)
        .args([
            "--measure",
            "--iters",
            &iters.to_string(),
            "--gen",
            &gen.to_string(),
            "--threads",
            &threads.to_string(),
        ])
        .env("HYENA_PROF", if on { "1" } else { "0" })
        .output()
        .with_context(|| format!("spawn measure child (HYENA_PROF={})", on as u8))?;
    let stdout = String::from_utf8_lossy(&out.stdout);
    if !out.status.success() {
        bail!(
            "measure child (HYENA_PROF={}) failed: {}\n{}{}",
            on as u8,
            out.status,
            stdout,
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let line = stdout
        .lines()
        .find_map(|l| l.trim().strip_prefix("obs-measure "))
        .ok_or_else(|| anyhow!("measure child printed no obs-measure line:\n{stdout}"))?;
    let mut m = Measure::default();
    for kv in line.split_whitespace() {
        let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("bad field {kv:?}"))?;
        match k {
            "ms_per_tok" => m.ms_per_tok = v.parse()?,
            "kernel_calls" => m.kernel_calls = v.parse()?,
            "fft_calls" => m.fft_calls = v.parse()?,
            "decode_rounds" => m.decode_rounds = v.parse()?,
            _ => {}
        }
    }
    if m.ms_per_tok <= 0.0 {
        bail!("measure child reported non-positive ms_per_tok");
    }
    Ok(m)
}

fn main() -> Result<()> {
    let args = Args::parse(&["smoke", "measure"]);
    if args.flag("measure") {
        return run_measure(&args);
    }
    let smoke = args.flag("smoke");
    let iters = args.get_usize("iters", if smoke { 3 } else { 8 });
    let gen = args.get_usize("gen", if smoke { 8 } else { 32 }).max(2);
    let rounds = args.get_usize("rounds", if smoke { 2 } else { 3 }).max(1);
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();

    println!(
        "obs overhead: op_hyena_L{SEQLEN}, occupancy {OCCUPANCY}, {gen} tokens, \
         {iters} iters x {rounds} interleaved rounds, {threads} threads"
    );
    // Interleave off/on children so drift (thermal, competing load) hits
    // both modes; the min over rounds is each mode's honest best.
    let mut off_best = f64::INFINITY;
    let mut on_best = f64::INFINITY;
    let mut on_last = Measure::default();
    for r in 0..rounds {
        let off = spawn_measure(false, iters, gen, threads)?;
        let on = spawn_measure(true, iters, gen, threads)?;
        println!(
            "  round {r}: off {:.3} ms/tok   on {:.3} ms/tok   \
             ({} kernel calls, {} fft runs, {} decode rounds profiled)",
            off.ms_per_tok, on.ms_per_tok, on.kernel_calls, on.fft_calls, on.decode_rounds
        );
        off_best = off_best.min(off.ms_per_tok);
        on_best = on_best.min(on.ms_per_tok);
        on_last = on;
    }
    let overhead_pct = (on_best / off_best - 1.0) * 100.0;

    let mut table = Table::new(
        "§Perf Native — obs: HYENA_PROF profiling overhead (batched decode)",
        &["L", "occ", "off ms/tok", "on ms/tok", "overhead %"],
    );
    table.row(vec![
        SEQLEN.to_string(),
        OCCUPANCY.to_string(),
        format!("{off_best:.3}"),
        format!("{on_best:.3}"),
        format!("{overhead_pct:.2}"),
    ]);
    table.emit("native_obs");

    merge_bench_json(
        Path::new(&out_path),
        "obs",
        Json::obj(vec![
            ("model", Json::str(&format!("op_hyena_L{SEQLEN}"))),
            ("occupancy", Json::num(OCCUPANCY as f64)),
            ("new_tokens", Json::num(gen as f64)),
            ("threads", Json::num(threads as f64)),
            ("rounds", Json::num(rounds as f64)),
            ("off_ms_per_tok", Json::num(off_best)),
            ("on_ms_per_tok", Json::num(on_best)),
            ("overhead_pct", Json::num(overhead_pct)),
            ("prof_kernel_calls", Json::num(on_last.kernel_calls as f64)),
            ("prof_fft_runs", Json::num(on_last.fft_calls as f64)),
            ("prof_decode_rounds", Json::num(on_last.decode_rounds as f64)),
        ]),
    )?;
    println!(
        "profiling overhead: {overhead_pct:.2}% (off {off_best:.3} -> on {on_best:.3} ms/tok)"
    );
    println!("bench ledger -> {out_path} (key: obs)");

    if smoke && overhead_pct > 3.0 {
        bail!(
            "obs-smoke gate: HYENA_PROF=1 decode overhead {overhead_pct:.2}% \
             exceeds the 3% budget"
        );
    }
    Ok(())
}
