//! L3 micro-benchmarks: the coordinator pieces that sit on the request path
//! outside XLA — batcher decisions, tensor⇄literal conversion, task/data
//! generation. These are the knobs of the §Perf L3 iteration: the
//! coordinator must not be the bottleneck (paper's bottleneck is FFTConv).
//!
//! Run: `cargo bench --bench coordinator_micro`

use std::time::{Duration, Instant};

use hyena::coordinator::batcher::Batcher;
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::Tensor;
use hyena::tasks::recall::RecallTask;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

fn time_it<F: FnMut()>(reps: usize, mut f: F) -> Summary {
    let mut s = Summary::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

fn main() {
    let mut table = Table::new(
        "coordinator micro-benchmarks",
        &["op", "p50", "p99", "unit"],
    );
    let mut push = |name: &str, s: &Summary, unit: &str| {
        println!("{name:>32}: p50 {:>10.3}µs", s.p50() * 1e6);
        table.row(vec![
            name.to_string(),
            format!("{:.3}", s.p50() * 1e6),
            format!("{:.3}", s.p99() * 1e6),
            unit.to_string(),
        ]);
    };

    // Batcher decision path.
    let s = time_it(2000, || {
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(5));
        for i in 0..8 {
            b.push(i);
        }
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        std::hint::black_box(batch);
    });
    push("batcher fill+drain (8 req)", &s, "µs");

    // Tensor -> literal conversion (the per-step host boundary).
    let data: Vec<f32> = (0..8 * 256).map(|i| i as f32).collect();
    let t = Tensor::from_f32(&[8, 256], data).unwrap();
    let s = time_it(500, || {
        let lit = t.to_literal().unwrap();
        std::hint::black_box(lit);
    });
    push("tensor->literal 8x256 f32", &s, "µs");

    let lit = t.to_literal().unwrap();
    let s = time_it(500, || {
        let back = Tensor::from_literal(&lit).unwrap();
        std::hint::black_box(back);
    });
    push("literal->tensor 8x256 f32", &s, "µs");

    // Task generation (per training batch).
    let task = RecallTask::new(1024, 30, 8);
    let mut rng = Pcg::new(0);
    let s = time_it(200, || {
        let b = task.sample_batch(&mut rng);
        std::hint::black_box(b);
    });
    push("recall batch gen 8x1024", &s, "µs");

    let task = RecallTask::new(1024, 30, 8);
    let mut rng = Pcg::new(0);
    let s = time_it(100, || {
        let b = task.sample_batch(&mut rng).to_tensors();
        std::hint::black_box(b);
    });
    push("recall batch gen+tensors", &s, "µs");

    // Corpus batch assembly.
    let corpus = generate(&CorpusConfig::default(), 100);
    let mut lb = LmBatches::new(&corpus.train, 8, 256, 0).with_vocab(96);
    let s = time_it(500, || {
        let b = lb.next_batch();
        std::hint::black_box(b);
    });
    push("tinypile batch 8x256", &s, "µs");

    table.emit("coordinator_micro");
}
