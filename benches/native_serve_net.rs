//! Network serving bench: the full wire path — HTTP/1.1 request parsing,
//! engine admission, SSE token streaming — measured end-to-end with the
//! chaos loadgen in steady (fault-free) mode. Reports time-to-first-token
//! and decode pace per token at p50/p99 across concurrent keep-alive
//! clients, i.e. what the resilience layer costs on top of the in-process
//! serving numbers in `native_serve` / `native_decode`.
//!
//! Results print as a table and persist into `BENCH_native.json` (key
//! `serve_net`) next to the other native ledgers (EXPERIMENTS.md §Perf
//! Native).
//!
//! Run: `cargo bench --bench native_serve_net -- [--model lm_hyena_s]
//!        [--clients 8] [--requests 8] [--max-new 16] [--threads N]
//!        [--out BENCH_native.json] [--smoke]`
//!
//! `--smoke` (part of `scripts/check.sh serve-net-smoke`) uses the tiny
//! golden config and fails hard unless every stream completes, no transport
//! errors occur, and zero decode sessions leak across the drain.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use hyena::backend::BackendKind;
use hyena::backend::native::NativeConfig;
use hyena::coordinator::server::Server;
use hyena::net::client::{run_loadgen, LoadGenConfig};
use hyena::net::server::NetServer;
use hyena::net::{ChaosConfig, NetConfig};
use hyena::report::{merge_bench_json, Table};
use hyena::util::cli::Args;
use hyena::util::json::Json;
use hyena::util::pool;

fn main() -> Result<()> {
    let args = Args::parse(&["smoke"]);
    let smoke = args.flag("smoke");
    let name = args
        .get_or("model", if smoke { "golden_tiny" } else { "lm_hyena_s" })
        .to_string();
    let clients = args.get_usize("clients", if smoke { 4 } else { 8 });
    let requests = args.get_usize("requests", if smoke { 4 } else { 8 });
    let threads = args.get_usize("threads", pool::default_threads()).max(1);
    let out_path = args.get_or("out", "BENCH_native.json").to_string();
    pool::configure(threads);

    let cfg = NativeConfig::builtin(&name)
        .ok_or_else(|| anyhow!("no built-in native config named {name:?}"))?;
    let (l, vocab) = (cfg.seqlen, cfg.vocab);
    let max_new = args.get_usize("max-new", (l / 4).clamp(4, 16));
    let prompt_len =
        args.get_usize("prompt-len", l / 8).clamp(1, l.saturating_sub(max_new + 1).max(1));

    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from(format!("artifacts/{name}")),
        0,
        Duration::from_millis(2),
        None,
        None,
        None,
    )?;
    let net = NetServer::start(
        server.handle.clone(),
        NetConfig {
            addr: "127.0.0.1:0".into(),
            conn_threads: clients + 4,
            quiet: true,
            ..NetConfig::default()
        },
    )?;
    let addr = net.addr();
    println!(
        "{name}: L={l}, capacity {}, {clients} clients x {requests} requests, \
         prompt {prompt_len} -> {max_new} tokens, {threads} threads",
        server.handle.capacity()
    );

    let lcfg = LoadGenConfig {
        clients,
        requests_per_client: requests,
        prompt_len,
        max_new,
        vocab,
        timeout_ms: 0, // perf run: no deadlines
        chaos: ChaosConfig::off(),
        burst: false,
        max_retries: 16,
        seed: 0,
        io_timeout_ms: 60_000,
    };
    let t0 = Instant::now();
    let r = run_loadgen(addr, &lcfg);
    let wall = t0.elapsed().as_secs_f64();
    let report = net.finish()?;
    server.stop();

    let total = clients * requests;
    let (ttfb50, ttfb99) = (r.ttfb_percentile(50.0), r.ttfb_percentile(99.0));
    let (tok50, tok99) = (r.ms_per_token_percentile(50.0), r.ms_per_token_percentile(99.0));
    let tok_per_s = r.tokens as f64 / wall.max(1e-9);
    let mut table = Table::new(
        "§Perf Native — network serving: HTTP/SSE wire path (steady load)",
        &["clients", "ok/total", "ttfb p50 ms", "ttfb p99 ms", "ms/token p50", "ms/token p99", "tok/s"],
    );
    table.row(vec![
        clients.to_string(),
        format!("{}/{}", r.ok, total),
        format!("{ttfb50:.2}"),
        format!("{ttfb99:.2}"),
        format!("{tok50:.3}"),
        format!("{tok99:.3}"),
        format!("{tok_per_s:.0}"),
    ]);
    table.emit("native_serve_net");
    println!(
        "{} ok / {total} ({} x 429 retried, {} stream errors, {} io errors), \
         {} tokens in {wall:.2}s; drain: {} finished / {} aborted, {} leaked",
        r.ok,
        r.rejected_429,
        r.stream_errors,
        r.io_errors,
        r.tokens,
        report.drain.finished,
        report.drain.aborted,
        report.leaked_sessions
    );

    merge_bench_json(
        Path::new(&out_path),
        "serve_net",
        Json::obj(vec![
            ("model", Json::str(&name)),
            ("seqlen", Json::num(l as f64)),
            ("threads", Json::num(threads as f64)),
            ("clients", Json::num(clients as f64)),
            ("requests", Json::num(total as f64)),
            ("prompt_len", Json::num(prompt_len as f64)),
            ("max_new", Json::num(max_new as f64)),
            ("ok", Json::num(r.ok as f64)),
            ("rejected_429", Json::num(r.rejected_429 as f64)),
            ("ttfb_p50_ms", Json::num(ttfb50)),
            ("ttfb_p99_ms", Json::num(ttfb99)),
            ("ms_per_token_p50", Json::num(tok50)),
            ("ms_per_token_p99", Json::num(tok99)),
            ("tokens_per_s", Json::num(tok_per_s)),
            ("leaked_sessions", Json::num(report.leaked_sessions as f64)),
        ]),
    )?;
    println!("bench ledger -> {out_path} (key: serve_net)");

    if smoke {
        if r.ok != total {
            bail!("serve-net-smoke gate: {} of {total} streams completed", r.ok);
        }
        if r.io_errors > 0 || r.stream_errors > 0 {
            bail!(
                "serve-net-smoke gate: {} io errors, {} stream errors under steady load",
                r.io_errors,
                r.stream_errors
            );
        }
        if report.leaked_sessions > 0 {
            bail!("serve-net-smoke gate: {} decode sessions leaked", report.leaked_sessions);
        }
    }
    Ok(())
}
