//! E1 — Fig. 4.1 / Tab. A.2: associative recall accuracy across long-conv
//! parametrizations, sequence lengths and vocabulary sizes.
//!
//! Paper setup: order-2 Hyena, 2 layers, width 64, swap only the long-conv
//! parametrization (Conv1d / FNO / SSM(H3) / TransferFunc / CKConv / Hyena
//! implicit). Paper sweeps L up to 131k on A100s; this testbed sweeps
//! L ∈ {128, 512} and vocab ∈ {10, 20, 30, 40} (DESIGN.md §3). The paper's
//! claim to reproduce: implicit FFN-based filters (Hyena, CKConv) >> SSM/
//! TransferFunc >> explicit (FNO, Conv1d), gap widening with L and vocab.
//!
//! Run: `cargo run --release --example fig4_1 -- [--steps 1500] [--lens 128,512] [--vocabs 10,30]`

use anyhow::Result;
use hyena::coordinator::experiment::train_and_eval;
use hyena::report::Table;
use hyena::tasks::recall::RecallTask;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

const KINDS: &[&str] = &["implicit", "ckconv", "ssm", "tf", "fno", "conv1d"];

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 1500);
    let lens: Vec<usize> = args
        .get_or("lens", "128,512")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let vocabs: Vec<usize> = args
        .get_or("vocabs", "10,30")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    let mut table = Table::new(
        "Fig 4.1 — recall accuracy (%) by long-conv parametrization",
        &["parametrization", "seqlen", "vocab", "accuracy", "steps/s"],
    );
    for &l in &lens {
        for kind in KINDS {
            let name = format!("ar_{kind}_L{l}");
            let dir = hyena::artifact(&name);
            if !dir.join("manifest.json").exists() {
                eprintln!("skip {name}: artifact missing");
                continue;
            }
            for &v in &vocabs {
                let task = RecallTask::new(l, v, 16);
                let mut rng = Pcg::new(0);
                let src = {
                    let task = task.clone();
                    move || task.sample_batch(&mut rng).to_tensors()
                };
                let (acc, rep) = train_and_eval(&dir, 0, src, steps, 8, true)?;
                println!(
                    "{kind:>9} L={l:<5} V={v:<3} acc {:>5.1}%  ({:.1} steps/s)",
                    100.0 * acc,
                    rep.steps_per_s
                );
                table.row(vec![
                    kind.to_string(),
                    l.to_string(),
                    v.to_string(),
                    format!("{:.1}", 100.0 * acc),
                    format!("{:.1}", rep.steps_per_s),
                ]);
            }
        }
    }
    table.emit("fig4_1");
    Ok(())
}
