//! Quickstart: the whole stack in one page.
//!
//! Loads the tiny Hyena LM artifact (AOT-compiled by `make artifacts`),
//! trains it on associative recall for a few hundred steps entirely from
//! Rust (Python is NOT running), evaluates recall accuracy, and generates a
//! few tokens through the dynamic-batching server.
//!
//! Run: `cargo run --release --example quickstart -- [--steps N]`

use std::time::Duration;

use anyhow::Result;
use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{GenerateRequest, Server};
use hyena::coordinator::trainer::{eval_accuracy, Trainer};
use hyena::runtime::ModelState;
use hyena::tasks::recall::RecallTask;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 800);
    let dir = hyena::artifact("golden_tiny");

    // 1. Load + AOT-compile the artifact; init params inside XLA.
    let mut model = ModelState::load(&dir, 0)?;
    println!(
        "loaded {} ({} params)",
        model.manifest.name, model.manifest.param_count
    );

    // 2. Train on associative recall (paper Sec. 4.1).
    let task = RecallTask::new(
        model.manifest.seqlen()?,
        8,
        model.manifest.batch()?,
    );
    let mut rng = Pcg::new(0);
    let mut source = {
        let task = task.clone();
        move || task.sample_batch(&mut rng).to_tensors()
    };
    let report = {
        let mut trainer = Trainer::new(&mut model, &mut source);
        trainer.log_every = 100;
        trainer.run(steps)?
    };
    println!(
        "trained {} steps in {:.1}s ({:.1} steps/s)",
        report.steps, report.wall_s, report.steps_per_s
    );

    // 3. Evaluate recall accuracy on fresh sequences.
    let acc = eval_accuracy(&model, &mut source, 8)?;
    println!("associative recall accuracy: {:.1}%", 100.0 * acc);

    // 4. Serve a couple of generate requests through the batching server.
    let server = Server::start(dir, 0, Duration::from_millis(5))?;
    for i in 0..3 {
        let resp = server.handle.generate(GenerateRequest {
            prompt: vec![1 + i, 4, 1 + i],
            max_new: 4,
            sampling: Sampling::Greedy,
            deadline: None,
            trace_id: 0,
        })?;
        println!(
            "generated {:?} in {:?} (batch x{})",
            resp.tokens, resp.total_time, resp.batch_occupancy
        );
    }
    server.stop();
    Ok(())
}
