//! E3 — Tab. 4.3: WikiText103-style LM perplexity shootout at matched
//! parameter budget (Transformer vs Hyena-3 vs Hyena-3-slim vs AFT vs RWKV).
//!
//! Paper: 125M params on WikiText103 — Transformer 18.6, Hyena-3 18.6,
//! Hyena-3-slim (deeper/thinner) 18.5, AFT-conv 28.2. Testbed: ~1M-param
//! models on TinyPile-W (DESIGN.md §3); the claim to reproduce is the
//! *ordering*: hyena ≈ transformer, slim ≤ hyena, both ≪ AFT/RWKV.
//!
//! Run: `cargo run --release --example table4_3 -- [--steps 800] [--docs 400]`

use anyhow::Result;
use hyena::coordinator::trainer::{eval_loss, Trainer};
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::ModelState;
use hyena::util::cli::Args;

const MODELS: &[(&str, &str)] = &[
    ("Transformer", "lm_attn_wt"),
    ("Hyena-3", "lm_hyena3_wt"),
    ("Hyena-3-slim", "lm_hyena3slim_wt"),
    ("AFT-conv", "lm_aft_wt"),
    ("RWKV", "lm_rwkv_wt"),
];

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 800);
    let docs = args.get_usize("docs", 400);
    let seed = args.get_u64("seed", 0);
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, docs);

    let mut table = Table::new(
        "Tab 4.3 — TinyPile-W validation perplexity (same tokenizer)",
        &["model", "params", "val loss", "ppl", "train flops"],
    );
    for (label, name) in MODELS {
        let dir = hyena::artifact(name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        let mut model = ModelState::load(&dir, seed as i32)?;
        let (b, l, v) = (
            model.manifest.batch()?,
            model.manifest.seqlen()?,
            model.manifest.vocab()?,
        );
        let mut batches = LmBatches::new(&corpus.train, b, l, seed).with_vocab(v);
        let rep = {
            let mut tr = Trainer::new(&mut model, move || batches.next_batch());
            tr.quiet = true;
            tr.run(steps)?
        };
        let evals = LmBatches::eval_batches_vocab(&corpus.val, b, l, v);
        let n = evals.len().min(6);
        let mut i = 0;
        let nll = eval_loss(
            &model,
            &mut || {
                let batch = evals[i].clone();
                i += 1;
                batch
            },
            n,
        )?;
        println!(
            "{label:>14}: {} params, val ppl {:.2}",
            model.manifest.param_count,
            nll.exp()
        );
        table.row(vec![
            label.to_string(),
            model.manifest.param_count.to_string(),
            format!("{nll:.4}"),
            format!("{:.2}", nll.exp()),
            format!("{:.2e}", rep.total_flops.unwrap_or(0.0)),
        ]);
    }
    table.emit("table4_3");
    Ok(())
}
