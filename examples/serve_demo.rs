//! Serving workload demo: open-loop arrival process against the
//! dynamic-batching server; reports latency percentiles, throughput and
//! batch occupancy across batching deadlines (the policy the vLLM-style
//! literature sweeps).
//!
//! Run: `cargo run --release --example serve_demo -- \
//!        [--model golden_tiny] [--requests 48] [--rate 20] [--deadlines 1,10,50]`

use std::time::{Duration, Instant};

use anyhow::Result;
use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{GenerateRequest, Server};
use hyena::report::Table;
use hyena::runtime::Manifest;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;
use hyena::util::stats::Summary;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let name = args.get_or("model", "golden_tiny").to_string();
    let n_req = args.get_usize("requests", 48);
    let rate = args.get_f64("rate", 20.0); // requests/second
    let deadlines: Vec<u64> = args
        .get_or("deadlines", "1,10,50")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let seed = args.get_u64("seed", 0);

    let man = Manifest::load(&hyena::artifact(&name))?;
    let vocab = man.vocab()?;
    let l = man.seqlen()?;
    let max_new = 8.min(l.saturating_sub(6));

    let mut table = Table::new(
        &format!("serving policy sweep — {name}, {n_req} req @ {rate}/s"),
        &["deadline_ms", "p50_ms", "p99_ms", "mean_occupancy", "tok_per_s"],
    );
    for &dl in &deadlines {
        let server = Server::start(
            hyena::artifact(&name),
            seed as i32,
            Duration::from_millis(dl),
        )?;
        let mut rng = Pcg::new(seed);
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for _ in 0..n_req {
            // Poisson-ish arrivals: exponential inter-arrival times.
            let gap = -(1.0 - rng.f32() as f64).ln() / rate;
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.25)));
            let prompt: Vec<i32> = (0..5).map(|_| rng.usize_below(vocab) as i32).collect();
            handles.push(server.handle.submit(GenerateRequest {
                prompt,
                max_new,
                sampling: Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            }));
        }
        let mut lat = Summary::new();
        let mut occ = Summary::new();
        let mut tokens = 0usize;
        for h in handles {
            let resp = h.recv().expect("worker alive")?;
            lat.push(resp.total_time.as_secs_f64() * 1e3);
            occ.push(resp.batch_occupancy as f64);
            tokens += resp.tokens.len();
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "deadline {dl:>3}ms: p50 {:.1}ms p99 {:.1}ms occupancy {:.2} {:.1} tok/s",
            lat.p50(),
            lat.p99(),
            occ.mean(),
            tokens as f64 / wall
        );
        table.row(vec![
            dl.to_string(),
            format!("{:.1}", lat.p50()),
            format!("{:.1}", lat.p99()),
            format!("{:.2}", occ.mean()),
            format!("{:.1}", tokens as f64 / wall),
        ]);
        server.stop();
    }
    table.emit("serve_demo");
    Ok(())
}
