//! E4 — Tab. 4.4 + Fig. 4.2: TinyPile perplexity at increasing token
//! budgets (the "preliminary scaling law"), GPT vs Hyena at two sizes, with
//! the App. A.2 FLOP accounting.
//!
//! Paper: GPT-125M vs Hyena-153M and GPT-355M vs Hyena-355M trained for
//! 5/10/15B tokens — Hyena matches ppl with ~20% fewer total FLOPs (the
//! saving is the non-parametric attention FLOPs). Testbed: two model sizes
//! × three token budgets on TinyPile; the claims to reproduce are
//! (a) ppl(hyena) ≈ ppl(gpt) at each budget, (b) FLOPs(hyena) < FLOPs(gpt)
//! at the same budget, with the gap growing with seqlen.
//!
//! Run: `cargo run --release --example fig4_2 -- [--budgets 100,200,400] [--docs 400]`

use anyhow::Result;
use hyena::coordinator::trainer::{eval_loss, Trainer};
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::ModelState;
use hyena::util::cli::Args;

const MODELS: &[(&str, &str)] = &[
    ("GPT-s", "lm_gpt_s"),
    ("Hyena-s", "lm_hyena_s"),
    ("GPT-m", "lm_gpt_m"),
    ("Hyena-m", "lm_hyena_m"),
];

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    // Budgets are in optimizer steps (tokens = steps × batch × seqlen);
    // separate runs per budget like the paper's 5/10/15B protocol.
    let budgets: Vec<u64> = args
        .get_or("budgets", "100,200,400")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let docs = args.get_usize("docs", 400);
    let seed = args.get_u64("seed", 0);
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, docs);

    let mut table = Table::new(
        "Fig 4.2 / Tab 4.4 — ppl vs token budget and total FLOPs",
        &["model", "params", "steps", "tokens", "val ppl", "total flops"],
    );
    for (label, name) in MODELS {
        let dir = hyena::artifact(name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        for &budget in &budgets {
            let mut model = ModelState::load(&dir, seed as i32)?;
            let (b, l, v) = (
                model.manifest.batch()?,
                model.manifest.seqlen()?,
                model.manifest.vocab()?,
            );
            let mut batches = LmBatches::new(&corpus.train, b, l, seed).with_vocab(v);
            let rep = {
                let mut tr = Trainer::new(&mut model, move || batches.next_batch());
                tr.quiet = true;
                tr.run(budget)?
            };
            let evals = LmBatches::eval_batches_vocab(&corpus.val, b, l, v);
            let n = evals.len().min(6);
            let mut i = 0;
            let nll = eval_loss(
                &model,
                &mut || {
                    let batch = evals[i].clone();
                    i += 1;
                    batch
                },
                n,
            )?;
            println!(
                "{label:>8} @ {budget:>4} steps ({} tok): ppl {:.2}, {:.2e} FLOPs",
                rep.tokens_seen,
                nll.exp(),
                rep.total_flops.unwrap_or(0.0)
            );
            table.row(vec![
                label.to_string(),
                model.manifest.param_count.to_string(),
                budget.to_string(),
                rep.tokens_seen.to_string(),
                format!("{:.2}", nll.exp()),
                format!("{:.3e}", rep.total_flops.unwrap_or(0.0)),
            ]);
        }
    }
    table.emit("fig4_2");
    Ok(())
}
