//! E2 — Tab. 4.2: associative recall on longer sequences, operator shootout.
//!
//! Paper: vocab 30, 2-layer width-64 models — Hyena solves it (100%) while
//! GSS/H3/AFT/RWKV collapse and exact attention runs out of memory at 64k+.
//! Testbed: L = 1024 (CPU budget; DESIGN.md §3), same operators, same
//! 2-layer width-64 recipe, expectation: hyena ≈ attention ≫ others.
//!
//! Run: `cargo run --release --example table4_2 -- [--steps 1500] [--vocab 30]`

use anyhow::Result;
use hyena::coordinator::experiment::train_and_eval;
use hyena::report::Table;
use hyena::tasks::recall::RecallTask;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

const OPS: &[&str] = &["hyena", "flash", "attn", "gss", "h3", "aft", "rwkv"];

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 1500);
    let vocab = args.get_usize("vocab", 30);
    let l = args.get_usize("len", 1024);
    let ops_filter = args.get_or("ops", "hyena,flash,attn,gss,h3,aft,rwkv").to_string();

    let mut table = Table::new(
        "Tab 4.2 — recall accuracy (%) by operator",
        &["operator", "seqlen", "vocab", "accuracy", "steps/s"],
    );
    for kind in OPS {
        if !ops_filter.split(',').any(|o| o == *kind) {
            continue;
        }
        let name = format!("op_{kind}_L{l}");
        let dir = hyena::artifact(&name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        let task = RecallTask::new(l, vocab, 8);
        let mut rng = Pcg::new(0);
        let src = {
            let task = task.clone();
            move || task.sample_batch(&mut rng).to_tensors()
        };
        let (acc, rep) = train_and_eval(&dir, 0, src, steps, 8, true)?;
        println!(
            "{kind:>6} L={l} V={vocab}: acc {:>5.1}%  ({:.2} steps/s)",
            100.0 * acc,
            rep.steps_per_s
        );
        table.row(vec![
            kind.to_string(),
            l.to_string(),
            vocab.to_string(),
            format!("{:.1}", 100.0 * acc),
            format!("{:.2}", rep.steps_per_s),
        ]);
    }
    table.emit("table4_2");
    Ok(())
}
