//! E10 — Fig. D.5: Hyena long-convolution filters at initialization vs after
//! training, plus the App. D.3 positional-encoding preconditioning check.
//!
//! Emits CSVs under `results/` with the block-0 filter responses of a Hyena
//! LM before and after a TinyPile training run, and summary statistics
//! (decay of |h_t| with t; high-frequency energy fraction) showing the
//! exp-decay window + sine activation at work.
//!
//! Run: `cargo run --release --example figD_filters -- [--steps 300]`

use anyhow::Result;
use hyena::coordinator::trainer::Trainer;
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::{ModelState, Tensor};
use hyena::util::cli::Args;

fn filter_stats(h: &Tensor) -> Result<(f64, f64)> {
    let shape = h.shape();
    let (n, d, l) = (shape[0], shape[1], shape[2]);
    let data = h.as_f32()?;
    // tail ratio: mean |h| over last half vs overall (decay signature)
    let mut head = 0.0f64;
    let mut tail = 0.0f64;
    for nd in 0..n * d {
        for t in 0..l {
            let v = data[nd * l + t].abs() as f64;
            if t >= l / 2 {
                tail += v;
            }
            head += v;
        }
    }
    let tail_ratio = tail / head.max(1e-12);
    // roughness: mean |h_t − h_{t−1}| / mean |h| (high-freq content proxy)
    let mut dsum = 0.0f64;
    let mut asum = 0.0f64;
    for nd in 0..n * d {
        for t in 1..l {
            dsum += (data[nd * l + t] - data[nd * l + t - 1]).abs() as f64;
            asum += data[nd * l + t].abs() as f64;
        }
    }
    Ok((tail_ratio, dsum / asum.max(1e-12)))
}

fn dump_csv(h: &Tensor, path: &str) -> Result<()> {
    let shape = h.shape();
    let (n, d, l) = (shape[0], shape[1], shape[2]);
    let data = h.as_f32()?;
    let mut csv = String::from("order,channel,t,h\n");
    for o in 0..n {
        for c in 0..d.min(8) {
            for t in 0..l {
                csv.push_str(&format!("{o},{c},{t},{}\n", data[(o * d + c) * l + t]));
            }
        }
    }
    std::fs::create_dir_all("results")?;
    std::fs::write(path, csv)?;
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 300);
    let name = args.get_or("model", "lm_hyena_s").to_string();
    let seed = args.get_u64("seed", 0);

    let mut model = ModelState::load(&hyena::artifact(&name), seed as i32)?;
    let h0 = model.dump_filters()?;
    dump_csv(&h0, "results/figD_filters_init.csv")?;
    let (tail0, rough0) = filter_stats(&h0)?;

    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, 300);
    let (b, l, v) = (
        model.manifest.batch()?,
        model.manifest.seqlen()?,
        model.manifest.vocab()?,
    );
    let mut batches = LmBatches::new(&corpus.train, b, l, seed).with_vocab(v);
    {
        let mut tr = Trainer::new(&mut model, move || batches.next_batch());
        tr.quiet = true;
        tr.run(steps)?;
    }
    let h1 = model.dump_filters()?;
    dump_csv(&h1, "results/figD_filters_trained.csv")?;
    let (tail1, rough1) = filter_stats(&h1)?;

    let mut t = Table::new(
        "Fig D.5 — filter statistics, init vs trained",
        &["state", "tail |h| fraction", "roughness (hi-freq proxy)"],
    );
    t.row(vec!["init".into(), format!("{tail0:.4}"), format!("{rough0:.4}")]);
    t.row(vec![
        format!("after {steps} steps"),
        format!("{tail1:.4}"),
        format!("{rough1:.4}"),
    ]);
    t.emit("figD_filters");
    println!("filter CSVs: results/figD_filters_{{init,trained}}.csv");
    Ok(())
}
