//! E8 — Tab. C.1: vocabulary-size scaling on associative recall vs LM loss
//! on the corpus — the paper's "synthetics predict scale" correlation.
//!
//! Paper: recall accuracy at vocab {10,20,30,40} (L = training seqlen)
//! correlates with loss on The Pile after 5B tokens — Hyena and Transformer
//! top both columns, Conv1d/AFT bottom both. Testbed: recall at the same
//! vocab grid on the op_* artifacts + TinyPile loss of the corresponding
//! lm-style training run on the same operator.
//!
//! Run: `cargo run --release --example tableC_1 -- [--steps 1200] [--lm-steps 300]`

use anyhow::Result;
use hyena::coordinator::experiment::train_and_eval;
use hyena::coordinator::trainer::Trainer;
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::ModelState;
use hyena::tasks::recall::RecallTask;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

const OPS: &[&str] = &["hyena", "attn", "h3", "aft"];
const VOCABS: &[usize] = &[10, 20, 30, 40];

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 1200);
    let lm_steps = args.get_u64("lm-steps", 300);
    let seed = args.get_u64("seed", 0);
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, 300);

    let mut table = Table::new(
        "Tab C.1 — recall acc (%) @ vocab size vs TinyPile loss",
        &["model", "acc@10", "acc@20", "acc@30", "acc@40", "tinypile loss"],
    );
    for kind in OPS {
        let name = format!("op_{kind}_L1024");
        let dir = hyena::artifact(&name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        let mut accs = Vec::new();
        for &v in VOCABS {
            let task = RecallTask::new(1024, v, 8);
            let mut rng = Pcg::new(seed);
            let src = {
                let task = task.clone();
                move || task.sample_batch(&mut rng).to_tensors()
            };
            let (acc, _) = train_and_eval(&dir, seed as i32, src, steps, 6, true)?;
            accs.push(acc);
            println!("{kind:>6} vocab {v}: acc {:.1}%", 100.0 * acc);
        }
        // TinyPile loss of the same operator trained as an LM (fresh init).
        let mut model = ModelState::load(&dir, seed as i32)?;
        let (b, l, vv) = (
            model.manifest.batch()?,
            model.manifest.seqlen()?,
            model.manifest.vocab()?,
        );
        let mut batches = LmBatches::new(&corpus.train, b, l, seed).with_vocab(vv);
        let rep = {
            let mut tr = Trainer::new(&mut model, move || batches.next_batch());
            tr.quiet = true;
            tr.run(lm_steps)?
        };
        println!("{kind:>6} TinyPile loss after {lm_steps} steps: {:.3}", rep.final_loss);
        table.row(vec![
            kind.to_string(),
            format!("{:.0}", 100.0 * accs[0]),
            format!("{:.0}", 100.0 * accs[1]),
            format!("{:.0}", 100.0 * accs[2]),
            format!("{:.0}", 100.0 * accs[3]),
            format!("{:.3}", rep.final_loss),
        ]);
    }
    table.emit("tableC_1");
    Ok(())
}
