//! End-to-end driver (DESIGN.md §6): pretrain a Hyena LM on TinyPile from
//! the Rust coordinator, log the loss curve, evaluate held-out perplexity,
//! then bring the trained model up behind the dynamic-batching server and
//! report serving latency/throughput. Proves all layers compose:
//! Pallas-kerneled JAX graphs → HLO artifacts → PJRT runtime → trainer →
//! server.
//!
//! Run: `cargo run --release --example lm_pretrain -- \
//!        [--model lm_hyena_s] [--steps 400] [--docs 400] [--requests 16]`
//!
//! The paper trains 125M–355M models for 5–15B tokens on 8×A100; this
//! testbed is one CPU core, so the default is a ~1.1M-param model for
//! ~0.8M tokens (substitution notes: DESIGN.md §3). Results land in
//! `results/lm_pretrain_<model>.csv` and EXPERIMENTS.md.

use std::time::{Duration, Instant};

use anyhow::Result;
use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{GenerateRequest, Server};
use hyena::coordinator::trainer::{eval_loss, Trainer};
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::report::Table;
use hyena::runtime::ModelState;
use hyena::tokenizer::CharTokenizer;
use hyena::util::cli::Args;
use hyena::util::stats::Summary;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let name = args.get_or("model", "lm_hyena_s").to_string();
    let steps = args.get_u64("steps", 400);
    let docs = args.get_usize("docs", 400);
    let n_req = args.get_usize("requests", 16);
    let seed = args.get_u64("seed", 0);

    // ---- data -----------------------------------------------------------
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, docs);
    println!(
        "TinyPile: {} train / {} val tokens",
        corpus.train.len(),
        corpus.val.len()
    );

    // ---- train ------------------------------------------------------------
    let mut model = ModelState::load(&hyena::artifact(&name), seed as i32)?;
    let (b, l, v) = (
        model.manifest.batch()?,
        model.manifest.seqlen()?,
        model.manifest.vocab()?,
    );
    println!(
        "{name}: {} params, batch {b} x seq {l}",
        model.manifest.param_count
    );
    let mut batches = LmBatches::new(&corpus.train, b, l, seed).with_vocab(v);
    let report = {
        let mut tr = Trainer::new(&mut model, move || batches.next_batch());
        tr.log_every = (steps / 10).max(1);
        tr.run(steps)?
    };

    // ---- held-out eval ------------------------------------------------------
    let evals = LmBatches::eval_batches_vocab(&corpus.val, b, l, v);
    let n_eval = evals.len().min(8);
    let mut i = 0;
    let val_nll = eval_loss(
        &model,
        &mut || {
            let batch = evals[i].clone();
            i += 1;
            batch
        },
        n_eval,
    )?;
    println!(
        "val: loss {val_nll:.4}  ppl {:.2}  (train FLOPs {:.2e})",
        val_nll.exp(),
        report.total_flops.unwrap_or(0.0)
    );

    // ---- persist loss curve ---------------------------------------------------
    let mut t = Table::new(
        &format!("lm_pretrain {name}"),
        &["step", "tokens", "loss", "ppl", "elapsed_s"],
    );
    for p in &report.curve {
        t.row(vec![
            p.step.to_string(),
            p.tokens_seen.to_string(),
            format!("{:.4}", p.loss),
            format!("{:.2}", p.ppl),
            format!("{:.1}", p.elapsed_s),
        ]);
    }
    t.emit(&format!("lm_pretrain_{name}"));

    // ---- serve the trained weights ---------------------------------------------
    // The server loads its own copy of the artifact (XLA state is per
    // thread); push the trained params over in host form.
    println!("\nserving {n_req} requests (dynamic batching, 10ms deadline)…");
    let trained = model.params_host()?;
    let server = Server::start_with_params(
        hyena::artifact(&name),
        seed as i32,
        Duration::from_millis(10),
        Some(trained),
    )?;
    let tok = CharTokenizer::new();
    let prompt = tok.encode("The ");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_req)
        .map(|_| {
            server.handle.submit(GenerateRequest {
                prompt: prompt.clone(),
                max_new: 32,
                sampling: Sampling::Temperature { t: 0.8, top_k: 20 },
                deadline: None,
                trace_id: 0,
            })
        })
        .collect();
    let mut lat = Summary::new();
    let mut generated = 0usize;
    let mut sample = String::new();
    for (idx, h) in handles.into_iter().enumerate() {
        let resp = h.recv().expect("worker alive")?;
        lat.push(resp.total_time.as_secs_f64());
        generated += resp.tokens.len();
        if idx == 0 {
            sample = tok.decode(&resp.tokens);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("sample continuation: {sample:?}");
    println!(
        "serving: {} req, mean latency {:.0}ms, p99 {:.0}ms, {:.1} tok/s",
        n_req,
        lat.mean() * 1e3,
        lat.p99() * 1e3,
        generated as f64 / wall
    );
    server.stop();
    Ok(())
}
