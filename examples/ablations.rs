//! Ablations over the Hyena design choices DESIGN.md calls out
//! (paper Sec. 3.3 + App. D): sine-activation frequency ω, operator order N,
//! short conv on/off, decay window on/off (≈ ckconv), PE feature count K.
//!
//! Run on associative recall at L = 512:
//! `cargo run --release --example ablations -- [--steps 1200] [--vocab 20]`

use anyhow::Result;
use hyena::coordinator::experiment::train_and_eval;
use hyena::report::Table;
use hyena::tasks::recall::RecallTask;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

const VARIANTS: &[(&str, &str)] = &[
    ("baseline (ω=14, N=2, short, decay)", "ar_implicit_L512"),
    ("no decay window (=CKConv)", "ar_ckconv_L512"),
    ("sine ω=1", "abl_sine1"),
    ("sine ω=10", "abl_sine10"),
    ("order N=1", "abl_order1"),
    ("order N=3", "abl_order3"),
    ("no short conv", "abl_noshort"),
    ("PE features K=32", "abl_pe32"),
];

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 1200);
    let vocab = args.get_usize("vocab", 20);
    let seed = args.get_u64("seed", 0);

    let mut table = Table::new(
        "Ablations — recall accuracy (%) at L=512",
        &["variant", "accuracy", "steps/s"],
    );
    for (label, name) in VARIANTS {
        let dir = hyena::artifact(name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        let task = RecallTask::new(512, vocab, 16);
        let mut rng = Pcg::new(seed);
        let src = {
            let task = task.clone();
            move || task.sample_batch(&mut rng).to_tensors()
        };
        let (acc, rep) = train_and_eval(&dir, seed as i32, src, steps, 8, true)?;
        println!("{label:>36}: acc {:>5.1}%", 100.0 * acc);
        table.row(vec![
            label.to_string(),
            format!("{:.1}", 100.0 * acc),
            format!("{:.1}", rep.steps_per_s),
        ]);
    }
    table.emit("ablations");
    Ok(())
}
