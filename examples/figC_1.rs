//! E9 — Fig. C.1: learning multi-digit addition vs model depth.
//!
//! Paper: 1-layer Hyena learns ≤4-digit addition; longer numbers need
//! deeper models. Testbed: depth ∈ {1,2,3} × digits ∈ {2,3,4}; metric is
//! exact-digit accuracy on the masked result positions.
//!
//! Run: `cargo run --release --example figC_1 -- [--steps 1500]`

use anyhow::Result;
use hyena::coordinator::experiment::train_and_eval;
use hyena::report::Table;
use hyena::tasks::arithmetic::ArithmeticTask;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 1500);
    let seed = args.get_u64("seed", 0);

    let mut table = Table::new(
        "Fig C.1 — addition: result-digit accuracy (%) by depth and digits",
        &["depth", "digits", "accuracy"],
    );
    for depth in [1usize, 2, 3] {
        let name = format!("arith_d{depth}");
        let dir = hyena::artifact(&name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        for digits in [2usize, 3, 4] {
            let task = ArithmeticTask::new(digits, 32, 32);
            let mut rng = Pcg::new(seed);
            let src = {
                let task = task.clone();
                move || task.sample_batch(&mut rng).to_tensors()
            };
            let (acc, _) = train_and_eval(&dir, seed as i32, src, steps, 6, true)?;
            println!("depth {depth} digits {digits}: acc {:.1}%", 100.0 * acc);
            table.row(vec![
                depth.to_string(),
                digits.to_string(),
                format!("{:.1}", 100.0 * acc),
            ]);
        }
    }
    table.emit("figC_1");
    Ok(())
}
