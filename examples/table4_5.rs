//! E5 — Tab. 4.5/4.6: zero-shot vs few-shot (3) accuracy of a pretrained
//! attention-free LM (SuperGLUE stand-in; substitution in DESIGN.md §3).
//!
//! Protocol mirrors the paper: the *same* pretrained model is scored
//! zero-shot and 3-shot by option log-likelihood on multiple-choice
//! episodes; the paper's claim to reproduce is the characteristic few-shot
//! lift of Hyena (Tab 4.6 avg 49.3 vs zero-shot 41.5) — demonstrations in
//! context improve the attention-free model.
//!
//! Episodes are synthetic QA over the model's own training distribution:
//!   recall-QA   "<kv pairs> <key> →  which value?" (in-context ability)
//!   majority-QA "<symbols> → which symbol dominated?"
//!   copy-QA     "<token> ... → which token opened the line?"
//!
//! Run: `cargo run --release --example table4_5 -- [--train-steps 600] [--episodes 60]`

use anyhow::Result;
use hyena::coordinator::experiment::train_artifact;
use hyena::coordinator::fewshot::{eval_episodes, Episode};
use hyena::report::Table;
use hyena::tasks::recall::RecallTask;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

/// recall-QA episode generator over `vocab` tokens with `pairs` kv pairs.
fn recall_episode(vocab: usize, pairs: usize) -> impl FnMut(&mut Pcg) -> Episode {
    move |rng| {
        let n_keys = vocab / 2;
        let dict: Vec<i32> = (0..n_keys)
            .map(|_| (n_keys + rng.usize_below(vocab - n_keys)) as i32)
            .collect();
        let mut prompt = Vec::new();
        let mut appeared = Vec::new();
        for _ in 0..pairs {
            let k = rng.usize_below(n_keys);
            appeared.push(k);
            prompt.push(k as i32);
            prompt.push(dict[k]);
        }
        let q = appeared[rng.usize_below(appeared.len())];
        prompt.push(q as i32);
        // options: correct value + 3 distractor values
        let mut options = vec![vec![dict[q]]];
        for _ in 0..3 {
            let mut d = dict[rng.usize_below(n_keys)];
            if d == dict[q] {
                d = (n_keys as i32) + ((d - n_keys as i32 + 1) % (vocab - n_keys) as i32);
            }
            options.push(vec![d]);
        }
        // shuffle options, track correct index
        let mut order: Vec<usize> = (0..options.len()).collect();
        rng.shuffle(&mut order);
        let correct = order.iter().position(|&i| i == 0).unwrap();
        let options = order.into_iter().map(|i| options[i].clone()).collect();
        Episode { prompt, options, correct }
    }
}

/// majority-QA: which symbol dominates the window?
fn majority_episode(vocab: usize, len: usize) -> impl FnMut(&mut Pcg) -> Episode {
    move |rng| {
        let maj = rng.usize_below(vocab) as i32;
        let mut prompt: Vec<i32> = (0..len)
            .map(|_| {
                if rng.f32() < 0.55 {
                    maj
                } else {
                    rng.usize_below(vocab) as i32
                }
            })
            .collect();
        prompt.push(0);
        let mut distract = (maj + 1) % vocab as i32;
        if distract == maj {
            distract = (maj + 2) % vocab as i32;
        }
        let swap = rng.f32() < 0.5;
        let options = if swap {
            vec![vec![distract], vec![maj]]
        } else {
            vec![vec![maj], vec![distract]]
        };
        Episode { prompt, options, correct: usize::from(swap) }
    }
}

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let train_steps = args.get_u64("train-steps", 600);
    let episodes = args.get_usize("episodes", 60);
    let model_name = args.get_or("model", "op_hyena_L1024").to_string();
    let dir = hyena::artifact(&model_name);

    // Pretrain on the recall distribution (the testbed "pretraining corpus").
    let l = hyena::runtime::Manifest::load(&dir)?.seqlen()?;
    let task = RecallTask::new(l, 30, 8);
    let mut rng = Pcg::new(0);
    let src = {
        let task = task.clone();
        move || task.sample_batch(&mut rng).to_tensors()
    };
    println!("pretraining {model_name} for {train_steps} steps…");
    let (model, _) = train_artifact(&dir, 0, src, train_steps, true)?;

    let mut table = Table::new(
        "Tab 4.5/4.6 — synthetic-QA accuracy (%), zero-shot vs 3-shot",
        &["task", "0-shot", "3-shot", "lift"],
    );
    let mut eval_rng = Pcg::new(42);
    let tasks: Vec<(&str, Box<dyn FnMut(&mut Pcg) -> Episode>)> = vec![
        ("recall-QA", Box::new(recall_episode(30, 8))),
        ("majority-QA", Box::new(majority_episode(10, 24))),
    ];
    for (label, mut mk) in tasks {
        let zero = eval_episodes(&model, &mut mk, 0, episodes, &mut eval_rng)?;
        let few = eval_episodes(&model, &mut mk, 3, episodes, &mut eval_rng)?;
        println!(
            "{label:>12}: 0-shot {:.1}%  3-shot {:.1}%  (lift {:+.1})",
            100.0 * zero,
            100.0 * few,
            100.0 * (few - zero)
        );
        table.row(vec![
            label.to_string(),
            format!("{:.1}", 100.0 * zero),
            format!("{:.1}", 100.0 * few),
            format!("{:+.1}", 100.0 * (few - zero)),
        ]);
    }
    table.emit("table4_5");
    Ok(())
}
