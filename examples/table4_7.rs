//! E7 — Tab. 4.7: image classification, ViT vs Hyena-ViT drop-in.
//!
//! Paper: swapping attention for Hyena in ViT-B matches top-1 on
//! ImageNet-1k (78.5 both) with positional embeddings removed for Hyena.
//! Testbed: Synthetic-10 pattern dataset (DESIGN.md §3), same drop-in
//! protocol (attention keeps pos-emb, Hyena drops it). Claim to reproduce:
//! accuracy(hyena-vit) ≈ accuracy(vit), both ≫ chance (10%).
//!
//! Run: `cargo run --release --example table4_7 -- [--steps 600] [--eval 20]`

use anyhow::Result;
use hyena::data::images::ImageTask;
use hyena::metrics::class_accuracy;
use hyena::report::Table;
use hyena::runtime::ModelState;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

const MODELS: &[(&str, &str)] = &[("ViT", "img_vit"), ("Hyena-ViT", "img_hyena")];

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let steps = args.get_u64("steps", 600);
    let eval_batches = args.get_usize("eval", 20);
    let seed = args.get_u64("seed", 0);

    let mut table = Table::new(
        "Tab 4.7 — Synthetic-10 top-1 accuracy",
        &["model", "params", "patch", "seq len", "acc (%)"],
    );
    for (label, name) in MODELS {
        let dir = hyena::artifact(name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skip {name}: artifact missing");
            continue;
        }
        let mut model = ModelState::load(&dir, seed as i32)?;
        let size = model.manifest.cfg_usize("image")?;
        let batch = model.manifest.batch()?;
        let task = ImageTask::new(size, batch);
        let mut rng = Pcg::new(seed);

        // train
        let mut last = f32::NAN;
        for s in 0..steps {
            let b = task.sample_batch(&mut rng);
            last = model.train_step(&b)?;
            if s % (steps / 5).max(1) == 0 {
                println!("  {label} step {s}: loss {last:.3}");
            }
        }

        // eval on fresh draws
        let mut correct_frac = 0.0;
        let mut eval_rng = Pcg::new(seed + 1000);
        for _ in 0..eval_batches {
            let b = task.sample_batch(&mut eval_rng);
            let logits = model.forward(&b[..1])?;
            let classes = *logits.shape().last().unwrap();
            correct_frac += class_accuracy(
                logits.as_f32()?,
                classes,
                b[1].as_i32()?,
            );
        }
        let acc = correct_frac / eval_batches as f64;
        println!(
            "{label:>10}: {} params, final loss {last:.3}, acc {:.1}%",
            model.manifest.param_count,
            100.0 * acc
        );
        table.row(vec![
            label.to_string(),
            model.manifest.param_count.to_string(),
            model.manifest.cfg_usize("patch")?.to_string(),
            model.manifest.seqlen()?.to_string(),
            format!("{:.1}", 100.0 * acc),
        ]);
    }
    table.emit("table4_7");
    Ok(())
}
