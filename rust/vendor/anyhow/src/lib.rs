//! Vendored minimal substitute for the `anyhow` crate.
//!
//! The container image has no crates.io registry, so the workspace vendors
//! the slice of `anyhow` the codebase actually uses: [`Error`] (a message +
//! optional cause chain), [`Result`], the [`anyhow!`]/[`bail!`] macros and
//! the [`Context`] extension trait for `Result` and `Option`. Semantics
//! mirror the real crate closely enough that swapping the path dependency
//! for the registry crate is a one-line `Cargo.toml` change.

use std::fmt;

/// A string-message error with an optional chained cause.
///
/// Like `anyhow::Error`, `{}` prints the outermost message and `{:#}`
/// prints the whole chain separated by `": "`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message plus chained causes, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Any std error converts via `?`. Like real anyhow, `Error` itself does NOT
// implement `std::error::Error`, which is what makes this blanket impl legal.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain: Vec<String> = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(c) = cur {
            chain.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("chain is non-empty")
    }
}

/// Extension trait: attach context to the error branch of `Result`/`Option`.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-computed context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("opening manifest");
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: missing");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let r = v.with_context(|| format!("missing {}", "key"));
        assert_eq!(format!("{}", r.unwrap_err()), "missing key");
    }

    #[test]
    fn bail_and_anyhow() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 7);
            }
            Err(anyhow!("other"))
        }
        assert_eq!(format!("{}", inner(true).unwrap_err()), "boom 7");
        assert_eq!(format!("{}", inner(false).unwrap_err()), "other");
    }
}
