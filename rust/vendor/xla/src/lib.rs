//! Vendored API-surface stub of the `xla` (PJRT bindings) crate.
//!
//! The container image does not ship the real PJRT/XLA native libraries, so
//! this crate keeps the workspace compiling and testable everywhere:
//!
//! * [`Literal`] is a **real, fully functional** host-side tensor container
//!   (f32/i32 arrays and tuples with shapes) — everything the runtime's
//!   host↔device boundary code needs works for real.
//! * The PJRT pieces ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`HloModuleProto`]) are present with the right signatures but return
//!   [`Error::Unavailable`] at the first point that would require the native
//!   runtime. Callers surface that error cleanly and fall back to the
//!   native Rust backend (`hyena::backend::NativeBackend`).
//!
//! Swapping in the real bindings is a one-line change in the workspace
//! `Cargo.toml` (point the `xla` path dependency at the real crate); no
//! source edits are needed because the call surface matches.

use std::fmt;
use std::path::Path;

/// Stub error: either "this build has no PJRT" or a host-side shape error.
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation needs the native XLA/PJRT libraries, which this
    /// vendored stub does not provide.
    Unavailable(String),
    /// Host-side literal misuse (bad reshape, dtype mismatch, ...).
    Literal(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT runtime unavailable (vendored xla stub; use the \
                 native backend, e.g. --backend native, or link the real xla crate)"
            ),
            Error::Literal(msg) => write!(f, "literal error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types the runtime exchanges with artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

/// Shape of an array literal: element type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Sealed host-element trait mapping Rust scalars to [`ElementType`].
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn store(data: &[Self]) -> Storage;
    fn unstore(s: &Storage) -> Option<Vec<Self>>;
}

/// Backing store of an array literal.
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }
    fn ty(&self) -> ElementType {
        match self {
            Storage::F32(_) => ElementType::F32,
            Storage::I32(_) => ElementType::S32,
        }
    }
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn store(data: &[f32]) -> Storage {
        Storage::F32(data.to_vec())
    }
    fn unstore(s: &Storage) -> Option<Vec<f32>> {
        match s {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn store(data: &[i32]) -> Storage {
        Storage::I32(data.to_vec())
    }
    fn unstore(s: &Storage) -> Option<Vec<i32>> {
        match s {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host tensor value: a shaped array or a tuple of literals.
///
/// Fully functional (this is plain host data, no PJRT involved).
#[derive(Debug, Clone)]
pub enum Literal {
    Array { shape: ArrayShape, data: Storage },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            shape: ArrayShape { ty: T::TY, dims: vec![data.len() as i64] },
            data: T::store(data),
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { shape, data } => {
                let want: i64 = dims.iter().product();
                if want as usize != data.len() {
                    return Err(Error::Literal(format!(
                        "cannot reshape {} elements to {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::Array {
                    shape: ArrayShape { ty: shape.ty, dims: dims.to_vec() },
                    data: data.clone(),
                })
            }
            Literal::Tuple(_) => Err(Error::Literal("cannot reshape a tuple".into())),
        }
    }

    /// Array shape accessor (errors on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { shape, .. } => Ok(shape.clone()),
            Literal::Tuple(_) => Err(Error::Literal("tuple literal has no array shape".into())),
        }
    }

    /// Copy elements out as a host `Vec` (dtype must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::unstore(data).ok_or_else(|| {
                Error::Literal(format!("dtype mismatch: literal is {:?}", data.ty()))
            }),
            Literal::Tuple(_) => Err(Error::Literal("cannot read a tuple as a vec".into())),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(items) => Ok(items),
            Literal::Array { .. } => Err(Error::Literal("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: parsing requires the native XLA text parser).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!("parsing HLO text {}", path.as_ref().display()))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // Unreachable in the stub (no HloModuleProto can be constructed),
        // but keeps the call-site signature identical to the real crate.
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("creating PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an XLA computation")
    }
}

/// Compiled executable handle (stub: can never be constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a PJRT executable")
    }
}

/// Device buffer handle (stub: can never be constructed).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("reading a device buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[7]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let items = t.to_tuple().unwrap();
        assert_eq!(items.len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn pjrt_surface_errors_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("native backend"));
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}
