//! PJRT client wrapper: load HLO-text artifacts, compile once, execute.
//!
//! The `xla` crate's handles are intentionally `!Send`/`!Sync` (they wrap
//! `Rc` + raw PJRT pointers), so the runtime is **per-thread**: each thread
//! that touches XLA gets its own client + executable cache via
//! [`runtime()`], and nothing XLA-owned ever crosses a thread boundary.
//! Cross-thread coordination (the server) exchanges plain host data only.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use crate::runtime::tensor::Tensor;

/// Thread-local PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

thread_local! {
    static RT: RefCell<Option<Rc<Runtime>>> = const { RefCell::new(None) };
}

/// The calling thread's runtime, or an error when the PJRT client cannot be
/// created (e.g. this build links the vendored `xla` stub). Only successful
/// initializations are cached.
pub fn try_runtime() -> Result<Rc<Runtime>> {
    RT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(Rc::new(Runtime::new()?));
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

/// The calling thread's runtime (created on first use); panics when PJRT is
/// unavailable — prefer [`try_runtime`] on paths that can fall back to the
/// native backend.
pub fn runtime() -> Rc<Runtime> {
    try_runtime().expect("PJRT CPU client init failed")
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by canonical path).
    pub fn load(&self, path: &Path) -> Result<Rc<Executable>> {
        let key = path
            .canonicalize()
            .unwrap_or_else(|_| path.to_path_buf())
            .display()
            .to_string();
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let exe = Rc::new(Executable { exe, name: key.clone() });
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (observability/test hook).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// A compiled artifact. All artifacts are lowered with `return_tuple=True`,
/// so execution yields a single tuple literal which we decompose.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    fn collect(&self, mut out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        let replica = out
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| anyhow!("no output buffers from {}", self.name))?;
        let lit = replica.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute on host literals, returning the decomposed output tuple.
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        self.collect(out)
    }

    /// Execute on borrowed literals (the trainer hot loop: persistent param
    /// literals are passed by reference, no re-conversion).
    pub fn run_literals_ref(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        self.collect(out)
    }

    /// Execute on host tensors (converted to literals at the boundary).
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits = args
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let outs = self.run_literals(&lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }
}
