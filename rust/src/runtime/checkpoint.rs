//! Checkpointing: persist/restore model parameters (and optimizer step) to
//! a single binary file, validated against the artifact manifest.
//!
//! Format (little-endian):
//!   magic "HYCK" | u32 version | u64 step | u32 n_tensors
//!   per tensor: u32 name_len | name bytes | u8 dtype (0=f32, 1=i32)
//!               u32 ndim | u64 dims… | raw data bytes
//!
//! Tensor order and names must match the manifest exactly — a checkpoint
//! from a different config is rejected rather than silently misloaded.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::{DType, Tensor};

const MAGIC: &[u8; 4] = b"HYCK";
const VERSION: u32 = 1;

pub struct Checkpoint {
    pub step: u64,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            let code: u8 = match t.dtype() {
                DType::F32 => 0,
                DType::I32 => 1,
            };
            w.write_all(&[code])?;
            w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for x in data {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    for x in data {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a hyena checkpoint (bad magic)");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = read_u64(&mut r)?;
        let n = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut r)? as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf-8")?;
            let mut code = [0u8; 1];
            r.read_exact(&mut code)?;
            let ndim = read_u32(&mut r)? as usize;
            if ndim > 16 {
                bail!("implausible rank {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            let numel: usize = shape.iter().product();
            let t = match code[0] {
                0 => {
                    let mut data = vec![0f32; numel];
                    let mut buf = [0u8; 4];
                    for x in data.iter_mut() {
                        r.read_exact(&mut buf)?;
                        *x = f32::from_le_bytes(buf);
                    }
                    Tensor::F32 { shape, data }
                }
                1 => {
                    let mut data = vec![0i32; numel];
                    let mut buf = [0u8; 4];
                    for x in data.iter_mut() {
                        r.read_exact(&mut buf)?;
                        *x = i32::from_le_bytes(buf);
                    }
                    Tensor::I32 { shape, data }
                }
                c => bail!("unknown dtype code {c}"),
            };
            tensors.push((name, t));
        }
        Ok(Checkpoint { step, tensors })
    }

    /// Validate names/shapes against a manifest and return tensors in
    /// manifest order, ready for `ModelState::set_params`.
    pub fn into_params(self, manifest: &Manifest) -> Result<Vec<Tensor>> {
        if self.tensors.len() != manifest.params.len() {
            bail!(
                "checkpoint has {} tensors, manifest wants {}",
                self.tensors.len(),
                manifest.params.len()
            );
        }
        let mut out = Vec::with_capacity(manifest.params.len());
        let map: std::collections::HashMap<_, _> = self.tensors.into_iter().collect();
        for spec in &manifest.params {
            let t = map
                .get(&spec.name)
                .with_context(|| format!("checkpoint missing param {}", spec.name))?;
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "param {}: checkpoint shape {:?} != manifest {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
            out.push(t.clone());
        }
        Ok(out)
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("hyena_ckpt_{name}.bin"))
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 42,
            tensors: vec![
                (
                    "a.w".into(),
                    Tensor::from_f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]).unwrap(),
                ),
                ("b.ids".into(), Tensor::from_i32(&[4], vec![7, -8, 9, 0]).unwrap()),
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let p = tmp("roundtrip");
        sample().save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].0, "a.w");
        assert_eq!(back.tensors[0].1.as_f32().unwrap()[5], -6.25);
        assert_eq!(back.tensors[1].1.as_i32().unwrap(), &[7, -8, 9, 0]);
        assert_eq!(back.tensors[0].1.shape(), &[2, 3]);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let p = tmp("trunc");
        sample().save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn into_params_validates_names_and_shapes() {
        use crate::runtime::manifest::ParamSpec;
        use crate::runtime::tensor::DType;
        let man = Manifest {
            name: "t".into(),
            dir: std::path::PathBuf::new(),
            params: vec![
                ParamSpec { name: "a.w".into(), shape: vec![2, 3], dtype: DType::F32 },
                ParamSpec { name: "b.ids".into(), shape: vec![4], dtype: DType::I32 },
            ],
            config: crate::util::json::Json::Null,
            param_count: 10,
            flops_per_step: None,
            flops_per_token: None,
            has_train_step: false,
            has_filters: false,
            filter_params: vec![],
        };
        let params = sample().into_params(&man).unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].shape(), &[2, 3]); // manifest order preserved

        let bad_man = Manifest {
            params: vec![ParamSpec {
                name: "a.w".into(),
                shape: vec![3, 2], // wrong shape
                dtype: DType::F32,
            }],
            ..man
        };
        assert!(sample().into_params(&bad_man).is_err());
    }
}
