//! `ModelState`: owns the parameter/optimizer literals of one artifact and
//! drives its init / train_step / forward executables.

use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::client::{try_runtime, Executable};
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::Tensor;

pub struct ModelState {
    pub manifest: Manifest,
    /// Parameter literals, in manifest (sorted-key) order.
    params: Vec<xla::Literal>,
    /// AdamW first/second moments (allocated when training starts).
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    pub step: u64,
    init_exe: Rc<Executable>,
    forward_exe: Rc<Executable>,
    train_exe: Option<Rc<Executable>>,
    filters_exe: Option<Rc<Executable>>,
}

impl ModelState {
    /// Load an artifact directory, compile its executables, and initialize
    /// parameters from `seed` (inside XLA — fully deterministic).
    pub fn load(dir: &Path, seed: i32) -> Result<ModelState> {
        let manifest = Manifest::load(dir)?;
        let rt = try_runtime()?;
        let init_exe = rt.load(&manifest.hlo_path("init"))?;
        let forward_exe = rt.load(&manifest.hlo_path("forward"))?;
        let train_exe = if manifest.has_train_step {
            Some(rt.load(&manifest.hlo_path("train_step"))?)
        } else {
            None
        };
        let filters_exe = if manifest.has_filters {
            Some(rt.load(&manifest.hlo_path("filters"))?)
        } else {
            None
        };

        let seed_t = Tensor::from_i32(&[], vec![seed])?;
        let params = init_exe
            .run_literals(&[seed_t.to_literal()?])
            .context("running init")?;
        if params.len() != manifest.params.len() {
            bail!(
                "init returned {} tensors, manifest lists {}",
                params.len(),
                manifest.params.len()
            );
        }

        Ok(ModelState {
            manifest,
            params,
            m: Vec::new(),
            v: Vec::new(),
            step: 0,
            init_exe,
            forward_exe,
            train_exe,
            filters_exe,
        })
    }

    /// Re-initialize parameters (fresh seed) and reset the optimizer.
    pub fn reinit(&mut self, seed: i32) -> Result<()> {
        let seed_t = Tensor::from_i32(&[], vec![seed])?;
        self.params = self.init_exe.run_literals(&[seed_t.to_literal()?])?;
        self.m.clear();
        self.v.clear();
        self.step = 0;
        Ok(())
    }

    fn ensure_opt_state(&mut self) -> Result<()> {
        if !self.m.is_empty() {
            return Ok(());
        }
        for spec in &self.manifest.params {
            let z = Tensor::zeros(spec.dtype, &spec.shape);
            self.m.push(z.to_literal()?);
            self.v.push(z.to_literal()?);
        }
        Ok(())
    }

    /// One optimizer step on a host batch. LM batches are
    /// `[tokens, targets, mask]`; image batches `[images, labels]`.
    /// Returns the scalar loss.
    pub fn train_step(&mut self, batch: &[Tensor]) -> Result<f32> {
        let exe = self
            .train_exe
            .clone()
            .ok_or_else(|| anyhow!("{} has no train_step artifact", self.manifest.name))?;
        self.ensure_opt_state()?;

        let step_t = Tensor::from_f32(&[], vec![self.step as f32])?.to_literal()?;
        let batch_lits = batch
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;

        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(3 * self.params.len() + 1 + batch.len());
        args.extend(self.params.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        args.push(&step_t);
        args.extend(batch_lits.iter());

        let mut outs = exe.run_literals_ref(&args)?;
        let n = self.params.len();
        if outs.len() != 3 * n + 1 {
            bail!("train_step returned {} outputs, want {}", outs.len(), 3 * n + 1);
        }
        let loss_lit = outs.pop().unwrap();
        let loss = Tensor::from_literal(&loss_lit)?.scalar_f32()?;
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;
        self.step += 1;
        Ok(loss)
    }

    /// Forward pass: `inputs` are the data tensors (tokens or images).
    /// Returns logits as a host tensor.
    pub fn forward(&self, inputs: &[Tensor]) -> Result<Tensor> {
        let input_lits = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + inputs.len());
        args.extend(self.params.iter());
        args.extend(input_lits.iter());
        let mut outs = self.forward_exe.run_literals_ref(&args)?;
        if outs.is_empty() {
            bail!("forward returned no outputs");
        }
        Tensor::from_literal(&outs.remove(0))
    }

    /// Materialize the block-0 implicit filters `h: (N, D, L)` (Fig. D.5).
    pub fn dump_filters(&self) -> Result<Tensor> {
        let exe = self
            .filters_exe
            .clone()
            .ok_or_else(|| anyhow!("{} has no filters artifact", self.manifest.name))?;
        // Only the block-0 filter params feed this artifact (manifest order).
        let args: Vec<&xla::Literal> = self
            .manifest
            .filter_params
            .iter()
            .map(|name| {
                self.manifest
                    .params
                    .iter()
                    .position(|p| &p.name == name)
                    .map(|i| &self.params[i])
                    .ok_or_else(|| anyhow!("filter param {name} not in manifest"))
            })
            .collect::<Result<_>>()?;
        let mut outs = exe.run_literals_ref(&args)?;
        Tensor::from_literal(&outs.remove(0))
    }

    /// Copy parameters out to host tensors (checkpointing).
    pub fn params_host(&self) -> Result<Vec<Tensor>> {
        self.params.iter().map(Tensor::from_literal).collect()
    }

    /// Restore parameters from host tensors (ordering must match manifest).
    pub fn set_params(&mut self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.manifest.params.len() {
            bail!("param count mismatch");
        }
        self.params = tensors
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}

/// The PJRT engine behind the [`crate::backend::Backend`] trait: thin
/// delegation to the inherent methods above.
impl crate::backend::Backend for ModelState {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }
    fn step(&self) -> u64 {
        self.step
    }
    fn set_step(&mut self, step: u64) {
        self.step = step;
    }
    fn reinit(&mut self, seed: i32) -> Result<()> {
        ModelState::reinit(self, seed)
    }
    fn train_step(&mut self, batch: &[Tensor]) -> Result<f32> {
        ModelState::train_step(self, batch)
    }
    fn forward(&self, inputs: &[Tensor]) -> Result<Tensor> {
        ModelState::forward(self, inputs)
    }
    fn dump_filters(&self) -> Result<Tensor> {
        ModelState::dump_filters(self)
    }
    fn params_host(&self) -> Result<Vec<Tensor>> {
        ModelState::params_host(self)
    }
    fn set_params(&mut self, tensors: &[Tensor]) -> Result<()> {
        ModelState::set_params(self, tensors)
    }
}
