//! Host tensors and conversion to/from `xla::Literal`.

use anyhow::{bail, Context, Result};

/// Element type of an artifact tensor (the subset the models use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_name(name: &str) -> Result<DType> {
        Ok(match name {
            "float32" | "f32" => DType::F32,
            "int32" | "i32" => DType::I32,
            other => bail!("unsupported dtype in manifest: {other}"),
        })
    }
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A host-side tensor: shape + f32 or i32 storage.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { shape: shape.to_vec(), data: vec![0; n] },
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor::F32 { shape: shape.to_vec(), data })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor::I32 { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar f32 view (shape [] or [1]).
    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("not a scalar: {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Convert into an `xla::Literal` with the right shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        lit.reshape(&dims)
            .with_context(|| format!("reshape literal to {:?}", self.shape()))
    }

    /// Read an `xla::Literal` back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shapes() {
        let t = Tensor::zeros(DType::F32, &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.as_f32().unwrap(), &[0.0; 6]);
    }

    #[test]
    fn from_rejects_bad_len() {
        assert!(Tensor::from_f32(&[2, 2], vec![1.0; 3]).is_err());
        assert!(Tensor::from_i32(&[2], vec![1, 2]).is_ok());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::from_name("float32").unwrap(), DType::F32);
        assert_eq!(DType::from_name("int32").unwrap(), DType::I32);
        assert!(DType::from_name("float64").is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 2]);
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::from_i32(&[3], vec![5, -6, 7]).unwrap();
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[5, -6, 7]);
    }

    #[test]
    fn scalar_f32_checks() {
        let t = Tensor::from_f32(&[], vec![2.5]).unwrap();
        assert_eq!(t.scalar_f32().unwrap(), 2.5);
        let t2 = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
        assert!(t2.scalar_f32().is_err());
    }
}
