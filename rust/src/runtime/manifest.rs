//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (DESIGN.md §2 "Artifact contract").

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

/// Shape+dtype+name of one parameter tensor, in flattening order.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `manifest.json` for one artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub dir: PathBuf,
    pub params: Vec<ParamSpec>,
    pub config: Json,
    pub param_count: usize,
    pub flops_per_step: Option<f64>,
    pub flops_per_token: Option<f64>,
    pub has_train_step: bool,
    pub has_filters: bool,
    /// Param names (flattening order) consumed by the filters artifact.
    pub filter_params: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {}", man_path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", man_path.display()))?;

        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing params array"))?
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("param missing name"))?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("param {name} missing shape"))?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?;
                let dtype = DType::from_name(
                    p.get("dtype").and_then(Json::as_str).unwrap_or("float32"),
                )?;
                Ok(ParamSpec { name, shape, dtype })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            dir: dir.to_path_buf(),
            param_count: j.get("param_count").and_then(Json::as_usize).unwrap_or(0),
            flops_per_step: j.get("flops_per_step").and_then(Json::as_f64),
            flops_per_token: j.get("flops_per_token").and_then(Json::as_f64),
            has_train_step: j
                .get("has_train_step")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            has_filters: j.get("has_filters").and_then(Json::as_bool).unwrap_or(false),
            filter_params: j
                .get("filter_params")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
            config: j.get("config").cloned().unwrap_or(Json::Null),
            params,
        })
    }

    // -- config accessors -----------------------------------------------------
    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("config missing {key}"))
    }
    pub fn cfg_str(&self, key: &str) -> Option<&str> {
        self.config.get(key).and_then(Json::as_str)
    }
    pub fn batch(&self) -> Result<usize> {
        self.cfg_usize("batch")
    }
    pub fn seqlen(&self) -> Result<usize> {
        self.cfg_usize("seqlen")
    }
    pub fn vocab(&self) -> Result<usize> {
        self.cfg_usize("vocab")
    }
    pub fn family(&self) -> &str {
        self.cfg_str("family").unwrap_or("lm")
    }

    pub fn hlo_path(&self, which: &str) -> PathBuf {
        self.dir.join(format!("{which}.hlo.txt"))
    }

    /// Total parameter elements per the specs (cross-check with param_count).
    pub fn numel(&self) -> usize {
        self.params.iter().map(ParamSpec::numel).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn loads_minimal_manifest() {
        let dir = std::env::temp_dir().join("hyena_test_manifest");
        write_manifest(
            &dir,
            r#"{"name":"t","config":{"batch":4,"seqlen":16,"vocab":32,"family":"lm"},
               "params":[{"name":"a","shape":[2,3],"dtype":"float32"},
                          {"name":"b","shape":[5],"dtype":"int32"}],
               "param_count":11,"has_train_step":true,"has_filters":false,
               "flops_per_step":123.5}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].numel(), 6);
        assert_eq!(m.params[1].dtype, DType::I32);
        assert_eq!(m.numel(), 11);
        assert_eq!(m.batch().unwrap(), 4);
        assert_eq!(m.seqlen().unwrap(), 16);
        assert!(m.has_train_step);
        assert_eq!(m.flops_per_step, Some(123.5));
        assert!(m.hlo_path("init").ends_with("init.hlo.txt"));
    }

    #[test]
    fn missing_file_errors() {
        let dir = std::env::temp_dir().join("hyena_test_missing");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn bad_dtype_errors() {
        let dir = std::env::temp_dir().join("hyena_test_baddtype");
        write_manifest(
            &dir,
            r#"{"name":"t","config":{},"params":[{"name":"a","shape":[1],"dtype":"float64"}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }
}
