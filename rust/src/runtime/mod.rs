//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from the
//! Rust hot path. Python never runs at request time (DESIGN.md §2).
pub mod checkpoint;
pub mod client;
pub mod manifest;
pub mod model;
pub mod tensor;

pub use client::{runtime, Executable, Runtime};
pub use manifest::{Manifest, ParamSpec};
pub use model::ModelState;
pub use tensor::{DType, Tensor};
