//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from the
//! Rust hot path.
//!
//! This is the **pjrt** implementation of [`crate::backend::Backend`]. On
//! this path Python never runs at request time (DESIGN.md §2) — artifacts
//! are compiled ahead of time and only PJRT executes. When the PJRT runtime
//! itself is absent (e.g. the vendored `xla` stub is linked), loading
//! returns a clean error and callers fall back to the dependency-free
//! native backend ([`crate::backend::native`]).
pub mod checkpoint;
pub mod client;
pub mod manifest;
pub mod model;
pub mod tensor;

pub use client::{runtime, try_runtime, Executable, Runtime};
pub use manifest::{Manifest, ParamSpec};
pub use model::ModelState;
pub use tensor::{DType, Tensor};
