//! Byte-pair encoding tokenizer substrate (the paper's models use the GPT2
//! BPE tokenizer; this is a from-scratch trainable equivalent for corpora
//! generated in-repo).
//!
//! Training: greedy merge of the most frequent adjacent pair, word-internal
//! only (words split on whitespace; whitespace is re-attached to the
//! following word GPT2-style via a leading marker). Deterministic given the
//! corpus (ties break lexicographically).

use std::collections::HashMap;

use crate::tokenizer::CharTokenizer;

/// Marker prepended to word-initial tokens (stand-in for GPT2's 'Ġ').
const WORD_MARK: char = '\u{1}';

#[derive(Debug, Clone)]
pub struct BpeTokenizer {
    /// Vocabulary: token string → id. Base vocab = single chars.
    vocab: HashMap<String, i32>,
    /// Reverse map for decode.
    rev: Vec<String>,
    /// Learned merges in priority order: (left, right) → merged.
    merges: Vec<(String, String)>,
}

impl BpeTokenizer {
    /// Train on `text` until the vocabulary reaches `vocab_size` (or no pair
    /// occurs at least twice).
    pub fn train(text: &str, vocab_size: usize) -> BpeTokenizer {
        // Base vocabulary: every char seen + the word marker.
        let mut vocab: HashMap<String, i32> = HashMap::new();
        let mut rev: Vec<String> = Vec::new();
        let mut add = |s: String, vocab: &mut HashMap<String, i32>, rev: &mut Vec<String>| {
            if !vocab.contains_key(&s) {
                vocab.insert(s.clone(), rev.len() as i32);
                rev.push(s);
            }
        };
        add(WORD_MARK.to_string(), &mut vocab, &mut rev);
        for c in text.chars() {
            if !c.is_whitespace() {
                add(c.to_string(), &mut vocab, &mut rev);
            }
        }

        // Word frequency table, words as symbol sequences.
        let mut words: HashMap<Vec<String>, usize> = HashMap::new();
        for w in text.split_whitespace() {
            let mut syms: Vec<String> = vec![WORD_MARK.to_string()];
            syms.extend(w.chars().map(|c| c.to_string()));
            *words.entry(syms).or_insert(0) += 1;
        }

        let mut merges = Vec::new();
        while rev.len() < vocab_size {
            // Count adjacent pairs.
            let mut pairs: HashMap<(String, String), usize> = HashMap::new();
            for (syms, &cnt) in &words {
                for win in syms.windows(2) {
                    *pairs
                        .entry((win[0].clone(), win[1].clone()))
                        .or_insert(0) += cnt;
                }
            }
            let Some(((l, r), best)) = pairs
                .into_iter()
                .max_by(|a, b| a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0)))
            else {
                break;
            };
            if best < 2 {
                break;
            }
            let merged = format!("{l}{r}");
            add(merged.clone(), &mut vocab, &mut rev);
            merges.push((l.clone(), r.clone()));
            // Apply the merge to every word.
            let mut new_words: HashMap<Vec<String>, usize> = HashMap::new();
            for (syms, cnt) in words {
                let mut out = Vec::with_capacity(syms.len());
                let mut i = 0;
                while i < syms.len() {
                    if i + 1 < syms.len() && syms[i] == l && syms[i + 1] == r {
                        out.push(merged.clone());
                        i += 2;
                    } else {
                        out.push(syms[i].clone());
                        i += 1;
                    }
                }
                *new_words.entry(out).or_insert(0) += cnt;
            }
            words = new_words;
        }
        BpeTokenizer { vocab, rev, merges }
    }

    pub fn vocab_size(&self) -> usize {
        self.rev.len()
    }

    /// Encode text: split on whitespace, apply merges in training order.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for w in text.split_whitespace() {
            let mut syms: Vec<String> = vec![WORD_MARK.to_string()];
            syms.extend(w.chars().map(|c| c.to_string()));
            for (l, r) in &self.merges {
                let mut merged_syms = Vec::with_capacity(syms.len());
                let mut i = 0;
                while i < syms.len() {
                    if i + 1 < syms.len() && &syms[i] == l && &syms[i + 1] == r {
                        merged_syms.push(format!("{l}{r}"));
                        i += 2;
                    } else {
                        merged_syms.push(syms[i].clone());
                        i += 1;
                    }
                }
                syms = merged_syms;
            }
            for s in syms {
                match self.vocab.get(&s) {
                    Some(&id) => out.push(id),
                    None => {
                        // Unknown char: fall back to char-level pieces.
                        for c in s.chars() {
                            if let Some(&id) = self.vocab.get(&c.to_string()) {
                                out.push(id);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Decode ids back to text (word marker → leading space).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            let Some(tok) = self.rev.get(id as usize) else { continue };
            for c in tok.chars() {
                if c == WORD_MARK {
                    if !out.is_empty() {
                        out.push(' ');
                    }
                } else {
                    out.push(c);
                }
            }
        }
        out
    }

    /// Mean tokens per word on `text` (compression diagnostics).
    pub fn fertility(&self, text: &str) -> f64 {
        let words = text.split_whitespace().count().max(1);
        self.encode(text).len() as f64 / words as f64
    }
}

/// Compression comparison against the char tokenizer (tokens per char).
pub fn compression_ratio(bpe: &BpeTokenizer, text: &str) -> f64 {
    let chars = CharTokenizer::new().encode(text).len().max(1);
    bpe.encode(text).len() as f64 / chars as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "the cat sat on the mat. the cat ran. a cat and the mat";

    #[test]
    fn roundtrips_whitespace_normalized() {
        let bpe = BpeTokenizer::train(CORPUS, 60);
        let ids = bpe.encode("the cat sat");
        assert_eq!(bpe.decode(&ids), "the cat sat");
    }

    #[test]
    fn learns_frequent_words_as_single_tokens() {
        let bpe = BpeTokenizer::train(CORPUS, 80);
        // "the" appears 4× — should merge into ≤2 symbols (often 1 + marker).
        let ids = bpe.encode("the");
        assert!(ids.len() <= 2, "'the' took {} tokens", ids.len());
    }

    #[test]
    fn compression_beats_char_level() {
        let bpe = BpeTokenizer::train(CORPUS, 100);
        assert!(compression_ratio(&bpe, CORPUS) < 0.75);
    }

    #[test]
    fn deterministic_training() {
        let a = BpeTokenizer::train(CORPUS, 64);
        let b = BpeTokenizer::train(CORPUS, 64);
        assert_eq!(a.encode(CORPUS), b.encode(CORPUS));
        assert_eq!(a.vocab_size(), b.vocab_size());
    }

    #[test]
    fn unknown_chars_fall_back_gracefully() {
        let bpe = BpeTokenizer::train(CORPUS, 40);
        let ids = bpe.encode("cat zzz");
        // 'z' never appeared; it's dropped rather than panicking.
        assert!(bpe.decode(&ids).starts_with("cat"));
    }

    #[test]
    fn vocab_capped() {
        let bpe = BpeTokenizer::train(CORPUS, 30);
        assert!(bpe.vocab_size() <= 30);
    }

    #[test]
    fn fertility_decreases_with_vocab() {
        let small = BpeTokenizer::train(CORPUS, 30);
        let large = BpeTokenizer::train(CORPUS, 120);
        assert!(large.fertility(CORPUS) <= small.fertility(CORPUS));
    }
}
