//! Character-level tokenizer (the LM substrate's GPT2-tokenizer stand-in).
//!
//! Vocabulary: 95 printable ASCII characters (0x20..0x7e) + `\n`, mapped to
//! ids 0..95, with one reserved `<unk>` slot — 96 total, matching the
//! `vocab: 96` of the `lm_*` configs. Round-trip safe on its domain.

pub mod bpe;

pub const VOCAB_SIZE: usize = 96;
pub const UNK: i32 = 95;

#[derive(Debug, Clone, Default)]
pub struct CharTokenizer;

impl CharTokenizer {
    pub fn new() -> Self {
        CharTokenizer
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }

    pub fn encode_char(&self, c: char) -> i32 {
        match c {
            '\n' => 94,
            c if (' '..='}').contains(&c) => (c as u8 - b' ') as i32,
            _ => UNK,
        }
    }

    pub fn decode_char(&self, id: i32) -> char {
        match id {
            94 => '\n',
            0..=93 => (b' ' + id as u8) as char,
            _ => '\u{fffd}',
        }
    }

    pub fn encode(&self, s: &str) -> Vec<i32> {
        s.chars().map(|c| self.encode_char(c)).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter().map(|&i| self.decode_char(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn roundtrip_ascii() {
        let t = CharTokenizer::new();
        let s = "Hello, world! 123 {ok}\nnext";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn ids_in_range() {
        let t = CharTokenizer::new();
        for c in ' '..='}' {
            let id = t.encode_char(c);
            assert!((0..VOCAB_SIZE as i32).contains(&id));
        }
        assert_eq!(t.encode_char('\n'), 94);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = CharTokenizer::new();
        assert_eq!(t.encode_char('é'), UNK);
        assert_eq!(t.encode_char('\t'), UNK);
    }

    #[test]
    fn prop_roundtrip_random_printable() {
        Prop::new("tokenizer roundtrip").cases(200).check(|rng| {
            let t = CharTokenizer::new();
            let len = 1 + rng.usize_below(200);
            let s: String = (0..len)
                .map(|_| {
                    if rng.f32() < 0.05 {
                        '\n'
                    } else {
                        (b' ' + rng.below(94) as u8) as char
                    }
                })
                .collect();
            prop_assert!(t.decode(&t.encode(&s)) == s, "roundtrip failed");
            Ok(())
        });
    }
}
