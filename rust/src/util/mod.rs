//! Substrate utilities: JSON, deterministic RNG, stats, CLI parsing, and
//! the worker pool powering the native backend's row-parallel engine.
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
