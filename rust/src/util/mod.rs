//! Substrate utilities: JSON, deterministic RNG, stats, CLI parsing.
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
