//! Minimal JSON parser/serializer (substrate: no serde in the vendored set).
//!
//! Supports the full JSON grammar needed by the artifact manifests and
//! experiment reports: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are kept as f64 (manifests only carry shapes,
//! counts and hyperparameters).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders -----------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // Surrogate pairs: only BMP escapes appear in our
                        // manifests, but handle pairs for completeness.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                lo = lo * 16
                                    + (d as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                        }
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-for-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize with stable key order (BTreeMap) — diffable reports.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64().unwrap(), 2.0);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn parses_raw_utf8() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,-3],"n":null,"obj":{"k":"v \"q\""},"t":true}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn reads_real_manifest_shape() {
        let m = r#"{"params":[{"name":"embed","shape":[32,16],"dtype":"float32"}]}"#;
        let j = Json::parse(m).unwrap();
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str().unwrap(), "embed");
        let shape: Vec<usize> = p
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![32, 16]);
    }
}
