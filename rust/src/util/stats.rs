//! Timing/statistics substrate used by the bench harness and the server.

/// Online summary of a stream of samples (latencies, losses, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    /// Percentile by linear interpolation on the sorted samples, q in [0, 1].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }
    pub fn p50(&self) -> f64 {
        self.percentile(0.5)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Format a duration in seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.p50(), 2.5);
        assert!((s.std() - 1.2909944).abs() < 1e-5);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        for x in 0..101 {
            s.push(x as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(1.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Summary::new().mean().is_nan());
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(0.5e-7).ends_with("ns"));
        assert!(fmt_secs(5e-5).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
