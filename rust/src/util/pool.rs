//! Dependency-free worker pool for the native backend's row-parallel engine.
//!
//! `std::thread` + mpsc channels only (the vendored crate set has no rayon /
//! crossbeam). One process-wide pool — sized by `--threads N`,
//! `HYENA_THREADS`, or `available_parallelism` — is shared by the trainer,
//! the batching server and the benches, so concurrent components contend
//! for the same cores instead of oversubscribing them (DESIGN.md §Perf).
//!
//! Design rules that keep the parallel model simple and *deterministic*:
//!
//! * Work items are **disjoint-write**: every parallel loop partitions its
//!   output rows, each index is processed with exactly the arithmetic the
//!   serial loop would use, so results are bitwise identical for any thread
//!   count (the threaded-vs-serial e2e test pins this).
//! * Parallel regions are **leaf-level** — tasks never spawn nested
//!   parallel regions, so the pool cannot deadlock on itself.
//! * Scoped borrows: [`WorkerPool::scope_run`] blocks until every submitted
//!   task has completed, which is what makes lending stack references to
//!   pool threads sound.

use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Inner {
    threads: usize,
    /// `None` for a 1-thread (inline) pool. Mutex so the handle stays `Sync`
    /// on toolchains where `mpsc::Sender` is not.
    tx: Mutex<Option<Sender<Job>>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        // Close the queue (workers observe a recv error and exit), then join.
        *self.tx.lock().unwrap() = None;
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Cloneable handle to a fixed-size worker pool.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<Inner>,
}

impl WorkerPool {
    /// Build a pool with `threads` workers (min 1). A 1-thread pool spawns
    /// no OS threads and runs every task inline on the caller.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        if threads == 1 {
            return WorkerPool {
                inner: Arc::new(Inner {
                    threads,
                    tx: Mutex::new(None),
                    handles: Mutex::new(Vec::new()),
                }),
            };
        }
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let h = std::thread::Builder::new()
                .name(format!("hyena-pool-{i}"))
                .spawn(move || worker_loop(rx))
                .expect("spawn pool worker");
            handles.push(h);
        }
        WorkerPool {
            inner: Arc::new(Inner {
                threads,
                tx: Mutex::new(Some(tx)),
                handles: Mutex::new(handles),
            }),
        }
    }

    /// Worker count the pool was built with.
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    fn send(&self, job: Job) {
        let guard = self.inner.tx.lock().unwrap();
        guard
            .as_ref()
            .expect("worker pool already shut down")
            .send(job)
            .expect("worker pool queue closed");
    }

    /// Run the given closures concurrently on the pool and block until every
    /// one has finished. Panics (after all tasks settle) if any task panicked.
    ///
    /// Tasks may borrow from the caller's stack: the function does not
    /// return before every task has completed, which is what makes the
    /// internal lifetime erasure sound.
    pub fn scope_run<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if self.threads() == 1 || n == 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let (done_tx, done_rx) = channel::<bool>();
        for t in tasks {
            let done = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
                let ok = catch_unwind(AssertUnwindSafe(t)).is_ok();
                let _ = done.send(ok);
            });
            // SAFETY: `job` may capture 'a borrows of the caller's stack.
            // We block on `done_rx` below until every job has signalled
            // completion (the signal is sent even on panic), so no borrow
            // outlives this call; erasing the lifetime is therefore sound.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(job)
            };
            self.send(job);
        }
        let mut ok = true;
        for _ in 0..n {
            ok &= done_rx.recv().expect("worker pool died mid-scope");
        }
        assert!(ok, "a worker-pool task panicked");
    }

    /// Parallel `for i in 0..n { f(i) }` over the pool (order unspecified,
    /// completion guaranteed on return).
    pub fn par_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.par_for_with(n, || (), |_, i| f(i), |_| ());
    }

    /// Parallel for with per-task worker state: each task calls `init` once,
    /// processes indices with `f(&mut state, i)`, then hands the state to
    /// `done` (e.g. back into a reuse pool). Indices are claimed from a
    /// shared atomic counter, so work balances across uneven rows.
    pub fn par_for_with<W, I, F, D>(&self, n: usize, init: I, f: F, done: D)
    where
        I: Fn() -> W + Sync,
        F: Fn(&mut W, usize) + Sync,
        D: Fn(W) + Sync,
    {
        if n == 0 {
            return;
        }
        let k = self.threads().min(n);
        if k <= 1 {
            let mut w = init();
            for i in 0..n {
                f(&mut w, i);
            }
            done(w);
            return;
        }
        let counter = AtomicUsize::new(0);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(k);
        for _ in 0..k {
            tasks.push(Box::new(|| {
                let mut w = init();
                loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(&mut w, i);
                }
                done(w);
            }));
        }
        self.scope_run(tasks);
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        // The guard is dropped before the job runs; blocking in recv under
        // the lock is fine (senders do not need it).
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // lock poisoned: shut down
        };
        match job {
            Ok(job) => job(),
            Err(_) => return, // queue closed: pool dropped
        }
    }
}

// ---------------------------------------------------------------------------
// global pool
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// Pool size when nothing is configured: `HYENA_THREADS` if set (≥ 1), else
/// the machine's available parallelism.
pub fn default_threads() -> usize {
    let from_env = std::env::var("HYENA_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1);
    from_env.unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Size the process-wide pool (the CLI's `--threads`). Must run before the
/// first [`global`] use; returns false (and changes nothing) afterwards.
pub fn configure(threads: usize) -> bool {
    GLOBAL.set(WorkerPool::new(threads)).is_ok()
}

/// The process-wide pool, created on first use with [`default_threads`].
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| WorkerPool::new(default_threads()))
}

// ---------------------------------------------------------------------------
// disjoint-write shared views
// ---------------------------------------------------------------------------

/// Unsynchronized shared-mutable view of an `f32` buffer for
/// embarrassingly-parallel *disjoint* writes (conv rows, dense row blocks).
///
/// Every parallel loop in the native backend partitions its output indices
/// up front; this view is how tasks reach their partition without wrapping
/// the whole buffer in a lock. All access is `unsafe` and the caller owns
/// the disjointness argument at each call site.
pub struct SharedMut<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: PhantomData<&'a mut [f32]>,
}

// SAFETY: SharedMut is a raw view over a caller-owned `&mut [f32]`; every
// dereference happens through the `unsafe` accessors below, whose contract
// (disjoint index partitions per task) is what actually guarantees absence
// of data races. Send/Sync only let the view cross thread boundaries; they
// add no access capability beyond those accessors.
unsafe impl Send for SharedMut<'_> {}
// SAFETY: as above — shared references to SharedMut expose only the same
// contract-guarded accessors, so `&SharedMut` is safe to share across the
// pool's worker threads.
unsafe impl Sync for SharedMut<'_> {}

impl<'a> SharedMut<'a> {
    pub fn new(data: &'a mut [f32]) -> SharedMut<'a> {
        SharedMut { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable subslice `[start, start + len)`.
    ///
    /// # Safety
    /// No other live reference (from this view or elsewhere) may overlap the
    /// range while the returned slice is alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [f32] {
        assert!(start + len <= self.len, "SharedMut slice out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// Mutable element reference.
    ///
    /// # Safety
    /// No other live reference may target `idx` while the returned
    /// reference is alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn at(&self, idx: usize) -> &mut f32 {
        assert!(idx < self.len, "SharedMut index out of bounds");
        &mut *self.ptr.add(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_every_index_once() {
        let pool = WorkerPool::new(4);
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.par_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_row_writes_match_serial() {
        let pool = WorkerPool::new(3);
        let (rows, width) = (37, 11);
        let mut out = vec![0.0f32; rows * width];
        {
            let view = SharedMut::new(&mut out);
            pool.par_for(rows, |r| {
                // SAFETY: each index owns row r exclusively.
                let row = unsafe { view.slice(r * width, width) };
                for (c, x) in row.iter_mut().enumerate() {
                    *x = (r * width + c) as f32;
                }
            });
        }
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut hits = 0u64;
        {
            let cell = AtomicU64::new(0);
            pool.par_for(5, |_| {
                cell.fetch_add(1, Ordering::Relaxed);
            });
            hits += cell.load(Ordering::Relaxed);
        }
        assert_eq!(hits, 5);
    }

    #[test]
    fn par_for_with_reuses_and_returns_state() {
        let pool = WorkerPool::new(2);
        let created = AtomicUsize::new(0);
        let returned = AtomicUsize::new(0);
        let work = AtomicUsize::new(0);
        pool.par_for_with(
            64,
            || {
                created.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; 8]
            },
            |w, i| {
                w[0] += 1.0;
                work.fetch_add(i, Ordering::Relaxed);
            },
            |_w| {
                returned.fetch_add(1, Ordering::Relaxed);
            },
        );
        let c = created.load(Ordering::Relaxed);
        assert!(c >= 1 && c <= 2, "one state per task, got {c}");
        assert_eq!(c, returned.load(Ordering::Relaxed));
        assert_eq!(work.load(Ordering::Relaxed), (0..64).sum::<usize>());
    }

    #[test]
    #[should_panic(expected = "worker-pool task panicked")]
    fn task_panic_propagates() {
        let pool = WorkerPool::new(2);
        pool.par_for(8, |i| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
