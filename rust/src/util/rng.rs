//! Deterministic RNG substrate (PCG-XSH-RR 64/32 + helpers).
//!
//! Every data generator in the repo (tasks, corpus, images, batching) draws
//! from this RNG so experiments are exactly reproducible from a seed — the
//! same role `torch.Generator` seeds play in the paper's training recipe.

/// PCG-XSH-RR 64/32: small, fast, statistically solid for data generation.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream selector: generators for different substrates use
    /// different streams so adding a draw in one never shifts another.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut r = Pcg { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n || l >= (u32::MAX - n + 1) % n {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u32) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    ///
    /// The weight total and the cumulative walk accumulate in **f64**
    /// (f64-accumulation audit, DESIGN.md §Decode): this is the softmax
    /// inner reduction of temperature sampling, and at vocab-sized supports
    /// an f32 running sum visibly skews the tail of the distribution.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        let mut r = self.f32() as f64 * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w as f64;
            if r < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipfian sampler over ranks 1..=n with exponent s (used by the TinyPile
/// corpus to mimic natural-language unigram statistics).
pub struct Zipf {
    cdf: Vec<f32>,
}

impl Zipf {
    pub fn new(n: usize, s: f32) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s as f64);
            cdf.push(acc as f32);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Pcg) -> usize {
        let r = rng.f32();
        match self
            .cdf
            .binary_search_by(|c| c.total_cmp(&r))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::new(7);
        let mut b = Pcg::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg::with_stream(1, 10);
        let mut b = Pcg::with_stream(1, 11);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg::new(4);
        for _ in 0..1000 {
            let f = r.f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Pcg::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let z = Zipf::new(100, 1.1);
        let mut r = Pcg::new(8);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Pcg::new(9);
        for _ in 0..100 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }
}
