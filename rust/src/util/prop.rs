//! Property-testing harness substrate (the vendored set has no proptest).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! reports the failing case seed so the case can be replayed exactly:
//! `Prop::new(name).cases(500).check(|rng| { ... })`.

use crate::util::rng::Pcg;

pub struct Prop {
    name: String,
    cases: usize,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &str) -> Prop {
        Prop { name: name.to_string(), cases: 256, base_seed: 0x9e3779b97f4a7c15 }
    }
    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Prop {
        self.base_seed = s;
        self
    }

    /// Run the property; panics (test failure) with the failing case seed.
    pub fn check<F>(self, mut prop: F)
    where
        F: FnMut(&mut Pcg) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let seed = self
                .base_seed
                .wrapping_add((case as u64).wrapping_mul(0xbf58476d1ce4e5b9));
            let mut rng = Pcg::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "property '{}' failed at case {case} (replay seed {seed:#x}): {msg}",
                    self.name
                );
            }
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new("u32 below bound").cases(100).check(|rng| {
            let n = 1 + rng.below(100);
            let v = rng.below(n);
            prop_assert!(v < n, "v={v} n={n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn reports_failing_seed() {
        Prop::new("always false").cases(3).check(|_| Err("nope".into()));
    }
}
