//! Tiny CLI argument parser substrate (the vendored crate set has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Every `--key value` / `--key=value` pair in argv order. `options`
    /// keeps last-wins semantics; this preserves repeats for options that
    /// accept multiple values (e.g. loadgen `--addr` per target).
    pub multi: Vec<(String, String)>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — flag names must be
    /// declared so `--flag value` vs `--opt value` is unambiguous.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, flag_names: &[&str]) -> Args {
        let mut a = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                    a.multi.push((k.to_string(), v.to_string()));
                } else if flag_names.contains(&body) {
                    a.flags.push(body.to_string());
                } else if let Some(v) = iter.peek() {
                    if v.starts_with("--") {
                        a.flags.push(body.to_string());
                    } else {
                        let val = iter.next().unwrap();
                        a.options.insert(body.to_string(), val.clone());
                        a.multi.push((body.to_string(), val));
                    }
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn parse(flag_names: &[&str]) -> Args {
        Self::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    /// All values given for a repeated option, in argv order.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.multi.iter().filter(|(k, _)| k == name).map(|(_, v)| v.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse_from(
            v(&["train", "--steps", "100", "--fast", "--lr=0.5", "extra"]),
            &["fast"],
        );
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
        assert!(a.flag("fast"));
    }

    #[test]
    fn trailing_option_without_value_is_flag() {
        let a = Args::parse_from(v(&["--verbose"]), &[]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn option_followed_by_option_is_flag() {
        let a = Args::parse_from(v(&["--a", "--b", "5"]), &[]);
        assert!(a.flag("a"));
        assert_eq!(a.get_usize("b", 0), 5);
    }

    #[test]
    fn repeated_options_kept_in_order() {
        let a = Args::parse_from(
            v(&["--addr", "h1:1", "--addr=h2:2", "--addr", "h3:3"]),
            &[],
        );
        assert_eq!(a.get_all("addr"), vec!["h1:1", "h2:2", "h3:3"]);
        assert_eq!(a.get("addr"), Some("h3:3")); // last wins for scalars
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn defaults_kick_in() {
        let a = Args::parse_from(v(&[]), &[]);
        assert_eq!(a.get_or("name", "dflt"), "dflt");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
