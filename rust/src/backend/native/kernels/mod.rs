//! Vectorized kernel subsystem: runtime-dispatched SIMD microkernels
//! (DESIGN.md §Kernels).
//!
//! Every hot inner loop of the native engine — the dense microkernel's
//! axpy/dot block, the FFT butterfly passes and spectrum pointwise
//! products, the streaming-decode dot, GELU, and the gating elementwise
//! ops — routes through a [`Kernels`] dispatch table chosen **once** per
//! process:
//!
//! * [`SCALAR`] holds the pre-existing loop bodies verbatim, so the scalar
//!   path is bitwise identical to the engine before this subsystem existed
//!   (pinned by the tests below).
//! * The SIMD table holds explicit 8-lane AVX2 kernels on x86-64
//!   (`simd.rs`, selected at runtime via `is_x86_feature_detected!`) and
//!   4-lane NEON kernels on aarch64 (`neon.rs`, baseline ISA — no runtime
//!   probe needed). Per-element kernels (axpy, gating, spectrum products,
//!   butterflies) perform exactly the scalar arithmetic per lane (no FMA
//!   contraction), so they agree with the scalar table bitwise; reduction
//!   kernels (dot) split the sum across lanes and reduce the lane partials
//!   in f64, so they agree to f32 round-off and sit *inside* the engine's
//!   f64-accumulation audit bounds (DESIGN.md §Decode); the SIMD GELU uses
//!   a polynomial `exp` (Cephes coefficients) whose tanh agrees with libm
//!   to ≲1e-6 relative.
//!
//! Selection: `HYENA_KERNEL=scalar|simd|auto` (default `auto` = SIMD when
//! the CPU supports it). The active table's name is surfaced through
//! `Backend::mem_report` and the serve report, so benches and the
//! `scripts/check.sh kernel-smoke` gate can verify which path actually ran
//! rather than trusting the environment.

// Unsafe policy: the dispatch layer (this file) and the scalar table are
// `unsafe`-free (`scalar.rs` forbids it); the only `unsafe` in the
// subsystem lives in the SIMD tables (`simd.rs`/`neon.rs`), each carrying
// its module-level safety argument.
pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod simd;

#[cfg(target_arch = "aarch64")]
pub mod neon;

use std::sync::OnceLock;

/// sqrt(2/pi) — tanh-GELU constant (jax.nn.gelu default).
pub const GELU_C: f32 = 0.797_884_6;
/// Cubic coefficient of the tanh-GELU argument.
pub const GELU_A: f32 = 0.044_715;

/// One dispatch table of hot-loop microkernels. All entries are plain `fn`
/// pointers so the table is a `'static` constant and call sites pay one
/// indirect call per *row/block*, never per element.
pub struct Kernels {
    /// Table name: `"scalar"` or `"simd"` (what gates match on).
    pub name: &'static str,
    /// Instruction set behind the table: `"portable"`, `"avx2"`, `"neon"`.
    pub isa: &'static str,
    /// `y[i] += a · w[i]` — the dense microkernel's inner row update and
    /// the recurrence's bias term (`c += bias ⊙ v`).
    pub axpy: fn(y: &mut [f32], w: &[f32], a: f32),
    /// `Σ_i a[i]·b[i]` — the dense backward `dx` reduction and the
    /// streaming-decode dot ([`crate::backend::fft::causal_dot_step`]).
    pub dot: fn(a: &[f32], b: &[f32]) -> f32,
    /// `out[t] = gate[t·stride] · c[t]` — the Hyena gating elementwise op
    /// (gates live strided inside the `(·, (N+1)D)` projection rows).
    pub gate_mul: fn(out: &mut [f32], c: &[f32], gate: &[f32], stride: usize),
    /// Tanh-GELU forward over a contiguous chunk: writes `y` and the cached
    /// tanh term `th`.
    pub gelu_fwd: fn(x: &[f32], y: &mut [f32], th: &mut [f32]),
    /// One radix-2 butterfly stage (`len` = current butterfly span) over
    /// the full `(re, im)` buffers; `inverse` conjugates the twiddles.
    pub butterfly_pass:
        fn(re: &mut [f32], im: &mut [f32], tw_re: &[f32], tw_im: &[f32], len: usize, inverse: bool),
    /// Pointwise half-spectrum product `P = A·B` (causal convolution).
    pub spec_mul: fn(
        a_re: &[f32],
        a_im: &[f32],
        b_re: &[f32],
        b_im: &[f32],
        p_re: &mut [f32],
        p_im: &mut [f32],
    ),
    /// Pointwise half-spectrum product `P = conj(A)·B` (causal correlation,
    /// the convolution adjoint).
    pub spec_mul_conj: fn(
        a_re: &[f32],
        a_im: &[f32],
        b_re: &[f32],
        b_im: &[f32],
        p_re: &mut [f32],
        p_im: &mut [f32],
    ),
}

/// The scalar table: the engine's pre-subsystem loop bodies, verbatim.
pub static SCALAR: Kernels = Kernels {
    name: "scalar",
    isa: "portable",
    axpy: scalar::axpy,
    dot: scalar::dot,
    gate_mul: scalar::gate_mul,
    gelu_fwd: scalar::gelu_fwd,
    butterfly_pass: scalar::butterfly_pass,
    spec_mul: scalar::spec_mul,
    spec_mul_conj: scalar::spec_mul_conj,
};

/// The SIMD table for this CPU, if it has one: AVX2 on x86-64 (runtime
/// detection — the one place the ISA probe happens), NEON on aarch64
/// (baseline, always present).
#[cfg(target_arch = "x86_64")]
pub fn simd_table() -> Option<&'static Kernels> {
    if is_x86_feature_detected!("avx2") {
        Some(&simd::AVX2)
    } else {
        None
    }
}

#[cfg(target_arch = "aarch64")]
pub fn simd_table() -> Option<&'static Kernels> {
    Some(&neon::NEON)
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn simd_table() -> Option<&'static Kernels> {
    None
}

/// A parsed `HYENA_KERNEL` value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    /// SIMD when the CPU supports it, scalar otherwise (the default).
    Auto,
    /// Force the scalar table (bitwise-reproducible reference path).
    Scalar,
    /// Force the SIMD table; falls back to scalar when the CPU lacks it
    /// (gates must check the *reported* name, not the request).
    Simd,
}

impl KernelChoice {
    /// Parse a `HYENA_KERNEL` spelling; `None` for unknown values.
    pub fn parse(s: &str) -> Option<KernelChoice> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Some(KernelChoice::Auto),
            "scalar" => Some(KernelChoice::Scalar),
            "simd" => Some(KernelChoice::Simd),
            _ => None,
        }
    }
}

/// Resolve a choice against an (optionally absent) SIMD table. Pure — the
/// selection policy in one testable place.
pub fn resolve(choice: KernelChoice, simd: Option<&'static Kernels>) -> &'static Kernels {
    match choice {
        KernelChoice::Scalar => &SCALAR,
        KernelChoice::Auto | KernelChoice::Simd => simd.unwrap_or(&SCALAR),
    }
}

/// Selection for an explicit `HYENA_KERNEL` value (`None` = unset) against
/// this CPU's SIMD table. Unknown values fall back to `auto` with a
/// warning (a serving process should not die on a typo'd tuning knob).
/// Pure in the environment — this is what the forcing tests exercise, so
/// they never mutate the process env under a parallel test harness.
pub fn select_from(env: Option<&str>) -> &'static Kernels {
    let choice = match env {
        Some(v) => KernelChoice::parse(v).unwrap_or_else(|| {
            eprintln!("warning: HYENA_KERNEL={v:?} is not scalar|simd|auto; using auto");
            KernelChoice::Auto
        }),
        None => KernelChoice::Auto,
    };
    resolve(choice, simd_table())
}

/// Perform the selection `active()` caches: read `HYENA_KERNEL`, resolve
/// against this CPU's SIMD table.
pub fn select() -> &'static Kernels {
    select_from(std::env::var("HYENA_KERNEL").ok().as_deref())
}

// -- HYENA_PROF timing wrapper ------------------------------------------
//
// When profiling is on, dispatch goes through a wrapper table whose
// entries time the base table's kernels into the `obs::prof` slots. The
// wrapper is chosen once at first `active()` alongside the base table, so
// the profiling-off path pays nothing — not even a branch per call.

/// Base table behind the profiled wrappers (entries are plain `fn`
/// pointers, so they reach the base through this global, not a capture).
static PROF_BASE: OnceLock<&'static Kernels> = OnceLock::new();

fn prof_base() -> &'static Kernels {
    PROF_BASE.get().copied().unwrap_or(&SCALAR)
}

fn prof_axpy(y: &mut [f32], w: &[f32], a: f32) {
    let t0 = std::time::Instant::now();
    (prof_base().axpy)(y, w, a);
    crate::obs::prof::KERNELS[crate::obs::prof::K_AXPY].record(t0.elapsed().as_nanos() as u64);
}

fn prof_dot(a: &[f32], b: &[f32]) -> f32 {
    let t0 = std::time::Instant::now();
    let r = (prof_base().dot)(a, b);
    crate::obs::prof::KERNELS[crate::obs::prof::K_DOT].record(t0.elapsed().as_nanos() as u64);
    r
}

fn prof_gate_mul(out: &mut [f32], c: &[f32], gate: &[f32], stride: usize) {
    let t0 = std::time::Instant::now();
    (prof_base().gate_mul)(out, c, gate, stride);
    crate::obs::prof::KERNELS[crate::obs::prof::K_GATE_MUL].record(t0.elapsed().as_nanos() as u64);
}

fn prof_gelu_fwd(x: &[f32], y: &mut [f32], th: &mut [f32]) {
    let t0 = std::time::Instant::now();
    (prof_base().gelu_fwd)(x, y, th);
    crate::obs::prof::KERNELS[crate::obs::prof::K_GELU_FWD].record(t0.elapsed().as_nanos() as u64);
}

fn prof_butterfly_pass(
    re: &mut [f32],
    im: &mut [f32],
    tw_re: &[f32],
    tw_im: &[f32],
    len: usize,
    inverse: bool,
) {
    let t0 = std::time::Instant::now();
    (prof_base().butterfly_pass)(re, im, tw_re, tw_im, len, inverse);
    crate::obs::prof::KERNELS[crate::obs::prof::K_BUTTERFLY].record(t0.elapsed().as_nanos() as u64);
}

fn prof_spec_mul(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    let t0 = std::time::Instant::now();
    (prof_base().spec_mul)(a_re, a_im, b_re, b_im, p_re, p_im);
    crate::obs::prof::KERNELS[crate::obs::prof::K_SPEC_MUL].record(t0.elapsed().as_nanos() as u64);
}

fn prof_spec_mul_conj(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    let t0 = std::time::Instant::now();
    (prof_base().spec_mul_conj)(a_re, a_im, b_re, b_im, p_re, p_im);
    crate::obs::prof::KERNELS[crate::obs::prof::K_SPEC_MUL_CONJ]
        .record(t0.elapsed().as_nanos() as u64);
}

/// The profiled wrapper over `base`: same field set as [`SCALAR`], every
/// kernel timed into [`crate::obs::prof`]. Keeps the base table's
/// `name`/`isa` — profiling is orthogonal to ISA selection, and gates
/// match on the reported kernel name.
fn profiled_table(base: &'static Kernels) -> &'static Kernels {
    let _ = PROF_BASE.set(base);
    static T: OnceLock<Kernels> = OnceLock::new();
    T.get_or_init(|| Kernels {
        name: prof_base().name,
        isa: prof_base().isa,
        axpy: prof_axpy,
        dot: prof_dot,
        gate_mul: prof_gate_mul,
        gelu_fwd: prof_gelu_fwd,
        butterfly_pass: prof_butterfly_pass,
        spec_mul: prof_spec_mul,
        spec_mul_conj: prof_spec_mul_conj,
    })
}

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// The process-wide dispatch table, selected once on first use. Hot loops
/// fetch this once per kernel entry point (an atomic load), then call
/// through plain `fn` pointers. With `HYENA_PROF=1` the selected table is
/// wrapped in the timing layer (chosen here, once — a mid-process
/// `prof::set_enabled` toggle does not rewire kernel dispatch).
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(|| {
        let base = select();
        if crate::obs::prof::enabled() {
            profiled_table(base)
        } else {
            base
        }
    })
}

/// Name of the active table (`"scalar"` / `"simd"`), for reports and gates.
pub fn active_name() -> &'static str {
    active().name
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn signal(rng: &mut Pcg, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn rel(a: f32, b: f32) -> f32 {
        (a - b).abs() / (1.0 + a.abs().max(b.abs()))
    }

    // -- scalar table pinned bitwise to the pre-subsystem loop bodies -------

    #[test]
    fn scalar_axpy_is_bitwise_the_original_loop() {
        let mut rng = Pcg::new(1);
        for &n in &[1usize, 7, 8, 64, 257] {
            let w = signal(&mut rng, n);
            let a = rng.normal();
            let mut y = signal(&mut rng, n);
            let mut want = y.clone();
            // Pre-PR dense_fwd_into inner block, verbatim.
            for o in 0..n {
                want[o] += a * w[o];
            }
            (SCALAR.axpy)(&mut y, &w, a);
            assert_eq!(y, want, "n={n}");
        }
    }

    #[test]
    fn scalar_dot_is_bitwise_the_original_loop() {
        let mut rng = Pcg::new(2);
        for &n in &[1usize, 5, 8, 100, 513] {
            let a = signal(&mut rng, n);
            let b = signal(&mut rng, n);
            // Pre-PR dense_bwd_dx_into / causal_dot_step inner, verbatim.
            let mut acc = 0.0f32;
            for o in 0..n {
                acc += a[o] * b[o];
            }
            assert_eq!((SCALAR.dot)(&a, &b), acc, "n={n}");
        }
    }

    #[test]
    fn scalar_gate_mul_is_bitwise_the_original_loop() {
        let mut rng = Pcg::new(3);
        let (l, stride) = (33usize, 5usize);
        let c = signal(&mut rng, l);
        let gate = signal(&mut rng, l * stride);
        let mut out = vec![0.0f32; l];
        let mut want = vec![0.0f32; l];
        for t in 0..l {
            want[t] = gate[t * stride] * c[t];
        }
        (SCALAR.gate_mul)(&mut out, &c, &gate, stride);
        assert_eq!(out, want);
    }

    #[test]
    fn scalar_gelu_is_bitwise_the_original_loop() {
        let mut rng = Pcg::new(4);
        let n = 97usize;
        let x = signal(&mut rng, n);
        let (mut y, mut th) = (vec![0.0f32; n], vec![0.0f32; n]);
        (SCALAR.gelu_fwd)(&x, &mut y, &mut th);
        for i in 0..n {
            let v = x[i];
            let t = (GELU_C * (v + GELU_A * v * v * v)).tanh();
            assert_eq!(th[i], t);
            assert_eq!(y[i], 0.5 * v * (1.0 + t));
        }
    }

    #[test]
    fn scalar_spec_mul_is_bitwise_the_original_loop() {
        let mut rng = Pcg::new(5);
        let bins = 65usize;
        let (ar, ai) = (signal(&mut rng, bins), signal(&mut rng, bins));
        let (br, bi) = (signal(&mut rng, bins), signal(&mut rng, bins));
        let (mut pr, mut pi) = (vec![0.0f32; bins], vec![0.0f32; bins]);
        (SCALAR.spec_mul)(&ar, &ai, &br, &bi, &mut pr, &mut pi);
        for k in 0..bins {
            assert_eq!(pr[k], ar[k] * br[k] - ai[k] * bi[k]);
            assert_eq!(pi[k], ar[k] * bi[k] + ai[k] * br[k]);
        }
        (SCALAR.spec_mul_conj)(&ar, &ai, &br, &bi, &mut pr, &mut pi);
        for k in 0..bins {
            assert_eq!(pr[k], ar[k] * br[k] + ai[k] * bi[k]);
            assert_eq!(pi[k], ar[k] * bi[k] - ai[k] * br[k]);
        }
    }

    #[test]
    fn scalar_butterfly_pass_is_bitwise_the_original_stage_loop() {
        let mut rng = Pcg::new(6);
        let n = 64usize;
        // Twiddles exactly as Fft::new builds them.
        let mut tw_re = Vec::with_capacity(n / 2);
        let mut tw_im = Vec::with_capacity(n / 2);
        for k in 0..n / 2 {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        for inverse in [false, true] {
            let re0 = signal(&mut rng, n);
            let im0 = signal(&mut rng, n);
            let mut len = 2usize;
            while len <= n {
                let (mut re, mut im) = (re0.clone(), im0.clone());
                let (mut wre, mut wim) = (re0.clone(), im0.clone());
                (SCALAR.butterfly_pass)(&mut re, &mut im, &tw_re, &tw_im, len, inverse);
                // Pre-PR Fft::run stage body, verbatim.
                {
                    let step = n / len;
                    let half = len / 2;
                    let mut start = 0usize;
                    while start < n {
                        for k in 0..half {
                            let wr = tw_re[k * step];
                            let wi =
                                if inverse { -tw_im[k * step] } else { tw_im[k * step] };
                            let a = start + k;
                            let b = a + half;
                            let tr = wre[b] * wr - wim[b] * wi;
                            let ti = wre[b] * wi + wim[b] * wr;
                            wre[b] = wre[a] - tr;
                            wim[b] = wim[a] - ti;
                            wre[a] += tr;
                            wim[a] += ti;
                        }
                        start += len;
                    }
                }
                assert_eq!(re, wre, "len={len} inverse={inverse}");
                assert_eq!(im, wim, "len={len} inverse={inverse}");
                len <<= 1;
            }
        }
    }

    // -- scalar vs SIMD agreement (skipped on CPUs without a SIMD table) ----

    #[test]
    fn simd_elementwise_kernels_match_scalar_bitwise() {
        // axpy / gate_mul / spec products / butterflies perform the exact
        // scalar arithmetic per lane (mul + add, no FMA), so the agreement
        // is bitwise, including non-multiple-of-lane tails.
        let Some(simd) = simd_table() else { return };
        let mut rng = Pcg::new(7);
        for &n in &[1usize, 3, 8, 9, 16, 31, 64, 257, 1000] {
            let w = signal(&mut rng, n);
            let a = rng.normal();
            let y0 = signal(&mut rng, n);
            let (mut ys, mut yv) = (y0.clone(), y0.clone());
            (SCALAR.axpy)(&mut ys, &w, a);
            (simd.axpy)(&mut yv, &w, a);
            assert_eq!(ys, yv, "axpy n={n}");

            let stride = 1 + rng.usize_below(6);
            let c = signal(&mut rng, n);
            let gate = signal(&mut rng, n * stride);
            let (mut os, mut ov) = (vec![0.0f32; n], vec![0.0f32; n]);
            (SCALAR.gate_mul)(&mut os, &c, &gate, stride);
            (simd.gate_mul)(&mut ov, &c, &gate, stride);
            assert_eq!(os, ov, "gate_mul n={n} stride={stride}");

            let (ar, ai) = (signal(&mut rng, n), signal(&mut rng, n));
            let (br, bi) = (signal(&mut rng, n), signal(&mut rng, n));
            let (mut prs, mut pis) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut prv, mut piv) = (vec![0.0f32; n], vec![0.0f32; n]);
            (SCALAR.spec_mul)(&ar, &ai, &br, &bi, &mut prs, &mut pis);
            (simd.spec_mul)(&ar, &ai, &br, &bi, &mut prv, &mut piv);
            assert_eq!((&prs, &pis), (&prv, &piv), "spec_mul n={n}");
            (SCALAR.spec_mul_conj)(&ar, &ai, &br, &bi, &mut prs, &mut pis);
            (simd.spec_mul_conj)(&ar, &ai, &br, &bi, &mut prv, &mut piv);
            assert_eq!((&prs, &pis), (&prv, &piv), "spec_mul_conj n={n}");
        }
    }

    #[test]
    fn simd_butterfly_pass_matches_scalar_bitwise() {
        let Some(simd) = simd_table() else { return };
        let mut rng = Pcg::new(8);
        for &n in &[2usize, 8, 32, 256, 2048] {
            let mut tw_re = Vec::with_capacity(n / 2);
            let mut tw_im = Vec::with_capacity(n / 2);
            for k in 0..n / 2 {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
                tw_re.push(ang.cos() as f32);
                tw_im.push(ang.sin() as f32);
            }
            for inverse in [false, true] {
                let re0 = signal(&mut rng, n);
                let im0 = signal(&mut rng, n);
                let mut len = 2usize;
                while len <= n {
                    let (mut rs, mut is) = (re0.clone(), im0.clone());
                    let (mut rv, mut iv) = (re0.clone(), im0.clone());
                    (SCALAR.butterfly_pass)(&mut rs, &mut is, &tw_re, &tw_im, len, inverse);
                    (simd.butterfly_pass)(&mut rv, &mut iv, &tw_re, &tw_im, len, inverse);
                    assert_eq!(rs, rv, "re n={n} len={len} inverse={inverse}");
                    assert_eq!(is, iv, "im n={n} len={len} inverse={inverse}");
                    len <<= 1;
                }
            }
        }
    }

    #[test]
    fn simd_dot_matches_scalar_within_reduction_tolerance() {
        // The SIMD dot reassociates the sum (lane partials, reduced in
        // f64), so the agreement bound is the property-test contract:
        // ≤ 1e-5 relative.
        let Some(simd) = simd_table() else { return };
        let mut rng = Pcg::new(9);
        for &n in &[1usize, 7, 8, 15, 16, 17, 100, 4096] {
            let a = signal(&mut rng, n);
            let b = signal(&mut rng, n);
            let s = (SCALAR.dot)(&a, &b);
            let v = (simd.dot)(&a, &b);
            assert!(rel(s, v) <= 1e-5, "dot n={n}: scalar {s} vs simd {v}");
        }
    }

    #[test]
    fn simd_gelu_matches_scalar_within_poly_tolerance() {
        // The SIMD tanh is a Cephes-style polynomial exp; ≲1e-6 relative
        // against libm, well inside the 1e-5 kernel contract.
        let Some(simd) = simd_table() else { return };
        let mut rng = Pcg::new(10);
        let n = 1003usize;
        let mut x = signal(&mut rng, n);
        // Hit the saturating and near-zero regimes explicitly.
        x[0] = 0.0;
        x[1] = 12.0;
        x[2] = -12.0;
        x[3] = 1e-4;
        x[4] = -88.0;
        x[5] = 88.0;
        let (mut ys, mut ts) = (vec![0.0f32; n], vec![0.0f32; n]);
        let (mut yv, mut tv) = (vec![0.0f32; n], vec![0.0f32; n]);
        (SCALAR.gelu_fwd)(&x, &mut ys, &mut ts);
        (simd.gelu_fwd)(&x, &mut yv, &mut tv);
        for i in 0..n {
            assert!(
                rel(ys[i], yv[i]) <= 1e-5,
                "gelu x={}: scalar {} vs simd {}",
                x[i],
                ys[i],
                yv[i]
            );
            assert!(
                rel(ts[i], tv[i]) <= 1e-5,
                "tanh x={}: scalar {} vs simd {}",
                x[i],
                ts[i],
                tv[i]
            );
        }
    }

    // -- f64-accumulation audit: the dot reduction at width 8K -------------

    #[test]
    fn f64_accumulation_bounds_dot_drift_at_8k() {
        // §Decode-audit extension to the new kernels: at reduction width
        // 8192 (positive operands — condition number ~1), the scalar f32
        // dot drifts by at most 5e-4 relative against an exact f64
        // reference, and the SIMD dot (lane partials reduced in f64) must
        // be at least as tight — never looser than the scalar bound.
        let d = 8192usize;
        let mut rng = Pcg::new(11);
        let a: Vec<f32> = (0..d).map(|_| 0.5 + 0.5 * rng.f32()).collect();
        let b: Vec<f32> = (0..d).map(|_| 0.5 + 0.5 * rng.f32()).collect();
        let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let s = (SCALAR.dot)(&a, &b) as f64;
        let err_scalar = (s - exact).abs() / exact;
        assert!(err_scalar <= 5e-4, "scalar dot drifted: {err_scalar}");
        if let Some(simd) = simd_table() {
            let v = (simd.dot)(&a, &b) as f64;
            let err_simd = (v - exact).abs() / exact;
            assert!(err_simd <= 5e-4, "simd dot drifted: {err_simd}");
            assert!(
                err_simd <= err_scalar + 1e-7,
                "simd dot ({err_simd}) looser than scalar ({err_scalar})"
            );
        }
    }

    // -- selection policy ---------------------------------------------------

    #[test]
    fn choice_parsing_and_resolution() {
        assert_eq!(KernelChoice::parse("scalar"), Some(KernelChoice::Scalar));
        assert_eq!(KernelChoice::parse(" SIMD "), Some(KernelChoice::Simd));
        assert_eq!(KernelChoice::parse("auto"), Some(KernelChoice::Auto));
        assert_eq!(KernelChoice::parse(""), Some(KernelChoice::Auto));
        assert_eq!(KernelChoice::parse("avx512"), None);

        let fake_simd: Option<&'static Kernels> = simd_table();
        // Scalar always forces the scalar table.
        assert_eq!(resolve(KernelChoice::Scalar, fake_simd).name, "scalar");
        assert_eq!(resolve(KernelChoice::Scalar, None).name, "scalar");
        // Auto/Simd take the SIMD table when present, scalar otherwise.
        assert_eq!(resolve(KernelChoice::Auto, None).name, "scalar");
        assert_eq!(resolve(KernelChoice::Simd, None).name, "scalar");
        if let Some(t) = fake_simd {
            assert_eq!(resolve(KernelChoice::Auto, fake_simd).name, t.name);
            assert_eq!(resolve(KernelChoice::Simd, fake_simd).name, "simd");
        }
    }

    #[test]
    fn env_override_forces_the_scalar_table() {
        // `select_from` is `select()` minus the env read (pure), so the
        // forcing knob is testable without mutating the process
        // environment — a set_var here would race other tests' first
        // `active()` initialization under the parallel test harness.
        assert_eq!(select_from(Some("scalar")).name, "scalar");
        assert_eq!(select_from(Some("SCALAR ")).name, "scalar");
        let forced = select_from(Some("simd"));
        match simd_table() {
            Some(t) => assert_eq!(forced.name, t.name),
            None => assert_eq!(forced.name, "scalar"),
        }
        // Unknown values warn and fall back to auto, never panic.
        let fallback = select_from(Some("definitely-not-a-kernel"));
        assert_eq!(fallback.name, select_from(None).name);
        // And `select()` agrees with `select_from` on the ambient env.
        assert_eq!(
            select().name,
            select_from(std::env::var("HYENA_KERNEL").ok().as_deref()).name
        );
    }

    #[test]
    fn profiled_table_times_kernels_and_matches_base() {
        use std::sync::atomic::Ordering;
        let t = profiled_table(&SCALAR);
        // Reports the base identity — gates match on the kernel name.
        assert_eq!(t.name, prof_base().name);
        let slot = &crate::obs::prof::KERNELS[crate::obs::prof::K_AXPY];
        let before = slot.calls.load(Ordering::Relaxed);
        let w = vec![2.0f32; 33];
        let mut y = vec![1.0f32; 33];
        let mut want = vec![1.0f32; 33];
        (t.axpy)(&mut y, &w, 0.5);
        (prof_base().axpy)(&mut want, &w, 0.5);
        assert_eq!(y, want, "wrapper must not change the arithmetic");
        // Deltas, not absolutes: other tests share the process slots.
        assert!(slot.calls.load(Ordering::Relaxed) > before, "axpy call not recorded");
    }

    #[test]
    fn active_table_is_consistent_with_selection_policy() {
        // Whatever the environment says, the cached table must be one of
        // the two real tables and agree with its own name.
        let k = active();
        assert!(k.name == "scalar" || k.name == "simd");
        assert_eq!(active_name(), k.name);
        if k.name == "simd" {
            assert!(simd_table().is_some(), "simd table active on a CPU without one");
        }
    }
}
