//! AVX2 f32x8 microkernels for x86-64 (DESIGN.md §Kernels).
//!
//! # Safety argument (the only `unsafe` in the kernel subsystem)
//!
//! Every `#[target_feature(enable = "avx2")]` function in this file is
//! reachable **only** through the [`AVX2`] dispatch table, and that table
//! is handed out exclusively by `kernels::simd_table()`, which returns it
//! only after `is_x86_feature_detected!("avx2")` succeeds at runtime. The
//! public entries of the table are safe wrappers whose single `unsafe`
//! block encodes exactly that invariant: "this table exists ⇒ the CPU has
//! AVX2". No other precondition is required — the intrinsics used here are
//! plain loads/stores/arithmetic on slice bounds that every wrapper
//! `assert!`s **unconditionally** (release builds included: the raw-pointer
//! bodies must never see mismatched lengths where the scalar kernels would
//! merely panic on slice indexing), with all vector loads/stores on
//! indices proved in-bounds by the loop structure given those asserts.
//!
//! # Numerics contract
//!
//! * Per-element kernels (`axpy`, `gate_mul`, `spec_mul`, `spec_mul_conj`,
//!   `butterfly_pass`) use separate `mul`/`add`/`sub` — **no FMA
//!   contraction** — so every lane performs exactly the scalar arithmetic
//!   and the results are bitwise identical to the scalar table.
//! * `dot` splits the sum into two 8-lane accumulators (paired-lane
//!   accumulation: 16 partial sums) and reduces lanes + tail in **f64**,
//!   which reassociates the sum but tightens it — drift at 8K-wide
//!   reductions is pinned to be no looser than the scalar kernel by
//!   `f64_accumulation_bounds_dot_drift_at_8k`.
//! * `gelu_fwd` evaluates tanh through a Cephes-style polynomial `exp`
//!   (`exp2` scaling + degree-5 polynomial, the classic `exp_ps`
//!   coefficients), accurate to ≲1e-6 relative vs libm — inside the 1e-5
//!   scalar-agreement contract. The non-multiple-of-8 tail falls back to
//!   libm tanh (bitwise the scalar kernel).

// Cephes coefficients are quoted at full precision; index loops mirror the
// scalar reference bodies one-to-one.
#![allow(clippy::excessive_precision, clippy::needless_range_loop)]

use core::arch::x86_64::*;

use super::{Kernels, GELU_A, GELU_C};

/// The AVX2 table. Only `kernels::simd_table()` may hand this out (see the
/// module-level safety argument).
pub static AVX2: Kernels = Kernels {
    name: "simd",
    isa: "avx2",
    axpy,
    dot,
    gate_mul,
    gelu_fwd,
    butterfly_pass,
    spec_mul,
    spec_mul_conj,
};

// ---------------------------------------------------------------------------
// safe wrappers (dispatch-table entries)
// ---------------------------------------------------------------------------

fn axpy(y: &mut [f32], w: &[f32], a: f32) {
    assert_eq!(y.len(), w.len(), "axpy length mismatch");
    // SAFETY: `AVX2` is only reachable after runtime AVX2 detection
    // (module-level safety argument); slice bounds are asserted above.
    unsafe { axpy_avx2(y, w, a) }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    // SAFETY: as above.
    unsafe { dot_avx2(a, b) }
}

fn gate_mul(out: &mut [f32], c: &[f32], gate: &[f32], stride: usize) {
    assert_eq!(out.len(), c.len(), "gate_mul length mismatch");
    assert!(
        out.is_empty() || (out.len() - 1) * stride < gate.len(),
        "gate_mul gate column out of bounds"
    );
    // SAFETY: as above.
    unsafe { gate_mul_avx2(out, c, gate, stride) }
}

fn gelu_fwd(x: &[f32], y: &mut [f32], th: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "gelu length mismatch");
    assert_eq!(x.len(), th.len(), "gelu length mismatch");
    // SAFETY: as above.
    unsafe { gelu_fwd_avx2(x, y, th) }
}

fn butterfly_pass(
    re: &mut [f32],
    im: &mut [f32],
    tw_re: &[f32],
    tw_im: &[f32],
    len: usize,
    inverse: bool,
) {
    let n = re.len();
    assert_eq!(im.len(), n, "butterfly re/im length mismatch");
    assert!(len >= 2 && len <= n && n % len == 0, "butterfly span {len} invalid for n={n}");
    assert!(tw_re.len() >= n / 2 && tw_im.len() >= n / 2, "butterfly twiddle table too short");
    // Stages with fewer than 8 butterflies per block gain nothing from
    // vectorizing; run the verbatim scalar stage (bitwise-identical math).
    if len / 2 < 8 {
        super::scalar::butterfly_pass(re, im, tw_re, tw_im, len, inverse);
        return;
    }
    // SAFETY: as above.
    unsafe { butterfly_pass_avx2(re, im, tw_re, tw_im, len, inverse) }
}

fn spec_mul(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    assert_spec_lens(a_re, a_im, b_re, b_im, p_re, p_im);
    // SAFETY: as above.
    unsafe { spec_mul_avx2(a_re, a_im, b_re, b_im, p_re, p_im, false) }
}

fn spec_mul_conj(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    assert_spec_lens(a_re, a_im, b_re, b_im, p_re, p_im);
    // SAFETY: as above.
    unsafe { spec_mul_avx2(a_re, a_im, b_re, b_im, p_re, p_im, true) }
}

/// Length contract of the spectrum product kernels: every input covers the
/// `p_re.len()` output bins (unconditional — the bodies use raw pointers).
fn assert_spec_lens(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &[f32],
    p_im: &[f32],
) {
    let n = p_re.len();
    assert!(
        p_im.len() == n
            && a_re.len() >= n
            && a_im.len() >= n
            && b_re.len() >= n
            && b_im.len() >= n,
        "spec_mul length mismatch"
    );
}

// ---------------------------------------------------------------------------
// AVX2 bodies
// ---------------------------------------------------------------------------

/// # Safety
///
/// Caller must guarantee AVX2 is available on the running CPU (the safe
/// wrapper dispatches here only after the one-time `is_x86_feature_detected!`
/// probe) and that `w.len() >= y.len()`.
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(y: &mut [f32], w: &[f32], a: f32) {
    let n = y.len();
    let av = _mm256_set1_ps(a);
    let (yp, wp) = (y.as_mut_ptr(), w.as_ptr());
    let mut i = 0usize;
    while i + 8 <= n {
        let yv = _mm256_loadu_ps(yp.add(i));
        let wv = _mm256_loadu_ps(wp.add(i));
        // mul + add, not FMA: bitwise the scalar `y[o] += a * w[o]`.
        _mm256_storeu_ps(yp.add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, wv)));
        i += 8;
    }
    while i < n {
        y[i] += a * w[i];
        i += 1;
    }
}

/// # Safety
///
/// Caller must guarantee AVX2 is available and `b.len() >= a.len()`.
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    // Paired-lane accumulation: two independent 8-lane partials break the
    // add dependency chain and halve rounding depth.
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let p0 = _mm256_mul_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)));
        let p1 =
            _mm256_mul_ps(_mm256_loadu_ps(ap.add(i + 8)), _mm256_loadu_ps(bp.add(i + 8)));
        acc0 = _mm256_add_ps(acc0, p0);
        acc1 = _mm256_add_ps(acc1, p1);
        i += 16;
    }
    if i + 8 <= n {
        let p = _mm256_mul_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)));
        acc0 = _mm256_add_ps(acc0, p);
        i += 8;
    }
    // Reduce the 16 lane partials and the scalar tail in f64 — keeps the
    // decode dot inside the engine's f64-accumulation audit bounds.
    let mut l0 = [0.0f32; 8];
    let mut l1 = [0.0f32; 8];
    _mm256_storeu_ps(l0.as_mut_ptr(), acc0);
    _mm256_storeu_ps(l1.as_mut_ptr(), acc1);
    let mut s = 0.0f64;
    for k in 0..8 {
        s += l0[k] as f64;
        s += l1[k] as f64;
    }
    while i < n {
        s += a[i] as f64 * b[i] as f64;
        i += 1;
    }
    s as f32
}

/// # Safety
///
/// Caller must guarantee AVX2 is available, `c.len() >= out.len()` and, for
/// `stride > 1`, that `gate` covers every index `i·stride` touched below.
#[target_feature(enable = "avx2")]
unsafe fn gate_mul_avx2(out: &mut [f32], c: &[f32], gate: &[f32], stride: usize) {
    let n = out.len();
    let (op, cp) = (out.as_mut_ptr(), c.as_ptr());
    let mut i = 0usize;
    if stride == 1 {
        while i + 8 <= n {
            let g = _mm256_loadu_ps(gate.as_ptr().add(i));
            let cv = _mm256_loadu_ps(cp.add(i));
            _mm256_storeu_ps(op.add(i), _mm256_mul_ps(g, cv));
            i += 8;
        }
    } else {
        let mut buf = [0.0f32; 8];
        while i + 8 <= n {
            // Strided gather of the gate column (scalar loads), vector
            // multiply against the contiguous c row. Per-element math is
            // exactly the scalar kernel's.
            for (j, slot) in buf.iter_mut().enumerate() {
                *slot = gate[(i + j) * stride];
            }
            let g = _mm256_loadu_ps(buf.as_ptr());
            let cv = _mm256_loadu_ps(cp.add(i));
            _mm256_storeu_ps(op.add(i), _mm256_mul_ps(g, cv));
            i += 8;
        }
    }
    while i < n {
        out[i] = gate[i * stride] * c[i];
        i += 1;
    }
}

/// # Safety
///
/// Caller must guarantee AVX2 is available and all six slices share one
/// length (asserted by the safe wrapper).
#[target_feature(enable = "avx2")]
unsafe fn spec_mul_avx2(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
    conj: bool,
) {
    let n = p_re.len();
    let mut k = 0usize;
    while k + 8 <= n {
        let ar = _mm256_loadu_ps(a_re.as_ptr().add(k));
        let ai = _mm256_loadu_ps(a_im.as_ptr().add(k));
        let br = _mm256_loadu_ps(b_re.as_ptr().add(k));
        let bi = _mm256_loadu_ps(b_im.as_ptr().add(k));
        let rr = _mm256_mul_ps(ar, br);
        let ii = _mm256_mul_ps(ai, bi);
        let ri = _mm256_mul_ps(ar, bi);
        let ir = _mm256_mul_ps(ai, br);
        let (pr, pi) = if conj {
            // conj(A)·B: re = ar·br + ai·bi, im = ar·bi − ai·br.
            (_mm256_add_ps(rr, ii), _mm256_sub_ps(ri, ir))
        } else {
            // A·B: re = ar·br − ai·bi, im = ar·bi + ai·br.
            (_mm256_sub_ps(rr, ii), _mm256_add_ps(ri, ir))
        };
        _mm256_storeu_ps(p_re.as_mut_ptr().add(k), pr);
        _mm256_storeu_ps(p_im.as_mut_ptr().add(k), pi);
        k += 8;
    }
    while k < n {
        if conj {
            p_re[k] = a_re[k] * b_re[k] + a_im[k] * b_im[k];
            p_im[k] = a_re[k] * b_im[k] - a_im[k] * b_re[k];
        } else {
            p_re[k] = a_re[k] * b_re[k] - a_im[k] * b_im[k];
            p_im[k] = a_re[k] * b_im[k] + a_im[k] * b_re[k];
        }
        k += 1;
    }
}

/// # Safety
///
/// Caller must guarantee AVX2 is available and that `re`/`im` are at least
/// `2·half` long with twiddles covering `half` entries — the FFT plan's
/// invariant, asserted by the safe wrapper.
#[target_feature(enable = "avx2")]
unsafe fn butterfly_pass_avx2(
    re: &mut [f32],
    im: &mut [f32],
    tw_re: &[f32],
    tw_im: &[f32],
    len: usize,
    inverse: bool,
) {
    let n = re.len();
    let step = n / len;
    let half = len / 2;
    let sign = if inverse { -1.0f32 } else { 1.0f32 };
    let (rp, ip) = (re.as_mut_ptr(), im.as_mut_ptr());
    let mut wr_buf = [0.0f32; 8];
    let mut wi_buf = [0.0f32; 8];
    let mut start = 0usize;
    while start < n {
        let mut k = 0usize;
        while k + 8 <= half {
            if step == 1 {
                // Final stage: twiddles are contiguous.
                wr_buf.copy_from_slice(&tw_re[k..k + 8]);
                for (j, slot) in wi_buf.iter_mut().enumerate() {
                    *slot = sign * tw_im[k + j];
                }
            } else {
                for j in 0..8 {
                    wr_buf[j] = tw_re[(k + j) * step];
                    wi_buf[j] = sign * tw_im[(k + j) * step];
                }
            }
            let wr = _mm256_loadu_ps(wr_buf.as_ptr());
            let wi = _mm256_loadu_ps(wi_buf.as_ptr());
            let a = start + k;
            let b = a + half;
            // b = a + half ≥ a + 8, so the two 8-lane windows are disjoint.
            let rb = _mm256_loadu_ps(rp.add(b));
            let ib = _mm256_loadu_ps(ip.add(b));
            // tr = re[b]·wr − im[b]·wi ; ti = re[b]·wi + im[b]·wr
            // (mul + add/sub, no FMA — bitwise the scalar stage).
            let tr = _mm256_sub_ps(_mm256_mul_ps(rb, wr), _mm256_mul_ps(ib, wi));
            let ti = _mm256_add_ps(_mm256_mul_ps(rb, wi), _mm256_mul_ps(ib, wr));
            let ra = _mm256_loadu_ps(rp.add(a));
            let ia = _mm256_loadu_ps(ip.add(a));
            _mm256_storeu_ps(rp.add(b), _mm256_sub_ps(ra, tr));
            _mm256_storeu_ps(ip.add(b), _mm256_sub_ps(ia, ti));
            _mm256_storeu_ps(rp.add(a), _mm256_add_ps(ra, tr));
            _mm256_storeu_ps(ip.add(a), _mm256_add_ps(ia, ti));
            k += 8;
        }
        // Tail butterflies of this block: the verbatim scalar body.
        while k < half {
            let wr = tw_re[k * step];
            let wi = if inverse { -tw_im[k * step] } else { tw_im[k * step] };
            let a = start + k;
            let b = a + half;
            let tr = re[b] * wr - im[b] * wi;
            let ti = re[b] * wi + im[b] * wr;
            re[b] = re[a] - tr;
            im[b] = im[a] - ti;
            re[a] += tr;
            im[a] += ti;
            k += 1;
        }
        start += len;
    }
}

// -- polynomial exp / tanh ---------------------------------------------------

// Cephes `expf` constants (the classic `exp_ps` from sse_mathfun): exp(x) =
// 2^round(x·log2e) · P(r) with Cody–Waite range reduction; |rel err| ≲ 2e-7
// over the clamped domain. Mirrored 1:1 by
// `python/tests/test_native_kernels.py`.
const EXP_HI: f32 = 88.3762626647950;
const EXP_LO: f32 = -88.3762626647949;
const LOG2EF: f32 = 1.44269504088896341;
const EXP_C1: f32 = 0.693359375;
const EXP_C2: f32 = -2.12194440e-4;
const EXP_P0: f32 = 1.9875691500e-4;
const EXP_P1: f32 = 1.3981999507e-3;
const EXP_P2: f32 = 8.3334519073e-3;
const EXP_P3: f32 = 4.1665795894e-2;
const EXP_P4: f32 = 1.6666665459e-1;
const EXP_P5: f32 = 5.0000001201e-1;

/// # Safety
///
/// Caller must guarantee AVX2 is available; pure lane-wise arithmetic
/// otherwise (no memory access).
#[target_feature(enable = "avx2")]
unsafe fn exp256(x: __m256) -> __m256 {
    let one = _mm256_set1_ps(1.0);
    let x = _mm256_min_ps(_mm256_set1_ps(EXP_HI), _mm256_max_ps(_mm256_set1_ps(EXP_LO), x));
    // fx = floor(x·log2e + 0.5)  (round to nearest).
    let fx = _mm256_floor_ps(_mm256_add_ps(
        _mm256_mul_ps(x, _mm256_set1_ps(LOG2EF)),
        _mm256_set1_ps(0.5),
    ));
    // Cody–Waite: r = x − fx·C1 − fx·C2.
    let r = _mm256_sub_ps(
        _mm256_sub_ps(x, _mm256_mul_ps(fx, _mm256_set1_ps(EXP_C1))),
        _mm256_mul_ps(fx, _mm256_set1_ps(EXP_C2)),
    );
    let r2 = _mm256_mul_ps(r, r);
    let mut y = _mm256_set1_ps(EXP_P0);
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P1));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P2));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P3));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P4));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P5));
    y = _mm256_add_ps(_mm256_add_ps(_mm256_mul_ps(y, r2), r), one);
    // Scale by 2^fx via the exponent field.
    let n = _mm256_cvtps_epi32(fx);
    let pow2n =
        _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(n, _mm256_set1_epi32(127))));
    _mm256_mul_ps(y, pow2n)
}

/// `tanh(x) = sign(x) · (1 − 2/(e^{2|x|} + 1))` — monotone, saturates
/// cleanly (the exp clamp at 88.37 sends the correction term to ~1e-38).
/// # Safety
///
/// Caller must guarantee AVX2 is available; pure lane-wise arithmetic
/// otherwise (no memory access).
#[target_feature(enable = "avx2")]
unsafe fn tanh256(x: __m256) -> __m256 {
    let sign_mask = _mm256_set1_ps(-0.0);
    let sign = _mm256_and_ps(x, sign_mask);
    let ax = _mm256_andnot_ps(sign_mask, x);
    let e = exp256(_mm256_add_ps(ax, ax));
    let one = _mm256_set1_ps(1.0);
    let two = _mm256_set1_ps(2.0);
    let t = _mm256_sub_ps(one, _mm256_div_ps(two, _mm256_add_ps(e, one)));
    _mm256_or_ps(t, sign)
}

/// # Safety
///
/// Caller must guarantee AVX2 is available and `y.len() == x.len()`,
/// `th.len() == x.len()` (asserted by the safe wrapper).
#[target_feature(enable = "avx2")]
unsafe fn gelu_fwd_avx2(x: &[f32], y: &mut [f32], th: &mut [f32]) {
    let n = x.len();
    let (xp, yp, tp) = (x.as_ptr(), y.as_mut_ptr(), th.as_mut_ptr());
    let c = _mm256_set1_ps(GELU_C);
    let a = _mm256_set1_ps(GELU_A);
    let half = _mm256_set1_ps(0.5);
    let one = _mm256_set1_ps(1.0);
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(xp.add(i));
        let v3 = _mm256_mul_ps(_mm256_mul_ps(v, v), v);
        let inner = _mm256_mul_ps(c, _mm256_add_ps(v, _mm256_mul_ps(a, v3)));
        let t = tanh256(inner);
        _mm256_storeu_ps(tp.add(i), t);
        let g = _mm256_mul_ps(_mm256_mul_ps(half, v), _mm256_add_ps(one, t));
        _mm256_storeu_ps(yp.add(i), g);
        i += 8;
    }
    // Tail: the verbatim scalar body (libm tanh).
    while i < n {
        let v = x[i];
        let t = (GELU_C * (v + GELU_A * v * v * v)).tanh();
        th[i] = t;
        y[i] = 0.5 * v * (1.0 + t);
        i += 1;
    }
}
