//! NEON f32x4 microkernels for aarch64 (DESIGN.md §Kernels).
//!
//! # Safety argument
//!
//! NEON (Advanced SIMD) is a baseline feature of every aarch64 target Rust
//! supports, so unlike the AVX2 table there is no runtime probe: the
//! intrinsics are always valid to execute. The `unsafe` blocks below exist
//! only because the `core::arch::aarch64` intrinsics are declared `unsafe
//! fn`; all loads/stores are unaligned (`vld1q`/`vst1q`) on indices proved
//! in-bounds by the loop structure, with the same slice-length contracts
//! as the scalar kernels.
//!
//! # Numerics contract
//!
//! Identical to the AVX2 table (`simd.rs`): per-element kernels use
//! separate mul/add (no FMA contraction — bitwise the scalar table), the
//! dot reduces paired-lane partials + tail in f64, and GELU's tanh runs
//! the same Cephes-style polynomial exp (mirrored by
//! `python/tests/test_native_kernels.py`).

// Cephes coefficients are quoted at full precision; index loops mirror the
// scalar reference bodies one-to-one.
#![allow(clippy::excessive_precision, clippy::needless_range_loop)]

use core::arch::aarch64::*;

use super::{Kernels, GELU_A, GELU_C};

/// The NEON table, handed out by `kernels::simd_table()` on aarch64.
pub static NEON: Kernels = Kernels {
    name: "simd",
    isa: "neon",
    axpy,
    dot,
    gate_mul,
    gelu_fwd,
    butterfly_pass,
    spec_mul,
    spec_mul_conj,
};

fn axpy(y: &mut [f32], w: &[f32], a: f32) {
    assert_eq!(y.len(), w.len(), "axpy length mismatch");
    let n = y.len();
    let (yp, wp) = (y.as_mut_ptr(), w.as_ptr());
    let mut i = 0usize;
    // SAFETY: NEON is baseline on aarch64; indices are in-bounds.
    unsafe {
        let av = vdupq_n_f32(a);
        while i + 4 <= n {
            let yv = vld1q_f32(yp.add(i));
            let wv = vld1q_f32(wp.add(i));
            // mul + add, not FMA: bitwise the scalar `y[o] += a * w[o]`.
            vst1q_f32(yp.add(i), vaddq_f32(yv, vmulq_f32(av, wv)));
            i += 4;
        }
    }
    while i < n {
        y[i] += a * w[i];
        i += 1;
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    let n = a.len();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut l0 = [0.0f32; 4];
    let mut l1 = [0.0f32; 4];
    let mut i = 0usize;
    // SAFETY: NEON is baseline on aarch64; indices are in-bounds.
    unsafe {
        // Paired-lane accumulation: two 4-lane partials.
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        while i + 8 <= n {
            let p0 = vmulq_f32(vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            let p1 = vmulq_f32(vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
            acc0 = vaddq_f32(acc0, p0);
            acc1 = vaddq_f32(acc1, p1);
            i += 8;
        }
        if i + 4 <= n {
            let p = vmulq_f32(vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            acc0 = vaddq_f32(acc0, p);
            i += 4;
        }
        vst1q_f32(l0.as_mut_ptr(), acc0);
        vst1q_f32(l1.as_mut_ptr(), acc1);
    }
    // Reduce lane partials and the tail in f64 (f64-accumulation audit).
    let mut s = 0.0f64;
    for k in 0..4 {
        s += l0[k] as f64;
        s += l1[k] as f64;
    }
    while i < n {
        s += a[i] as f64 * b[i] as f64;
        i += 1;
    }
    s as f32
}

fn gate_mul(out: &mut [f32], c: &[f32], gate: &[f32], stride: usize) {
    assert_eq!(out.len(), c.len(), "gate_mul length mismatch");
    assert!(
        out.is_empty() || (out.len() - 1) * stride < gate.len(),
        "gate_mul gate column out of bounds"
    );
    let n = out.len();
    let (op, cp) = (out.as_mut_ptr(), c.as_ptr());
    let mut i = 0usize;
    // SAFETY: NEON is baseline on aarch64; indices are in-bounds.
    unsafe {
        if stride == 1 {
            while i + 4 <= n {
                let g = vld1q_f32(gate.as_ptr().add(i));
                let cv = vld1q_f32(cp.add(i));
                vst1q_f32(op.add(i), vmulq_f32(g, cv));
                i += 4;
            }
        } else {
            let mut buf = [0.0f32; 4];
            while i + 4 <= n {
                for (j, slot) in buf.iter_mut().enumerate() {
                    *slot = gate[(i + j) * stride];
                }
                let g = vld1q_f32(buf.as_ptr());
                let cv = vld1q_f32(cp.add(i));
                vst1q_f32(op.add(i), vmulq_f32(g, cv));
                i += 4;
            }
        }
    }
    while i < n {
        out[i] = gate[i * stride] * c[i];
        i += 1;
    }
}

fn spec_mul(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    spec_mul_impl(a_re, a_im, b_re, b_im, p_re, p_im, false);
}

fn spec_mul_conj(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    spec_mul_impl(a_re, a_im, b_re, b_im, p_re, p_im, true);
}

fn spec_mul_impl(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
    conj: bool,
) {
    let n = p_re.len();
    // Unconditional: the vector body below uses raw pointers.
    assert!(
        p_im.len() == n
            && a_re.len() >= n
            && a_im.len() >= n
            && b_re.len() >= n
            && b_im.len() >= n,
        "spec_mul length mismatch"
    );
    let mut k = 0usize;
    // SAFETY: NEON is baseline on aarch64; indices are in-bounds.
    unsafe {
        while k + 4 <= n {
            let ar = vld1q_f32(a_re.as_ptr().add(k));
            let ai = vld1q_f32(a_im.as_ptr().add(k));
            let br = vld1q_f32(b_re.as_ptr().add(k));
            let bi = vld1q_f32(b_im.as_ptr().add(k));
            let rr = vmulq_f32(ar, br);
            let ii = vmulq_f32(ai, bi);
            let ri = vmulq_f32(ar, bi);
            let ir = vmulq_f32(ai, br);
            let (pr, pi) = if conj {
                (vaddq_f32(rr, ii), vsubq_f32(ri, ir))
            } else {
                (vsubq_f32(rr, ii), vaddq_f32(ri, ir))
            };
            vst1q_f32(p_re.as_mut_ptr().add(k), pr);
            vst1q_f32(p_im.as_mut_ptr().add(k), pi);
            k += 4;
        }
    }
    while k < n {
        if conj {
            p_re[k] = a_re[k] * b_re[k] + a_im[k] * b_im[k];
            p_im[k] = a_re[k] * b_im[k] - a_im[k] * b_re[k];
        } else {
            p_re[k] = a_re[k] * b_re[k] - a_im[k] * b_im[k];
            p_im[k] = a_re[k] * b_im[k] + a_im[k] * b_re[k];
        }
        k += 1;
    }
}

fn butterfly_pass(
    re: &mut [f32],
    im: &mut [f32],
    tw_re: &[f32],
    tw_im: &[f32],
    len: usize,
    inverse: bool,
) {
    let nn = re.len();
    assert_eq!(im.len(), nn, "butterfly re/im length mismatch");
    assert!(len >= 2 && len <= nn && nn % len == 0, "butterfly span {len} invalid for n={nn}");
    assert!(
        tw_re.len() >= nn / 2 && tw_im.len() >= nn / 2,
        "butterfly twiddle table too short"
    );
    if len / 2 < 4 {
        super::scalar::butterfly_pass(re, im, tw_re, tw_im, len, inverse);
        return;
    }
    let n = re.len();
    let step = n / len;
    let half = len / 2;
    let sign = if inverse { -1.0f32 } else { 1.0f32 };
    let (rp, ip) = (re.as_mut_ptr(), im.as_mut_ptr());
    let mut wr_buf = [0.0f32; 4];
    let mut wi_buf = [0.0f32; 4];
    let mut start = 0usize;
    while start < n {
        let mut k = 0usize;
        while k + 4 <= half {
            for j in 0..4 {
                wr_buf[j] = tw_re[(k + j) * step];
                wi_buf[j] = sign * tw_im[(k + j) * step];
            }
            // SAFETY: NEON is baseline on aarch64; b = a + half ≥ a + 4,
            // so the two 4-lane windows are disjoint and in-bounds.
            unsafe {
                let wr = vld1q_f32(wr_buf.as_ptr());
                let wi = vld1q_f32(wi_buf.as_ptr());
                let a = start + k;
                let b = a + half;
                let rb = vld1q_f32(rp.add(b));
                let ib = vld1q_f32(ip.add(b));
                let tr = vsubq_f32(vmulq_f32(rb, wr), vmulq_f32(ib, wi));
                let ti = vaddq_f32(vmulq_f32(rb, wi), vmulq_f32(ib, wr));
                let ra = vld1q_f32(rp.add(a));
                let ia = vld1q_f32(ip.add(a));
                vst1q_f32(rp.add(b), vsubq_f32(ra, tr));
                vst1q_f32(ip.add(b), vsubq_f32(ia, ti));
                vst1q_f32(rp.add(a), vaddq_f32(ra, tr));
                vst1q_f32(ip.add(a), vaddq_f32(ia, ti));
            }
            k += 4;
        }
        while k < half {
            let wr = tw_re[k * step];
            let wi = if inverse { -tw_im[k * step] } else { tw_im[k * step] };
            let a = start + k;
            let b = a + half;
            let tr = re[b] * wr - im[b] * wi;
            let ti = re[b] * wi + im[b] * wr;
            re[b] = re[a] - tr;
            im[b] = im[a] - ti;
            re[a] += tr;
            im[a] += ti;
            k += 1;
        }
        start += len;
    }
}

// -- polynomial exp / tanh (same constants as simd.rs) ----------------------

const EXP_HI: f32 = 88.3762626647950;
const EXP_LO: f32 = -88.3762626647949;
const LOG2EF: f32 = 1.44269504088896341;
const EXP_C1: f32 = 0.693359375;
const EXP_C2: f32 = -2.12194440e-4;
const EXP_P0: f32 = 1.9875691500e-4;
const EXP_P1: f32 = 1.3981999507e-3;
const EXP_P2: f32 = 8.3334519073e-3;
const EXP_P3: f32 = 4.1665795894e-2;
const EXP_P4: f32 = 1.6666665459e-1;
const EXP_P5: f32 = 5.0000001201e-1;

/// Cephes-style polynomial exp on 4 lanes (see `simd.rs` for the scheme).
///
/// # Safety
/// NEON only (baseline on aarch64).
unsafe fn exp_neon(x: float32x4_t) -> float32x4_t {
    let one = vdupq_n_f32(1.0);
    let x = vminq_f32(vdupq_n_f32(EXP_HI), vmaxq_f32(vdupq_n_f32(EXP_LO), x));
    let fx = vrndmq_f32(vaddq_f32(vmulq_f32(x, vdupq_n_f32(LOG2EF)), vdupq_n_f32(0.5)));
    let r = vsubq_f32(
        vsubq_f32(x, vmulq_f32(fx, vdupq_n_f32(EXP_C1))),
        vmulq_f32(fx, vdupq_n_f32(EXP_C2)),
    );
    let r2 = vmulq_f32(r, r);
    let mut y = vdupq_n_f32(EXP_P0);
    y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EXP_P1));
    y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EXP_P2));
    y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EXP_P3));
    y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EXP_P4));
    y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EXP_P5));
    y = vaddq_f32(vaddq_f32(vmulq_f32(y, r2), r), one);
    let n = vcvtq_s32_f32(fx); // fx is an exact integer after floor
    let pow2n = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(n, vdupq_n_s32(127))));
    vmulq_f32(y, pow2n)
}

/// `tanh(x) = sign(x)·(1 − 2/(e^{2|x|}+1))` on 4 lanes.
///
/// # Safety
/// NEON only (baseline on aarch64).
unsafe fn tanh_neon(x: float32x4_t) -> float32x4_t {
    let sign_mask = vdupq_n_u32(0x8000_0000);
    let bits = vreinterpretq_u32_f32(x);
    let sign = vandq_u32(bits, sign_mask);
    let ax = vabsq_f32(x);
    let e = exp_neon(vaddq_f32(ax, ax));
    let one = vdupq_n_f32(1.0);
    let two = vdupq_n_f32(2.0);
    let t = vsubq_f32(one, vdivq_f32(two, vaddq_f32(e, one)));
    vreinterpretq_f32_u32(vorrq_u32(vreinterpretq_u32_f32(t), sign))
}

fn gelu_fwd(x: &[f32], y: &mut [f32], th: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "gelu length mismatch");
    assert_eq!(x.len(), th.len(), "gelu length mismatch");
    let n = x.len();
    let (xp, yp, tp) = (x.as_ptr(), y.as_mut_ptr(), th.as_mut_ptr());
    let mut i = 0usize;
    // SAFETY: NEON is baseline on aarch64; indices are in-bounds.
    unsafe {
        let c = vdupq_n_f32(GELU_C);
        let a = vdupq_n_f32(GELU_A);
        let half = vdupq_n_f32(0.5);
        let one = vdupq_n_f32(1.0);
        while i + 4 <= n {
            let v = vld1q_f32(xp.add(i));
            let v3 = vmulq_f32(vmulq_f32(v, v), v);
            let inner = vmulq_f32(c, vaddq_f32(v, vmulq_f32(a, v3)));
            let t = tanh_neon(inner);
            vst1q_f32(tp.add(i), t);
            vst1q_f32(yp.add(i), vmulq_f32(vmulq_f32(half, v), vaddq_f32(one, t)));
            i += 4;
        }
    }
    while i < n {
        let v = x[i];
        let t = (GELU_C * (v + GELU_A * v * v * v)).tanh();
        th[i] = t;
        y[i] = 0.5 * v * (1.0 + t);
        i += 1;
    }
}
