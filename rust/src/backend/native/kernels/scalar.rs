//! Scalar reference kernels — the engine's pre-subsystem inner loops,
//! moved here **verbatim** (DESIGN.md §Kernels).
//!
//! These bodies are the single source of the scalar path's numerics: the
//! dispatch table routes the same call sites that used to inline these
//! loops, so `HYENA_KERNEL=scalar` reproduces the pre-subsystem engine
//! bitwise (pinned by the tests in `kernels/mod.rs` against inlined copies
//! of the original loops, and end-to-end by the thread-invariance and
//! serving equality tests). Do not "improve" the arithmetic here — any
//! reassociation breaks that contract; put fast variants in the SIMD
//! tables instead.

// The index-based loops are the verbatim pre-subsystem bodies; iterator
// rewrites would obscure the bitwise-pinning contract. The reference table
// is `unsafe`-free by construction (kernel-subsystem unsafe policy:
// intrinsics live only in simd.rs/neon.rs).
#![forbid(unsafe_code)]
#![allow(clippy::needless_range_loop)]

use super::{GELU_A, GELU_C};

/// `y[i] += a · w[i]` — `dense_fwd_into`/`dense_bwd_dw_into` inner block
/// and the recurrence bias update, verbatim.
pub fn axpy(y: &mut [f32], w: &[f32], a: f32) {
    debug_assert_eq!(y.len(), w.len());
    for o in 0..y.len() {
        y[o] += a * w[o];
    }
}

/// `Σ_i a[i]·b[i]` — `dense_bwd_dx_into` inner reduction and
/// `causal_dot_step`, verbatim (serial f32 accumulation in index order).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for o in 0..a.len() {
        acc += a[o] * b[o];
    }
    acc
}

/// `out[t] = gate[t·stride] · c[t]` — the mixer gating elementwise op,
/// verbatim (the gate column lives strided inside the projection rows).
pub fn gate_mul(out: &mut [f32], c: &[f32], gate: &[f32], stride: usize) {
    debug_assert_eq!(out.len(), c.len());
    debug_assert!(out.len() == 0 || (out.len() - 1) * stride < gate.len());
    for t in 0..out.len() {
        out[t] = gate[t * stride] * c[t];
    }
}

/// Tanh-approximate GELU forward over one contiguous chunk — the
/// `gelu_fwd_into` element body, verbatim. Writes `y` and the cached tanh.
pub fn gelu_fwd(x: &[f32], y: &mut [f32], th: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), th.len());
    for i in 0..x.len() {
        let v = x[i];
        let t = (GELU_C * (v + GELU_A * v * v * v)).tanh();
        th[i] = t;
        y[i] = 0.5 * v * (1.0 + t);
    }
}

/// One radix-2 butterfly stage — the `Fft::run` stage body, verbatim.
/// At stage `len`, butterfly `k` uses twiddle `w_{k·(n/len)}`; `inverse`
/// conjugates it.
pub fn butterfly_pass(
    re: &mut [f32],
    im: &mut [f32],
    tw_re: &[f32],
    tw_im: &[f32],
    len: usize,
    inverse: bool,
) {
    let n = re.len();
    debug_assert_eq!(im.len(), n);
    let step = n / len;
    let half = len / 2;
    let mut start = 0usize;
    while start < n {
        for k in 0..half {
            let wr = tw_re[k * step];
            let wi = if inverse { -tw_im[k * step] } else { tw_im[k * step] };
            let a = start + k;
            let b = a + half;
            let tr = re[b] * wr - im[b] * wi;
            let ti = re[b] * wi + im[b] * wr;
            re[b] = re[a] - tr;
            im[b] = im[a] - ti;
            re[a] += tr;
            im[a] += ti;
        }
        start += len;
    }
}

/// Pointwise half-spectrum product `P = A·B` — the `conv_spec_slices_into`
/// inner loop, verbatim.
pub fn spec_mul(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    for k in 0..p_re.len() {
        p_re[k] = a_re[k] * b_re[k] - a_im[k] * b_im[k];
        p_im[k] = a_re[k] * b_im[k] + a_im[k] * b_re[k];
    }
}

/// Pointwise half-spectrum product `P = conj(A)·B` — the
/// `corr_spec_slices_into` inner loop, verbatim.
pub fn spec_mul_conj(
    a_re: &[f32],
    a_im: &[f32],
    b_re: &[f32],
    b_im: &[f32],
    p_re: &mut [f32],
    p_im: &mut [f32],
) {
    for k in 0..p_re.len() {
        p_re[k] = a_re[k] * b_re[k] + a_im[k] * b_im[k];
        p_im[k] = a_re[k] * b_im[k] - a_im[k] * b_re[k];
    }
}
