//! `NativeBackend`: the pure-Rust evaluation path for Hyena LMs.
//!
//! Runs the full operator end-to-end — implicit filter FFN, short conv, FFT
//! long conv, gating, embedding/head, training and decoding — with zero
//! Python/XLA/PJRT dependencies, so every coordinator feature (trainer,
//! dynamic-batching server, few-shot harness, examples) works on a bare
//! container. Artifact directories remain the unit of addressing: pointing
//! the native backend at an artifact dir reuses its `manifest.json` config;
//! pointing it at a name with no artifacts resolves a built-in config
//! (DESIGN.md §1/§2).

pub mod config;
pub mod kernels;
pub mod model;

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::backend::{Backend, DecodeSession, MemReport};
use crate::metrics::flops::{flops_per_step, flops_per_token, FlopShape};
use crate::runtime::manifest::ParamSpec;
use crate::runtime::tensor::DType;
use crate::runtime::{Manifest, Tensor};

pub use config::NativeConfig;
pub use model::{DecodeState, NativeModel};

/// A native model plus the synthesized manifest that makes it
/// indistinguishable from an artifact-backed model to the coordinator.
pub struct NativeBackend {
    model: NativeModel,
    manifest: Manifest,
}

impl NativeBackend {
    /// Load from an artifact directory (reusing its `manifest.json` config)
    /// or, when the directory has no manifest, from the built-in config
    /// matching the directory's final path component.
    pub fn load(dir: &Path, seed: i32) -> Result<NativeBackend> {
        let cfg = if dir.join("manifest.json").exists() {
            NativeConfig::from_manifest(&Manifest::load(dir)?)?
        } else {
            let name = dir.file_name().and_then(|s| s.to_str()).unwrap_or_default();
            NativeConfig::builtin(name).ok_or_else(|| {
                anyhow!(
                    "no artifact at {} and no built-in native config named {name:?} \
                     (built-ins: {})",
                    dir.display(),
                    NativeConfig::builtin_names().join(", ")
                )
            })?
        };
        NativeBackend::from_config(cfg, dir, seed)
    }

    /// Build from an explicit config (tests, sweeps).
    pub fn from_config(cfg: NativeConfig, dir: &Path, seed: i32) -> Result<NativeBackend> {
        let model = NativeModel::new(cfg, seed)?;
        let manifest = synthesize_manifest(&model, dir);
        Ok(NativeBackend { model, manifest })
    }

    /// The underlying model (native-only call sites, e.g. the FFT bench).
    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Mutable access to the model (thread-count sweeps in benches/tests;
    /// see [`NativeModel::set_threads`]).
    pub fn model_mut(&mut self) -> &mut NativeModel {
        &mut self.model
    }

    /// One engine step for [`Backend::decode_step`], after `token` has been
    /// appended to the session: streams against the resident state, or
    /// rebuilds it from the session's tokens when stale or missing.
    fn step_session(
        &self,
        sess: &mut DecodeSession,
        token: i32,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        // Streaming fast path: one O(L) step against the session state.
        let streamed = match sess.ext_mut::<DecodeState>() {
            Some(state) if !self.model.decode_state_stale(state) => {
                self.model.decode_step_into(state, token, logits)?;
                true
            }
            _ => false,
        };
        if !streamed {
            // Stale (a parameter update landed mid-session) or missing
            // state: rebuild it from the session's tokens — prefill the
            // prefix, then stream the new token through the fresh state.
            if let Some(old) = sess.take_ext::<DecodeState>() {
                self.model.decode_end_state(*old);
            }
            let prefix = &sess.tokens[..sess.tokens.len() - 1];
            let mut state = self.model.decode_begin_state(prefix, logits)?;
            self.model.decode_step_into(&mut state, token, logits)?;
            sess.set_ext(Box::new(state));
        }
        Ok(())
    }
}

/// Build a [`Manifest`] equivalent to what `python/compile/aot.py` would
/// record for this config, so manifest consumers (trainer token accounting,
/// decode shapes, checkpoint validation, FLOP reporting) work unchanged.
fn synthesize_manifest(model: &NativeModel, dir: &Path) -> Manifest {
    let cfg = &model.cfg;
    let shape = FlopShape {
        depth: cfg.depth,
        width: cfg.width,
        seqlen: cfg.seqlen,
        vocab: cfg.vocab,
        mlp_ratio: cfg.mlp_ratio,
        order: cfg.order,
        short_filter: cfg.short_filter,
        is_attention: false,
    };
    let params: Vec<ParamSpec> = model
        .layout
        .entries
        .iter()
        .map(|e| ParamSpec { name: e.name.clone(), shape: e.shape.clone(), dtype: DType::F32 })
        .collect();
    let filter_params = params
        .iter()
        .filter(|p| p.name.starts_with("blocks.0.mixer.filter."))
        .map(|p| p.name.clone())
        .collect();
    Manifest {
        name: cfg.name.clone(),
        dir: dir.to_path_buf(),
        param_count: model.layout.total,
        flops_per_step: Some(flops_per_step(&shape, cfg.batch)),
        flops_per_token: Some(flops_per_token(&shape)),
        has_train_step: true,
        has_filters: true,
        filter_params,
        config: cfg.config_json(),
        params,
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn step(&self) -> u64 {
        self.model.step
    }

    fn set_step(&mut self, step: u64) {
        self.model.step = step;
    }

    fn reinit(&mut self, seed: i32) -> Result<()> {
        self.model.init(seed);
        Ok(())
    }

    fn train_step(&mut self, batch: &[Tensor]) -> Result<f32> {
        if batch.len() != 3 {
            bail!(
                "native train_step wants [tokens, targets, mask], got {} tensors",
                batch.len()
            );
        }
        let (tokens, targets, mask) = (&batch[0], &batch[1], &batch[2]);
        let shape = tokens.shape();
        if shape.len() != 2 || shape[1] != self.model.cfg.seqlen {
            bail!(
                "native train_step wants tokens (B, {}), got {:?}",
                self.model.cfg.seqlen,
                shape
            );
        }
        let b = shape[0];
        let (tok, tgt, mk) = (tokens.as_i32()?, targets.as_i32()?, mask.as_f32()?);
        if tgt.len() != tok.len() || mk.len() != tok.len() {
            bail!(
                "native train_step wants targets/mask of {} elements, got {}/{}",
                tok.len(),
                tgt.len(),
                mk.len()
            );
        }
        self.model.train_step(tok, tgt, mk, b)
    }

    fn forward(&self, inputs: &[Tensor]) -> Result<Tensor> {
        let tokens = inputs
            .first()
            .ok_or_else(|| anyhow!("native forward wants a tokens tensor"))?;
        let shape = tokens.shape();
        if shape.len() != 2 || shape[1] != self.model.cfg.seqlen {
            bail!(
                "native forward wants tokens (B, {}), got {:?}",
                self.model.cfg.seqlen,
                shape
            );
        }
        let b = shape[0];
        let (logits, _cache) = self.model.forward_cached(tokens.as_i32()?, b)?;
        Tensor::from_f32(&[b, self.model.cfg.seqlen, self.model.cfg.vocab], logits)
    }

    fn infer(&self, tokens: &[i32], rows: usize, l: usize) -> Result<Tensor> {
        let (logits, _bucket) = self.model.forward_infer(tokens, rows, l)?;
        Tensor::from_f32(&[rows, l, self.model.cfg.vocab], logits)
    }

    fn decode_begin(&self, prompt: &[i32], logits: &mut Vec<f32>) -> Result<DecodeSession> {
        let state = self.model.decode_begin_state(prompt, logits)?;
        let mut sess = DecodeSession::new(prompt);
        sess.set_ext(Box::new(state));
        Ok(sess)
    }

    fn decode_step(
        &self,
        sess: &mut DecodeSession,
        token: i32,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let full = self.model.max_context();
        if sess.len() >= full {
            bail!("decode session is at the context edge (length {full})");
        }
        sess.tokens.push(token);
        match self.step_session(sess, token, logits) {
            Ok(()) => {
                sess.steps += 1;
                Ok(())
            }
            Err(e) => {
                // The token was not consumed by the engine; keep the
                // session's history consistent with its state.
                sess.tokens.pop();
                Err(e)
            }
        }
    }

    fn decode_step_batch(
        &self,
        sessions: &mut [&mut DecodeSession],
        tokens: &[i32],
        logits: &mut Vec<f32>,
    ) -> Vec<Result<()>> {
        assert_eq!(
            sessions.len(),
            tokens.len(),
            "decode_step_batch wants one token per session"
        );
        // HYENA_PROF hook: one timer per batched round. Lives here (not in
        // the coordinator) so direct backend drivers — the obs bench — see
        // the same accounting the serving loop does.
        let prof_t0 = if crate::obs::prof::enabled() {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let full = self.model.max_context();
        let v = self.model.cfg.vocab;
        let rows = sessions.len();

        // Common case: every row is fresh and in sync. Run the whole round
        // as one engine call writing **directly into `logits`** — no
        // intermediate packed buffer, no per-row copy; the only per-round
        // heap traffic is two rows-sized pointer Vecs (the engine's own
        // scratch is arena-pinned).
        let all_fast = sessions.iter_mut().all(|s| {
            let len = s.len();
            len < full
                && s
                    .ext_mut::<DecodeState>()
                    .map_or(false, |st| st.pos() == len && !self.model.decode_state_stale(st))
        });
        if all_fast {
            let mut states: Vec<Box<DecodeState>> = sessions
                .iter_mut()
                .map(|s| s.take_ext::<DecodeState>().expect("probed fast above"))
                .collect();
            let res = {
                let mut refs: Vec<&mut DecodeState> =
                    states.iter_mut().map(|b| &mut **b).collect();
                self.model.decode_step_batch_into(&mut refs, tokens, logits)
            };
            match res {
                Ok(()) => {
                    for (i, state) in states.into_iter().enumerate() {
                        sessions[i].tokens.push(tokens[i]);
                        sessions[i].steps += 1;
                        sessions[i].set_ext(state);
                    }
                    if let Some(t0) = prof_t0 {
                        crate::obs::prof::DECODE_BATCH.record(t0.elapsed().as_nanos() as u64);
                    }
                    return (0..rows).map(|_| Ok(())).collect();
                }
                Err(_) => {
                    // Unexpected (rows were probed); restore the states and
                    // attribute errors per row through the general path.
                    for (i, state) in states.into_iter().enumerate() {
                        sessions[i].set_ext(state);
                    }
                }
            }
        }

        let mut results: Vec<Option<Result<()>>> = Vec::with_capacity(rows);
        results.resize_with(rows, || None);
        logits.clear();
        logits.resize(rows * v, 0.0);

        // Partition the round: sessions with fresh in-sync streaming state
        // take the batched fast path; stale/missing-state sessions (and
        // window-edge rows, which fail) go through the serial step, which
        // transparently rebuilds state from the session's tokens.
        let mut fast_ix: Vec<usize> = Vec::new();
        let mut fast_states: Vec<Box<DecodeState>> = Vec::new();
        let mut fast_toks: Vec<i32> = Vec::new();
        let mut slow_ix: Vec<usize> = Vec::new();
        for (i, sess) in sessions.iter_mut().enumerate() {
            if sess.len() >= full {
                results[i] = Some(Err(anyhow!(
                    "decode session is at the context edge (length {full})"
                )));
                continue;
            }
            match sess.take_ext::<DecodeState>() {
                Some(state)
                    if !self.model.decode_state_stale(&state)
                        && state.pos() == sess.len() =>
                {
                    fast_ix.push(i);
                    fast_toks.push(tokens[i]);
                    fast_states.push(state);
                }
                Some(state) => {
                    // Stale (or out of sync): release it now; the serial
                    // path re-prefills from the session's tokens.
                    self.model.decode_end_state(*state);
                    slow_ix.push(i);
                }
                None => slow_ix.push(i),
            }
        }

        if !fast_ix.is_empty() {
            let mut packed = Vec::new();
            let batch_res = {
                let mut refs: Vec<&mut DecodeState> =
                    fast_states.iter_mut().map(|b| &mut **b).collect();
                self.model.decode_step_batch_into(&mut refs, &fast_toks, &mut packed)
            };
            match batch_res {
                Ok(()) => {
                    for (j, state) in fast_states.into_iter().enumerate() {
                        let i = fast_ix[j];
                        sessions[i].tokens.push(fast_toks[j]);
                        sessions[i].steps += 1;
                        sessions[i].set_ext(state);
                        logits[i * v..(i + 1) * v]
                            .copy_from_slice(&packed[j * v..(j + 1) * v]);
                        results[i] = Some(Ok(()));
                    }
                }
                Err(_) => {
                    // Unexpected batch-level failure (the rows were
                    // pre-validated): restore the states untouched and let
                    // the serial path attribute errors per session.
                    for (j, state) in fast_states.into_iter().enumerate() {
                        sessions[fast_ix[j]].set_ext(state);
                        slow_ix.push(fast_ix[j]);
                    }
                }
            }
        }

        let mut row = Vec::new();
        for &i in &slow_ix {
            let res = self.decode_step(&mut *sessions[i], tokens[i], &mut row);
            if res.is_ok() {
                logits[i * v..(i + 1) * v].copy_from_slice(&row);
            }
            results[i] = Some(res);
        }
        if let Some(t0) = prof_t0 {
            crate::obs::prof::DECODE_BATCH.record(t0.elapsed().as_nanos() as u64);
        }
        results
            .into_iter()
            .map(|r| r.expect("every decode_step_batch row is resolved"))
            .collect()
    }

    fn decode_end(&self, mut sess: DecodeSession) {
        if let Some(state) = sess.take_ext::<DecodeState>() {
            self.model.decode_end_state(*state);
        }
    }

    fn serve_buckets(&self) -> Vec<usize> {
        self.model.bucket_lens()
    }

    fn set_serve_buckets(&mut self, levels: usize) -> Result<()> {
        self.model.set_bucket_levels(levels);
        Ok(())
    }

    fn decode_window(&self) -> usize {
        self.model.max_context()
    }

    fn set_max_context(&mut self, n: usize) -> Result<()> {
        self.model.set_max_context(n)
    }

    fn mem_report(&self) -> Option<MemReport> {
        let train = self.model.train_arena_stats();
        let serve = self.model.serve_stats();
        Some(MemReport {
            train_arena_hiwater_bytes: train.hiwater_bytes,
            train_arena_allocs: train.allocs,
            serve_arena_hiwater_bytes: serve.arena.hiwater_bytes,
            serve_arena_allocs: serve.arena.allocs,
            serve_spec_bytes: serve.spec_bytes,
            serve_forwards: serve.forwards,
            bucket_lens: serve.bucket_lens,
            bucket_hits: serve.bucket_hits,
            decode_sessions_live: serve.decode_sessions_live,
            decode_sessions_total: serve.decode_sessions_total,
            decode_steps: serve.decode_steps,
            decode_step_batches: serve.decode_step_batches,
            decode_step_batch_rows: serve.decode_step_batch_rows,
            decode_state_bytes: serve.decode_state_bytes,
            max_context: serve.max_context,
            ext_bucket_lens: serve.ext_bucket_lens,
            prefill_chunked: serve.prefill_chunked,
            prefill_chunks: serve.prefill_chunks,
            prefill_chunk_bytes: serve.prefill_chunk_bytes,
            params_epoch: serve.params_epoch,
            kernel: kernels::active_name().to_string(),
        })
    }

    fn dump_filters(&self) -> Result<Tensor> {
        let cfg = &self.model.cfg;
        Tensor::from_f32(&[cfg.order, cfg.width, cfg.seqlen], self.model.filters_block0())
    }

    fn params_host(&self) -> Result<Vec<Tensor>> {
        self.model
            .layout
            .entries
            .iter()
            .map(|e| Tensor::from_f32(&e.shape, self.model.params[e.range()].to_vec()))
            .collect()
    }

    fn set_params(&mut self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.model.layout.entries.len() {
            bail!(
                "param count mismatch: got {}, layout has {}",
                tensors.len(),
                self.model.layout.entries.len()
            );
        }
        for (e, t) in self.model.layout.entries.clone().iter().zip(tensors) {
            if t.shape() != e.shape.as_slice() {
                bail!("param {}: shape {:?} != layout {:?}", e.name, t.shape(), e.shape);
            }
            self.model.params[e.range()].copy_from_slice(t.as_f32()?);
        }
        // Serving caches key off the params epoch; a restore is an
        // out-of-band parameter change.
        self.model.note_params_changed();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn backend(name: &str) -> NativeBackend {
        NativeBackend::load(&PathBuf::from("artifacts").join(name), 0).unwrap()
    }

    #[test]
    fn load_resolves_builtin_without_artifacts() {
        let b = backend("golden_tiny");
        let m = b.manifest();
        assert_eq!(m.name, "golden_tiny");
        assert_eq!(m.params.len(), 27);
        assert_eq!(m.numel(), 16320);
        assert_eq!(m.param_count, 16320);
        assert_eq!(m.batch().unwrap(), 2);
        assert_eq!(m.seqlen().unwrap(), 16);
        assert_eq!(m.vocab().unwrap(), 32);
        assert_eq!(m.family(), "lm");
        assert!(m.has_train_step);
        assert!(m.has_filters);
        assert!(m.flops_per_step.unwrap() > 0.0);
        assert!(!m.filter_params.is_empty());
    }

    #[test]
    fn load_rejects_unknown_name() {
        let err = NativeBackend::load(&PathBuf::from("artifacts/nope_model"), 0).unwrap_err();
        assert!(format!("{err}").contains("built-in"));
    }

    #[test]
    fn forward_through_trait_has_logits_shape() {
        let b = backend("native_micro");
        let m = b.manifest();
        let (bs, l, v) = (m.batch().unwrap(), m.seqlen().unwrap(), m.vocab().unwrap());
        let tokens = Tensor::from_i32(&[bs, l], vec![1; bs * l]).unwrap();
        let logits = b.forward(&[tokens]).unwrap();
        assert_eq!(logits.shape(), &[bs, l, v]);
    }

    #[test]
    fn params_roundtrip_preserves_forward() {
        let src = backend("native_micro");
        let mut dst = NativeBackend::load(&PathBuf::from("artifacts/native_micro"), 9).unwrap();
        let m = src.manifest().clone();
        let (bs, l) = (m.batch().unwrap(), m.seqlen().unwrap());
        let tokens = Tensor::from_i32(&[bs, l], vec![2; bs * l]).unwrap();
        let want = src.forward(std::slice::from_ref(&tokens)).unwrap();
        // Different seed: forward differs until params are copied over.
        let before = dst.forward(std::slice::from_ref(&tokens)).unwrap();
        assert_ne!(want.as_f32().unwrap(), before.as_f32().unwrap());
        dst.set_params(&src.params_host().unwrap()).unwrap();
        let got = dst.forward(std::slice::from_ref(&tokens)).unwrap();
        assert_eq!(want.as_f32().unwrap(), got.as_f32().unwrap());
    }

    #[test]
    fn train_step_validates_batch_arity() {
        let mut b = backend("native_micro");
        assert!(b.train_step(&[]).is_err());
    }

    #[test]
    fn dump_filters_shape() {
        let b = backend("golden_tiny");
        let h = b.dump_filters().unwrap();
        assert_eq!(h.shape(), &[2, 32, 16]);
    }

    #[test]
    fn decode_step_batch_streams_and_rebuilds_stale_rows() {
        // Through the Backend surface: a batched round must (a) serve
        // fresh rows through the batched fast path, (b) transparently
        // re-prefill a session whose engine state was dropped (the slow
        // path), and (c) keep both token-identical to serial stepping.
        let mut b = backend("golden_tiny");
        let v = b.manifest().vocab().unwrap();
        let mut lg = Vec::new();
        let mut s1 = b.decode_begin(&[1, 2, 3], &mut lg).unwrap();
        let mut s2 = b.decode_begin(&[4, 5, 6, 7], &mut lg).unwrap();
        // Reference twins, stepped serially.
        let mut r1 = b.decode_begin(&[1, 2, 3], &mut lg).unwrap();
        let mut r2 = b.decode_begin(&[4, 5, 6, 7], &mut lg).unwrap();
        let mut packed = Vec::new();
        for round in 0..3 {
            if round == 1 {
                // Make s2's engine state stale mid-stream: a parameter
                // update bumps the epoch for every session equally, so
                // drop s2's state instead — the batch must rebuild it from
                // the session tokens (the None → slow path).
                if let Some(st) = s2.take_ext::<DecodeState>() {
                    b.model().decode_end_state(*st);
                }
            }
            let toks = [(round % 9) as i32, ((round + 3) % 9) as i32];
            let mut want = Vec::new();
            b.decode_step(&mut r1, toks[0], &mut lg).unwrap();
            want.extend_from_slice(&lg);
            b.decode_step(&mut r2, toks[1], &mut lg).unwrap();
            want.extend_from_slice(&lg);
            let results = {
                let mut sessions = [&mut s1, &mut s2];
                b.decode_step_batch(&mut sessions, &toks, &mut packed)
            };
            assert!(results.iter().all(Result::is_ok), "round {round}: {results:?}");
            assert_eq!(packed.len(), 2 * v);
            // The rebuilt row re-prefills through the FFT path, so its
            // logits agree to round-off; the fresh row is bitwise.
            for (ch, (&x, &y)) in packed.iter().zip(want.iter()).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-3 * (1.0 + x.abs().max(y.abs())),
                    "round {round} ch {ch}: batched {x} vs serial {y}"
                );
            }
            assert_eq!(s1.tokens(), r1.tokens());
            assert_eq!(s2.tokens(), r2.tokens());
        }
        let mem = b.mem_report().unwrap();
        assert!(mem.decode_step_batches >= 1, "no batched rounds recorded");
        assert!(mem.decode_step_batch_rows >= 2);
        assert!(mem.kernel == "scalar" || mem.kernel == "simd");
        for s in [s1, s2, r1, r2] {
            b.decode_end(s);
        }
        let mem = b.mem_report().unwrap();
        assert_eq!(mem.decode_sessions_live, 0, "sessions leaked");
        assert_eq!(mem.decode_state_bytes, 0, "state bytes leaked");
    }

    #[test]
    fn mem_report_names_the_active_kernel_table() {
        let b = backend("native_micro");
        let mem = b.mem_report().unwrap();
        assert_eq!(mem.kernel, kernels::active_name());
    }
}
