//! Native-backend model configuration.
//!
//! Mirrors the hyperparameter dictionaries of `python/compile/configs.py`
//! for the configurations the native backend evaluates: decoder-only LMs
//! with the `hyena` mixer and the `implicit` (sine-FFN + decay window)
//! filter parametrization. A config arrives either from an artifact's
//! `manifest.json` (so `--backend native` runs the exact shape an artifact
//! was compiled for) or from the built-in table below (so the native path
//! needs no artifacts at all — DESIGN.md §1/§2).

use anyhow::{bail, Result};

use crate::runtime::Manifest;
use crate::util::json::Json;

/// Hyperparameters of one native Hyena LM (paper Tab. A.1/A.3 scaled down).
#[derive(Debug, Clone)]
pub struct NativeConfig {
    pub name: String,
    // Shape.
    pub depth: usize,
    pub width: usize,
    pub vocab: usize,
    pub seqlen: usize,
    pub batch: usize,
    pub mlp_ratio: f64,
    /// Hyena order N (Def. 3.1).
    pub order: usize,
    /// Depthwise explicit short-conv taps F (Algorithm 1; 0 disables).
    pub short_filter: usize,
    // Implicit filter FFN (Sec. 3.3 / App. D.3).
    pub pe_features: usize,
    pub filter_width: usize,
    pub filter_depth: usize,
    pub sine_freq: f32,
    pub decay_fast: f32,
    pub decay_slow: f32,
    pub window_shift: f32,
    // Optimizer (paper App. A.2 recipe).
    pub lr: f32,
    pub warmup_steps: f64,
    pub total_steps: f64,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    pub grad_clip: f32,
}

impl NativeConfig {
    /// Synthetic-task defaults (`_SYN` in configs.py): 2 layers, width 64.
    fn syn(name: &str, seqlen: usize) -> NativeConfig {
        NativeConfig {
            name: name.to_string(),
            depth: 2,
            width: 64,
            vocab: 64,
            seqlen,
            batch: 16,
            mlp_ratio: 2.0,
            order: 2,
            short_filter: 3,
            pe_features: 8,
            filter_width: 32,
            filter_depth: 4,
            sine_freq: 14.0,
            decay_fast: 0.3,
            decay_slow: 1.5,
            window_shift: 0.01,
            lr: 5e-4,
            warmup_steps: 100.0,
            total_steps: 2000.0,
            weight_decay: 0.1,
            beta1: 0.9,
            beta2: 0.98,
            adam_eps: 1e-8,
            grad_clip: 1.0,
        }
    }

    /// TinyPile LM defaults (`_LM` in configs.py).
    fn lm(name: &str, depth: usize, width: usize) -> NativeConfig {
        NativeConfig {
            depth,
            width,
            vocab: 96,
            seqlen: 256,
            batch: 8,
            mlp_ratio: 4.0,
            filter_width: 64,
            lr: 6e-4,
            ..NativeConfig::syn(name, 256)
        }
    }

    /// Built-in configs runnable with zero artifacts, keyed by artifact name.
    pub fn builtin(name: &str) -> Option<NativeConfig> {
        let cfg = match name {
            // rust↔python golden shape (configs.py `golden_tiny`).
            "golden_tiny" => NativeConfig {
                depth: 1,
                width: 32,
                vocab: 32,
                batch: 2,
                ..NativeConfig::syn(name, 16)
            },
            // Micro shape for fast native tests (native-only addition).
            "native_micro" => NativeConfig {
                depth: 1,
                width: 16,
                vocab: 16,
                batch: 2,
                pe_features: 4,
                filter_width: 8,
                filter_depth: 3,
                ..NativeConfig::syn(name, 8)
            },
            // E1: filter parametrization testbeds (implicit rows).
            "ar_implicit_L128" => NativeConfig::syn(name, 128),
            "ar_implicit_L512" => NativeConfig::syn(name, 512),
            // E2: operator comparison (hyena row).
            "op_hyena_L1024" => NativeConfig { batch: 8, ..NativeConfig::syn(name, 1024) },
            // E3/E4: TinyPile LMs.
            "lm_hyena_s" => NativeConfig::lm(name, 4, 128),
            "lm_hyena_m" => NativeConfig::lm(name, 6, 192),
            "lm_hyena3_wt" => NativeConfig { order: 3, ..NativeConfig::lm(name, 4, 128) },
            // E9: learning arithmetic.
            "arith_d1" | "arith_d2" | "arith_d3" => NativeConfig {
                depth: name.as_bytes()[7] as usize - b'0' as usize,
                vocab: 16,
                batch: 32,
                ..NativeConfig::syn(name, 32)
            },
            _ => return None,
        };
        Some(cfg)
    }

    /// Names accepted by [`NativeConfig::builtin`], for `hyena list`.
    pub fn builtin_names() -> &'static [&'static str] {
        &[
            "golden_tiny",
            "native_micro",
            "ar_implicit_L128",
            "ar_implicit_L512",
            "op_hyena_L1024",
            "lm_hyena_s",
            "lm_hyena_m",
            "lm_hyena3_wt",
            "arith_d1",
            "arith_d2",
            "arith_d3",
        ]
    }

    /// Read a config from an artifact manifest (`config` block of
    /// `manifest.json`). Only LM/hyena/implicit configs are evaluable
    /// natively; anything else is directed to the PJRT backend.
    pub fn from_manifest(man: &Manifest) -> Result<NativeConfig> {
        if man.family() != "lm" {
            bail!(
                "native backend supports family=lm, {} is {:?} (use --backend pjrt)",
                man.name,
                man.family()
            );
        }
        let mixer = man.cfg_str("mixer").unwrap_or("hyena");
        if mixer != "hyena" {
            bail!(
                "native backend implements the hyena mixer, {} uses {mixer:?} \
                 (use --backend pjrt)",
                man.name
            );
        }
        let filter = man.cfg_str("filter_kind").unwrap_or("implicit");
        if filter != "implicit" {
            bail!(
                "native backend implements the implicit filter, {} uses {filter:?} \
                 (use --backend pjrt)",
                man.name
            );
        }
        let f = |key: &str, dflt: f64| man.config.get(key).and_then(Json::as_f64).unwrap_or(dflt);
        let u = |key: &str| man.cfg_usize(key);
        Ok(NativeConfig {
            name: man.name.clone(),
            depth: u("depth")?,
            width: u("width")?,
            vocab: u("vocab")?,
            seqlen: u("seqlen")?,
            batch: u("batch")?,
            mlp_ratio: f("mlp_ratio", 4.0),
            order: f("order", 2.0) as usize,
            short_filter: f("short_filter", 3.0) as usize,
            pe_features: f("pe_features", 8.0) as usize,
            filter_width: f("filter_width", 32.0) as usize,
            filter_depth: f("filter_depth", 4.0) as usize,
            sine_freq: f("sine_freq", 14.0) as f32,
            decay_fast: f("decay_fast", 0.3) as f32,
            decay_slow: f("decay_slow", 1.5) as f32,
            window_shift: f("window_shift", 0.01) as f32,
            lr: f("lr", 6e-4) as f32,
            warmup_steps: f("warmup_steps", 100.0),
            total_steps: f("total_steps", 1000.0),
            weight_decay: f("weight_decay", 0.1) as f32,
            beta1: f("beta1", 0.9) as f32,
            beta2: f("beta2", 0.98) as f32,
            adam_eps: f("adam_eps", 1e-8) as f32,
            grad_clip: f("grad_clip", 1.0) as f32,
        })
    }

    /// MLP hidden width (`int(D * mlp_ratio)` like the Python model).
    pub fn mlp_dim(&self) -> usize {
        (self.width as f64 * self.mlp_ratio) as usize
    }

    /// Filter-FFN input features: `2K + 1` positional-encoding channels.
    pub fn pe_dim(&self) -> usize {
        2 * self.pe_features + 1
    }

    /// Per-layer (fan-in, fan-out) of the filter FFN:
    /// `[pe_dim] + [filter_width]*(filter_depth-1) + [order*width]`.
    pub fn filter_layer_dims(&self) -> Vec<(usize, usize)> {
        let mut sizes = vec![self.pe_dim()];
        for _ in 0..self.filter_depth.saturating_sub(1) {
            sizes.push(self.filter_width);
        }
        sizes.push(self.order * self.width);
        sizes.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// Sanity-check shape parameters before building a model.
    pub fn validate(&self) -> Result<()> {
        if self.depth == 0 || self.width == 0 || self.vocab == 0 {
            bail!("{}: depth/width/vocab must be nonzero", self.name);
        }
        if self.seqlen == 0 || self.batch == 0 {
            bail!("{}: seqlen/batch must be nonzero", self.name);
        }
        if self.order == 0 {
            bail!("{}: hyena order must be ≥ 1", self.name);
        }
        if self.filter_depth == 0 {
            bail!("{}: filter_depth must be ≥ 1", self.name);
        }
        Ok(())
    }

    /// The `config` block of a synthesized manifest (same keys the AOT
    /// pipeline records, so manifest consumers cannot tell the backends
    /// apart — DESIGN.md §2).
    pub fn config_json(&self) -> Json {
        Json::obj(vec![
            ("family", Json::str("lm")),
            ("mixer", Json::str("hyena")),
            ("filter_kind", Json::str("implicit")),
            ("depth", Json::num(self.depth as f64)),
            ("width", Json::num(self.width as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("seqlen", Json::num(self.seqlen as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("mlp_ratio", Json::num(self.mlp_ratio)),
            ("order", Json::num(self.order as f64)),
            ("short_filter", Json::num(self.short_filter as f64)),
            ("pe_features", Json::num(self.pe_features as f64)),
            ("filter_width", Json::num(self.filter_width as f64)),
            ("filter_depth", Json::num(self.filter_depth as f64)),
            ("sine_freq", Json::num(self.sine_freq as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("warmup_steps", Json::num(self.warmup_steps)),
            ("total_steps", Json::num(self.total_steps)),
            ("weight_decay", Json::num(self.weight_decay as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_validate() {
        for name in NativeConfig::builtin_names() {
            let cfg = NativeConfig::builtin(name).expect(name);
            assert_eq!(&cfg.name, name);
            cfg.validate().expect(name);
        }
        assert!(NativeConfig::builtin("lm_attn_wt").is_none());
    }

    #[test]
    fn golden_tiny_matches_python_shape() {
        let c = NativeConfig::builtin("golden_tiny").unwrap();
        assert_eq!((c.depth, c.width, c.vocab, c.seqlen, c.batch), (1, 32, 32, 16, 2));
        assert_eq!(c.order, 2);
        assert_eq!(c.pe_dim(), 17);
        assert_eq!(c.mlp_dim(), 64);
        let dims = c.filter_layer_dims();
        assert_eq!(dims, vec![(17, 32), (32, 32), (32, 32), (32, 64)]);
    }

    #[test]
    fn arith_depth_parses_from_name() {
        assert_eq!(NativeConfig::builtin("arith_d3").unwrap().depth, 3);
        assert_eq!(NativeConfig::builtin("arith_d1").unwrap().depth, 1);
    }

    #[test]
    fn from_manifest_rejects_non_hyena() {
        let man = Manifest {
            name: "t".into(),
            dir: std::path::PathBuf::new(),
            params: vec![],
            config: Json::parse(
                r#"{"family":"lm","mixer":"attn","batch":1,"seqlen":8,
                    "vocab":8,"depth":1,"width":8}"#,
            )
            .unwrap(),
            param_count: 0,
            flops_per_step: None,
            flops_per_token: None,
            has_train_step: false,
            has_filters: false,
            filter_params: vec![],
        };
        assert!(NativeConfig::from_manifest(&man).is_err());
    }
}
