//! Pure-Rust Hyena LM: parameter layout, forward, hand-derived backward,
//! and the AdamW step — the compute core of the native backend.
//!
//! The math mirrors `python/compile/{model,ops,filters,train}.py` exactly
//! (GPT skeleton with the Hyena mixer of Def. 3.1, implicit sine-FFN filters
//! of Sec. 3.3 under an exponential-decay window, masked cross-entropy,
//! AdamW with warmup→cosine LR). The backward pass is hand-derived; every
//! formula here was cross-checked against `jax.grad` of the Python model and
//! against central finite differences (see the gradcheck test at the bottom
//! and EXPERIMENTS.md §Native backend).
//!
//! Tensors are flat `Vec<f32>` in row-major order. Sequence-mixing state
//! uses the channel-major `(B, D, L)` layout of the paper's SISO convolution
//! formulation; everything else is `(B, L, ·)`.
//!
//! **Throughput architecture** (DESIGN.md §Perf). The hot path is organized
//! around three ideas:
//!
//! * A step-scoped [`Scratch`] arena threads reusable buffers through the
//!   whole forward/backward pass — after the first optimizer step the inner
//!   loops allocate nothing (activation caches are recycled into the arena
//!   when the step retires).
//! * Filter spectra (`spec_h`) are computed once per block in `mixer_fwd`
//!   and cached in the block cache, so `mixer_bwd` multiplies cached spectra
//!   instead of re-running an FFT per filter row.
//! * The embarrassingly-parallel loops — (batch × channel) conv rows,
//!   dense-kernel row blocks, filter-spectrum synthesis — run on the
//!   process-wide worker pool ([`crate::util::pool`]). Every parallel loop
//!   partitions its *output* rows and performs per-row arithmetic in the
//!   exact serial order, so results are bitwise identical for any thread
//!   count (pinned by tests here and in `rust/tests/native_e2e.rs`).

// Index-based loops mirror the validated reference math one-to-one (iterator
// rewrites would obscure the correspondence), and backward-pass helpers
// legitimately thread many buffers.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use std::ops::Range;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::backend::fft::{CausalConv, ChunkedCausalConv, ConvWorkspace, PlanBank};
use crate::backend::native::config::NativeConfig;
use crate::backend::native::kernels::{self, GELU_A, GELU_C};
use crate::util::pool::{self, SharedMut, WorkerPool};
use crate::util::rng::Pcg;

// ---------------------------------------------------------------------------
// parameter layout
// ---------------------------------------------------------------------------

/// How one parameter tensor is initialized (mirrors the Python init rules).
#[derive(Debug, Clone, Copy)]
enum Init {
    Zero,
    One,
    /// `normal() * scale`.
    Normal(f32),
    /// `normal() / sqrt(fan_in)`.
    NormalFan(usize),
    /// `uniform(-1/sqrt(fan_in), 1/sqrt(fan_in))` (torch-style dense init).
    UniformFan(usize),
    /// Short-conv taps: `normal() * 0.1`, plus `1.0` on tap 0 so the block
    /// starts near-linear (ops.py `init_hyena`).
    ShortTap,
}

/// One named parameter tensor inside the flat parameter vector.
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    init: Init,
}

impl Entry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn range(&self) -> Range<usize> {
        self.offset..self.offset + self.numel()
    }
}

/// Per-block indices into [`Layout::entries`].
#[derive(Debug, Clone)]
pub struct BlockIx {
    pub ln1_g: usize,
    pub ln1_b: usize,
    pub ln2_g: usize,
    pub ln2_b: usize,
    pub mlp_w1: usize,
    pub mlp_b1: usize,
    pub mlp_w2: usize,
    pub mlp_b2: usize,
    pub proj_w: usize,
    pub proj_b: usize,
    /// Absent when `short_filter == 0`.
    pub short_w: Option<usize>,
    pub out_w: usize,
    pub out_b: usize,
    pub bias: usize,
    pub filt_w: Vec<usize>,
    pub filt_b: Vec<usize>,
}

/// Named indices into [`Layout::entries`].
#[derive(Debug, Clone)]
pub struct Indices {
    pub embed: usize,
    pub pos: usize,
    pub lnf_g: usize,
    pub lnf_b: usize,
    pub head: usize,
    pub blocks: Vec<BlockIx>,
}

/// Flat parameter layout in Python's flattening order (sorted dotted keys),
/// so manifests and checkpoints are interchangeable across backends.
#[derive(Debug, Clone)]
pub struct Layout {
    pub entries: Vec<Entry>,
    pub total: usize,
    pub ix: Indices,
}

impl Layout {
    pub fn new(cfg: &NativeConfig) -> Layout {
        let (d, v, l) = (cfg.width, cfg.vocab, cfg.seqlen);
        let dm = cfg.mlp_dim();
        let c = (cfg.order + 1) * d;
        let n = cfg.order;

        let mut specs: Vec<(String, Vec<usize>, Init)> = vec![
            ("embed".into(), vec![v, d], Init::Normal(0.02)),
            ("pos".into(), vec![l, d], Init::Normal(0.01)),
            ("lnf.g".into(), vec![d], Init::One),
            ("lnf.b".into(), vec![d], Init::Zero),
            ("head".into(), vec![d, v], Init::Normal(0.02)),
        ];
        for i in 0..cfg.depth {
            let p = |suffix: &str| format!("blocks.{i}.{suffix}");
            specs.push((p("ln1.g"), vec![d], Init::One));
            specs.push((p("ln1.b"), vec![d], Init::Zero));
            specs.push((p("ln2.g"), vec![d], Init::One));
            specs.push((p("ln2.b"), vec![d], Init::Zero));
            specs.push((p("mlp.w1"), vec![d, dm], Init::NormalFan(d)));
            specs.push((p("mlp.b1"), vec![dm], Init::Zero));
            specs.push((p("mlp.w2"), vec![dm, d], Init::NormalFan(dm)));
            specs.push((p("mlp.b2"), vec![d], Init::Zero));
            specs.push((p("mixer.proj_w"), vec![d, c], Init::NormalFan(d)));
            specs.push((p("mixer.proj_b"), vec![c], Init::Zero));
            if cfg.short_filter > 0 {
                specs.push((p("mixer.short_w"), vec![c, cfg.short_filter], Init::ShortTap));
            }
            specs.push((p("mixer.out_w"), vec![d, d], Init::NormalFan(d)));
            specs.push((p("mixer.out_b"), vec![d], Init::Zero));
            specs.push((p("mixer.bias"), vec![n, d], Init::Normal(0.2)));
            for (j, (fan_in, fan_out)) in cfg.filter_layer_dims().into_iter().enumerate() {
                specs.push((
                    p(&format!("mixer.filter.w{j}")),
                    vec![fan_in, fan_out],
                    Init::UniformFan(fan_in),
                ));
                specs.push((
                    p(&format!("mixer.filter.b{j}")),
                    vec![fan_out],
                    Init::UniformFan(fan_in),
                ));
            }
        }
        specs.sort_by(|a, b| a.0.cmp(&b.0));

        let mut entries = Vec::with_capacity(specs.len());
        let mut offset = 0usize;
        for (name, shape, init) in specs {
            let numel: usize = shape.iter().product();
            entries.push(Entry { name, shape, offset, init });
            offset += numel;
        }

        let find = |name: &str| -> usize {
            entries
                .iter()
                .position(|e| e.name == name)
                .unwrap_or_else(|| panic!("layout missing {name}"))
        };
        let blocks = (0..cfg.depth)
            .map(|i| {
                let p = |suffix: &str| format!("blocks.{i}.{suffix}");
                BlockIx {
                    ln1_g: find(&p("ln1.g")),
                    ln1_b: find(&p("ln1.b")),
                    ln2_g: find(&p("ln2.g")),
                    ln2_b: find(&p("ln2.b")),
                    mlp_w1: find(&p("mlp.w1")),
                    mlp_b1: find(&p("mlp.b1")),
                    mlp_w2: find(&p("mlp.w2")),
                    mlp_b2: find(&p("mlp.b2")),
                    proj_w: find(&p("mixer.proj_w")),
                    proj_b: find(&p("mixer.proj_b")),
                    short_w: if cfg.short_filter > 0 {
                        Some(find(&p("mixer.short_w")))
                    } else {
                        None
                    },
                    out_w: find(&p("mixer.out_w")),
                    out_b: find(&p("mixer.out_b")),
                    bias: find(&p("mixer.bias")),
                    filt_w: (0..cfg.filter_layer_dims().len())
                        .map(|j| find(&p(&format!("mixer.filter.w{j}"))))
                        .collect(),
                    filt_b: (0..cfg.filter_layer_dims().len())
                        .map(|j| find(&p(&format!("mixer.filter.b{j}"))))
                        .collect(),
                }
            })
            .collect();

        let ix = Indices {
            embed: find("embed"),
            pos: find("pos"),
            lnf_g: find("lnf.g"),
            lnf_b: find("lnf.b"),
            head: find("head"),
            blocks,
        };
        Layout { total: offset, entries, ix }
    }

    pub fn slice<'a>(&self, buf: &'a [f32], ix: usize) -> &'a [f32] {
        &buf[self.entries[ix].range()]
    }
    pub fn slice_mut<'a>(&self, buf: &'a mut [f32], ix: usize) -> &'a mut [f32] {
        &mut buf[self.entries[ix].range()]
    }
}

// ---------------------------------------------------------------------------
// step-scoped workspaces
// ---------------------------------------------------------------------------

/// Snapshot of an [`Arena`]'s accounting, exposed through the train/serve
/// reports so memory regressions show up in benches (ROADMAP "per-step
/// arena high-water metrics").
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Times `take` had to allocate a fresh buffer or grow a reused one.
    /// In steady state this stops increasing — the zero-alloc invariant the
    /// serve-path test pins.
    pub allocs: u64,
    /// High-water mark of checked-out + pooled capacity, in bytes.
    pub hiwater_bytes: usize,
    /// Capacity currently parked in the free pool, in bytes.
    pub pool_bytes: usize,
}

/// Pool of reusable `f32` buffers, reusing capacity LIFO — the phase
/// structure of a train step makes this hit almost every time.
///
/// `take` hands out a buffer with *unspecified contents* (no memset — for
/// outputs the kernels overwrite in full); `take_zeroed` is for the
/// accumulator buffers (`dzs`, `dhfilt`, `grads`) that are built with `+=`.
///
/// The arena tracks its own accounting (fresh/grown allocations, live +
/// pooled capacity high-water) so steady-state allocation behaviour is
/// observable rather than assumed.
#[derive(Default)]
struct Arena {
    free: Vec<Vec<f32>>,
    allocs: u64,
    out_elems: usize,
    pool_elems: usize,
    hiwater_elems: usize,
}

impl Arena {
    fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = match self.free.pop() {
            Some(v) => {
                self.pool_elems = self.pool_elems.saturating_sub(v.capacity());
                v
            }
            None => Vec::new(),
        };
        if v.capacity() < len {
            self.allocs += 1;
        }
        // Only the grown tail is written; any reused prefix keeps stale
        // values by design.
        v.resize(len, 0.0);
        self.out_elems += v.capacity();
        self.hiwater_elems = self.hiwater_elems.max(self.out_elems + self.pool_elems);
        v
    }
    fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take(len);
        v.fill(0.0);
        v
    }
    fn put(&mut self, v: Vec<f32>) {
        self.out_elems = self.out_elems.saturating_sub(v.capacity());
        self.pool_elems += v.capacity();
        self.hiwater_elems = self.hiwater_elems.max(self.out_elems + self.pool_elems);
        self.free.push(v);
    }
    fn stats(&self) -> ArenaStats {
        ArenaStats {
            allocs: self.allocs,
            hiwater_bytes: self.hiwater_elems * std::mem::size_of::<f32>(),
            pool_bytes: self.pool_elems * std::mem::size_of::<f32>(),
        }
    }
}

/// Per-worker convolution scratch: an FFT workspace (with its spectrum
/// pool) plus two length-L real buffers for adjoint intermediates.
struct ConvCtx {
    ws: ConvWorkspace,
    a: Vec<f32>,
    b: Vec<f32>,
}

impl ConvCtx {
    fn new(plan: &CausalConv) -> ConvCtx {
        ConvCtx { ws: plan.workspace(), a: vec![0.0; plan.len()], b: vec![0.0; plan.len()] }
    }
}

fn take_ctx(ctxs: &Mutex<Vec<ConvCtx>>, plan: &CausalConv) -> ConvCtx {
    ctxs.lock()
        .unwrap()
        .pop()
        .filter(|c| c.ws.fft_size() == plan.fft_size())
        .unwrap_or_else(|| ConvCtx::new(plan))
}

fn put_ctx(ctxs: &Mutex<Vec<ConvCtx>>, ctx: ConvCtx) {
    ctxs.lock().unwrap().push(ctx);
}

/// Step-scoped scratch threaded through the forward/backward pass: the
/// buffer arena plus the shared pool of per-worker [`ConvCtx`]s (taken once
/// per parallel task, not per row). Owned by the model across training steps
/// so the steady state allocates nothing; public entry points that lack a
/// scratch (`forward_cached`, `backward`) build a transient one.
#[derive(Default)]
struct Scratch {
    arena: Arena,
    conv_ctxs: Mutex<Vec<ConvCtx>>,
}

impl Scratch {
    /// Return every buffer of a retired activation cache to the arena.
    fn recycle(&mut self, cache: Cache) {
        let Cache { b: _, tokens: _, blocks, lnf_xhat, lnf_rstd, uf } = cache;
        for v in [lnf_xhat, lnf_rstd, uf] {
            self.arena.put(v);
        }
        for blk in blocks {
            let BlockCache {
                ln1_xhat,
                ln1_rstd,
                t1,
                zp,
                zs,
                filt,
                hfilt,
                spec_h,
                spec_v,
                vs,
                cs,
                y_mix,
                ln2_xhat,
                ln2_rstd,
                t2,
                mlp_pre,
                mlp_tanh,
                mlp_act,
            } = blk;
            for v in [
                ln1_xhat, ln1_rstd, t1, zp, zs, hfilt, y_mix, ln2_xhat, ln2_rstd, t2, mlp_pre,
                mlp_tanh, mlp_act,
            ] {
                self.arena.put(v);
            }
            for v in vs {
                self.arena.put(v);
            }
            for v in cs {
                self.arena.put(v);
            }
            let FilterCache { zins, pres } = filt;
            for v in zins {
                self.arena.put(v);
            }
            for v in pres {
                self.arena.put(v);
            }
            let SpecBank { re, im, .. } = spec_h;
            self.arena.put(re);
            self.arena.put(im);
            for bank in spec_v {
                let SpecBank { re, im, .. } = bank;
                self.arena.put(re);
                self.arena.put(im);
            }
        }
    }
}

/// Half spectra of many length-L rows in two flat buffers (`bins` floats
/// per row). This is the cached `spec_h` of a block: computed once in
/// `mixer_fwd`, reused by every batch element and again by `mixer_bwd`.
struct SpecBank {
    re: Vec<f32>,
    im: Vec<f32>,
    bins: usize,
}

impl SpecBank {
    fn row(&self, i: usize) -> (&[f32], &[f32]) {
        let r = i * self.bins..(i + 1) * self.bins;
        (&self.re[r.clone()], &self.im[r])
    }
}

// ---------------------------------------------------------------------------
// serving workspaces (shape-bucketed, zero steady-state allocation)
// ---------------------------------------------------------------------------

/// How many halving plan buckets a model builds by default (`L/8..L`).
pub const DEFAULT_BUCKET_LEVELS: usize = 4;

/// Per-bucket serving state: a pool of per-worker conv contexts sized for
/// the bucket's plan, plus the filter spectra of every block materialized at
/// the bucket length (built lazily on the bucket's first request, reused
/// until the parameters change).
#[derive(Default)]
struct ServeBucket {
    ctxs: Mutex<Vec<ConvCtx>>,
    /// One [`SpecBank`] per block (empty until the bucket is first used).
    spec: Vec<SpecBank>,
    hits: u64,
}

/// Persistent inference workspace: one buffer arena plus per-bucket conv
/// contexts, cached filter spectra, and the decode-path caches (reversed
/// time-domain filters + per-session accounting). Owned by the model behind
/// a `Mutex` (the `Backend` forward surface is `&self`), so a steady-state
/// request allocates nothing — buffers, FFT scratch and spectra all
/// round-trip.
#[derive(Default)]
struct ServeState {
    arena: Arena,
    /// Parallel to the model's plan-bank buckets (ascending lengths).
    buckets: Vec<ServeBucket>,
    /// Params epoch the cached spectra were built at.
    epoch: u64,
    forwards: u64,
    /// Per-block time-domain filters for the streaming decode dot kernel,
    /// each `(N·D, L)` with every row **reversed** (`causal_dot_step`'s
    /// layout — reversing once at cache-build time makes each step a
    /// forward dot). Built lazily per params epoch, like the spectra.
    decode_filt: Vec<Vec<f32>>,
    /// Decode sessions currently holding streaming state.
    sessions_live: u64,
    /// Decode sessions begun over the model's lifetime.
    sessions_total: u64,
    /// Tokens served through the streaming step path.
    decode_steps: u64,
    /// Batched decode rounds served through `decode_step_batch_into`
    /// (every call counts, including rows == 1).
    step_batch_calls: u64,
    /// Session-tokens served by those batched rounds (Σ rows per call).
    step_batch_rows: u64,
    /// f32 elements checked out into live decode states (rings+histories).
    decode_state_elems: usize,
    /// Overlap-save plan of the chunked prefill path (lazy; shares the full
    /// bucket's FFT size, so spectra and conv workspaces are reused).
    chunked: Option<ChunkedCausalConv>,
    /// Pooled per-worker overlap-save block buffers (length = full-bucket
    /// FFT size) — `ConvCtx::a/b` are only `L` long, a block is up to
    /// `2L − 1`.
    chunk_bufs: Mutex<Vec<Vec<f32>>>,
    /// Prompts served through the chunked overlap-save prefill.
    prefill_chunked: u64,
    /// Total overlap-save chunks processed across those prefills.
    prefill_chunks: u64,
    /// Peak f32 elements a single chunked prefill checked out (carries +
    /// per-chunk activations + block buffers) — the O(chunk) gauge: it must
    /// not grow with the prompt length.
    prefill_chunk_elems: usize,
}

/// Pop a pooled overlap-save block buffer (or build one at `n`).
fn take_chunk_buf(pool: &Mutex<Vec<Vec<f32>>>, n: usize) -> Vec<f32> {
    let mut b = pool.lock().unwrap().pop().unwrap_or_default();
    if b.len() < n {
        b.resize(n, 0.0);
    }
    b
}

fn put_chunk_buf(pool: &Mutex<Vec<Vec<f32>>>, b: Vec<f32>) {
    pool.lock().unwrap().push(b);
}

impl ServeState {
    /// Re-key the state to the current plan ladder and parameter epoch,
    /// recycling stale cached spectra (and decode filters) into the arena.
    /// Live decode states are *not* touched — they carry their own epoch
    /// and the session layer re-prefills stale ones from their tokens.
    fn sync(&mut self, epoch: u64, levels: usize) {
        if self.buckets.len() != levels {
            let old = std::mem::take(&mut self.buckets);
            for bkt in old {
                for s in bkt.spec {
                    self.arena.put(s.re);
                    self.arena.put(s.im);
                }
            }
            self.buckets = (0..levels).map(|_| ServeBucket::default()).collect();
            for f in self.decode_filt.drain(..) {
                self.arena.put(f);
            }
            self.epoch = epoch;
        } else if self.epoch != epoch {
            for bkt in self.buckets.iter_mut() {
                for s in bkt.spec.drain(..) {
                    self.arena.put(s.re);
                    self.arena.put(s.im);
                }
            }
            for f in self.decode_filt.drain(..) {
                self.arena.put(f);
            }
            self.epoch = epoch;
        }
    }

    /// Bytes held by the input-independent filter caches: per-bucket half
    /// spectra plus the decode path's reversed time-domain filters.
    fn spec_bytes(&self) -> usize {
        let spectra: usize = self
            .buckets
            .iter()
            .flat_map(|b| b.spec.iter())
            .map(|s| (s.re.len() + s.im.len()) * std::mem::size_of::<f32>())
            .sum();
        let filt: usize =
            self.decode_filt.iter().map(|f| f.len() * std::mem::size_of::<f32>()).sum();
        spectra + filt
    }
}

/// Snapshot of the serving workspace for the serve report.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Inference forward passes executed (streaming decode: one per
    /// prefill; recompute decode: one per round per batch).
    pub forwards: u64,
    pub arena: ArenaStats,
    /// Bytes held by the cached per-bucket filter spectra + the decode
    /// path's reversed time-domain filters.
    pub spec_bytes: usize,
    /// Bucket signal lengths, ascending (last = full L).
    pub bucket_lens: Vec<usize>,
    /// Requests served per bucket, aligned with `bucket_lens`.
    pub bucket_hits: Vec<u64>,
    /// Decode sessions currently holding streaming state.
    pub decode_sessions_live: u64,
    /// Engine-level decode sessions begun over the model's lifetime
    /// (every state-building prefill counts, including mid-session
    /// stale-state rebuilds and failed prefill attempts).
    pub decode_sessions_total: u64,
    /// Tokens served through the streaming `decode_step_into` path
    /// (single-session and batched steps both count, per row).
    pub decode_steps: u64,
    /// Batched decode rounds served through `decode_step_batch_into`.
    pub decode_step_batches: u64,
    /// Session-tokens served by those batched rounds (Σ rows per call).
    pub decode_step_batch_rows: u64,
    /// Bytes held by live per-session ring buffers / channel histories.
    pub decode_state_bytes: usize,
    /// Longest prompt + generation the engine admits (= seqlen unless
    /// extended with `set_max_context`).
    pub max_context: usize,
    /// Extended monolithic plan lengths above the full bucket, ascending
    /// (empty without a context extension).
    pub ext_bucket_lens: Vec<usize>,
    /// Prompts served through the chunked overlap-save prefill.
    pub prefill_chunked: u64,
    /// Total overlap-save chunks processed across those prefills.
    pub prefill_chunks: u64,
    /// Peak bytes one chunked prefill checked out of the serving arena
    /// (carries + per-chunk activations + block buffers). O(chunk): at a
    /// fixed model this number is the same for a 4K and a 64K prompt —
    /// pinned by the longctx e2e tests.
    pub prefill_chunk_bytes: usize,
    /// Parameter epoch being served (bumped on every out-of-band param
    /// change; live sessions from older epochs are refused as stale).
    pub params_epoch: u64,
}

// ---------------------------------------------------------------------------
// streaming decode state (per-request recurrence state)
// ---------------------------------------------------------------------------

/// Channels per parallel task in the decode-step dot kernel: the per-channel
/// dots are O(t) each, so a handful of channels amortizes pool dispatch
/// while keeping enough tasks to balance.
const DECODE_CH_BLOCK: usize = 16;

/// Per-block streaming state of one decode session.
struct DecodeBlockState {
    /// Ring of the last `F−1` pre-short-conv projection rows `(F−1, C)`;
    /// position `t`'s row lives in slot `t mod (F−1)`. Empty when `F ≤ 1`.
    short_tail: Vec<f32>,
    /// Histories of the long-conv inputs `v_0..v_{N−1}`: `N` buffers of
    /// `(D, L)` channel-major rows, append-only in `t`.
    hist: Vec<Vec<f32>>,
}

/// Per-request streaming decode state (DESIGN.md §Decode): everything the
/// model needs to emit the *next* token in O(L) time without re-running the
/// prefix. Built by [`NativeModel::decode_begin_state`] (a bucketed-FFT
/// prefill that captures the histories as a side effect), advanced by
/// [`NativeModel::decode_step_into`] (time-domain dots against the buffered
/// histories — no FFT), released by [`NativeModel::decode_end_state`]
/// (every buffer returns to the serving arena, so steady-state session
/// churn allocates nothing).
pub struct DecodeState {
    /// Positions consumed so far (prompt + generated).
    pos: usize,
    /// Params epoch the histories were built at; on mismatch the state is
    /// stale and the session layer re-prefills from its tokens.
    epoch: u64,
    blocks: Vec<DecodeBlockState>,
}

impl DecodeState {
    /// Positions consumed so far (prompt + generated).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// f32 elements held by this state's ring/history buffers.
    fn elems(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.short_tail.len() + b.hist.iter().map(Vec::len).sum::<usize>())
            .sum()
    }
}

// ---------------------------------------------------------------------------
// dense / layernorm / gelu / short-conv primitives
// ---------------------------------------------------------------------------

/// Rows (or weight rows) per parallel task in the blocked dense kernels:
/// large enough to amortize dispatch and reuse streamed `w` rows, small
/// enough that a block's outputs stay cache-resident.
const DENSE_BLOCK: usize = 8;
/// Elements per parallel task in the elementwise kernels (GELU).
const ELEM_BLOCK: usize = 4096;

fn blocks_of(n: usize, blk: usize) -> usize {
    n.div_ceil(blk)
}

/// `y[r, o] = b[o] + Σ_i x[r, i] w[i, o]`, cache-blocked over row blocks
/// (each streamed `w` row is applied to the whole block) and parallel over
/// blocks. The inner row update runs through the dispatched axpy microkernel
/// (DESIGN.md §Kernels). Overwrites `y`.
fn dense_fwd_into(
    pool: &WorkerPool,
    x: &[f32],
    w: &[f32],
    b: Option<&[f32]>,
    rows: usize,
    din: usize,
    dout: usize,
    y: &mut [f32],
) {
    assert_eq!(x.len(), rows * din);
    assert_eq!(w.len(), din * dout);
    assert_eq!(y.len(), rows * dout);
    let k = kernels::active();
    let yv = SharedMut::new(y);
    pool.par_for(blocks_of(rows, DENSE_BLOCK), |blk| {
        let r0 = blk * DENSE_BLOCK;
        let r1 = (r0 + DENSE_BLOCK).min(rows);
        // SAFETY: row blocks partition `y`; block `blk` owns rows r0..r1.
        let yblk = unsafe { yv.slice(r0 * dout, (r1 - r0) * dout) };
        for yrow in yblk.chunks_mut(dout) {
            match b {
                Some(bv) => yrow.copy_from_slice(bv),
                None => yrow.fill(0.0),
            }
        }
        for i in 0..din {
            let wrow = &w[i * dout..(i + 1) * dout];
            for rr in 0..(r1 - r0) {
                let xv = x[(r0 + rr) * din + i];
                if xv == 0.0 {
                    continue;
                }
                let yrow = &mut yblk[rr * dout..(rr + 1) * dout];
                (k.axpy)(yrow, wrow, xv);
            }
        }
    });
}

/// `dx = dy @ wᵀ`, blocked + parallel over row blocks; the per-row
/// reduction runs through the dispatched dot microkernel. Overwrites `dx`.
fn dense_bwd_dx_into(
    pool: &WorkerPool,
    dy: &[f32],
    w: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
    dx: &mut [f32],
) {
    assert_eq!(dy.len(), rows * dout);
    assert_eq!(w.len(), din * dout);
    assert_eq!(dx.len(), rows * din);
    let k = kernels::active();
    let dxv = SharedMut::new(dx);
    pool.par_for(blocks_of(rows, DENSE_BLOCK), |blk| {
        let r0 = blk * DENSE_BLOCK;
        let r1 = (r0 + DENSE_BLOCK).min(rows);
        // SAFETY: row blocks partition `dx`.
        let dxblk = unsafe { dxv.slice(r0 * din, (r1 - r0) * din) };
        for i in 0..din {
            let wrow = &w[i * dout..(i + 1) * dout];
            for rr in 0..(r1 - r0) {
                let dyrow = &dy[(r0 + rr) * dout..(r0 + rr + 1) * dout];
                dxblk[rr * din + i] = (k.dot)(dyrow, wrow);
            }
        }
    });
}

/// `dw += xᵀ @ dy`, parallel over disjoint blocks of `dw` rows (each task
/// scans every data row, so per-element accumulation order matches the
/// serial kernel exactly). Accumulates into `dw`.
fn dense_bwd_dw_into(
    pool: &WorkerPool,
    x: &[f32],
    dy: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
    dw: &mut [f32],
) {
    assert_eq!(x.len(), rows * din);
    assert_eq!(dy.len(), rows * dout);
    assert_eq!(dw.len(), din * dout);
    let k = kernels::active();
    let dwv = SharedMut::new(dw);
    pool.par_for(blocks_of(din, DENSE_BLOCK), |blk| {
        let i0 = blk * DENSE_BLOCK;
        let i1 = (i0 + DENSE_BLOCK).min(din);
        // SAFETY: weight-row blocks partition `dw`.
        let dwblk = unsafe { dwv.slice(i0 * dout, (i1 - i0) * dout) };
        for r in 0..rows {
            let xrow = &x[r * din..(r + 1) * din];
            let dyrow = &dy[r * dout..(r + 1) * dout];
            for ii in 0..(i1 - i0) {
                let xv = xrow[i0 + ii];
                if xv == 0.0 {
                    continue;
                }
                let dwrow = &mut dwblk[ii * dout..(ii + 1) * dout];
                (k.axpy)(dwrow, dyrow, xv);
            }
        }
    });
}

/// `db += Σ_r dy[r, ·]`.
fn dense_bwd_db(dy: &[f32], rows: usize, dout: usize, db: &mut [f32]) {
    for r in 0..rows {
        let dyrow = &dy[r * dout..(r + 1) * dout];
        for o in 0..dout {
            db[o] += dyrow[o];
        }
    }
}

const LN_EPS: f32 = 1e-5;

/// Pre-LN layer norm over the last axis; overwrites `y`, `xhat`, `rstd`.
///
/// The mean/variance reductions accumulate in **f64** (first slice of the
/// ROADMAP f64-accumulation audit, DESIGN.md §Decode): the per-row sums are
/// the only place forward-path round-off grows with the reduction width,
/// and f64 accumulators cost nothing measurable next to the multiplies.
/// The per-element normalization stays f32, so `xhat`/`rstd` keep their
/// dtype and the backward formulas are unchanged.
fn layer_norm_fwd_into(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    rows: usize,
    d: usize,
    y: &mut [f32],
    xhat: &mut [f32],
    rstd: &mut [f32],
) {
    assert_eq!(y.len(), rows * d);
    assert_eq!(xhat.len(), rows * d);
    assert_eq!(rstd.len(), rows);
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f64;
        for &v in xr {
            mu += v as f64;
        }
        mu /= d as f64;
        let mut var = 0.0f64;
        for &v in xr {
            let dv = v as f64 - mu;
            var += dv * dv;
        }
        var /= d as f64;
        let rs = (1.0 / (var + LN_EPS as f64).sqrt()) as f32;
        let mu = mu as f32;
        rstd[r] = rs;
        for i in 0..d {
            let xh = (xr[i] - mu) * rs;
            xhat[r * d + i] = xh;
            y[r * d + i] = xh * g[i] + b[i];
        }
    }
}

/// Layer-norm backward; accumulates `dg`/`db`, overwrites `dx`.
fn layer_norm_bwd_into(
    dy: &[f32],
    g: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    rows: usize,
    d: usize,
    dg: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
) {
    assert_eq!(dx.len(), rows * d);
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xhr = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32; // mean(dxhat)
        let mut m2 = 0.0f32; // mean(dxhat * xhat)
        for i in 0..d {
            dg[i] += dyr[i] * xhr[i];
            db[i] += dyr[i];
            let dxh = dyr[i] * g[i];
            m1 += dxh;
            m2 += dxh * xhr[i];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let rs = rstd[r];
        for i in 0..d {
            let dxh = dyr[i] * g[i];
            dx[r * d + i] = rs * (dxh - m1 - xhr[i] * m2);
        }
    }
}

/// Tanh-approximate GELU (jax.nn.gelu default); overwrites `y` and the
/// cached `tanh` term. Parallel over element blocks, each chunk evaluated
/// by the dispatched GELU microkernel (tanh dominates).
fn gelu_fwd_into(pool: &WorkerPool, x: &[f32], y: &mut [f32], th: &mut [f32]) {
    let n = x.len();
    assert_eq!(y.len(), n);
    assert_eq!(th.len(), n);
    let k = kernels::active();
    let yv = SharedMut::new(y);
    let tv = SharedMut::new(th);
    pool.par_for(blocks_of(n, ELEM_BLOCK), |blk| {
        let s = blk * ELEM_BLOCK;
        let e = (s + ELEM_BLOCK).min(n);
        // SAFETY: element blocks partition `y` and `th`.
        let ys = unsafe { yv.slice(s, e - s) };
        let ts = unsafe { tv.slice(s, e - s) };
        (k.gelu_fwd)(&x[s..e], ys, ts);
    });
}

/// GELU backward; overwrites `dx`.
fn gelu_bwd_into(pool: &WorkerPool, dy: &[f32], x: &[f32], th: &[f32], dx: &mut [f32]) {
    let n = x.len();
    assert_eq!(dx.len(), n);
    let dxv = SharedMut::new(dx);
    pool.par_for(blocks_of(n, ELEM_BLOCK), |blk| {
        let s = blk * ELEM_BLOCK;
        let e = (s + ELEM_BLOCK).min(n);
        // SAFETY: element blocks partition `dx`.
        let ds = unsafe { dxv.slice(s, e - s) };
        for (j, i) in (s..e).enumerate() {
            let (v, t) = (x[i], th[i]);
            let dsig = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
            ds[j] = dy[i] * (0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * dsig);
        }
    });
}

/// Depthwise causal FIR conv `y[b,t,c] = Σ_f w[c,f] u[b,t−f,c]`, parallel
/// over batch rows. Overwrites `y`.
fn short_conv_fwd_into(
    pool: &WorkerPool,
    w: &[f32],
    u: &[f32],
    b: usize,
    l: usize,
    c: usize,
    f: usize,
    y: &mut [f32],
) {
    assert_eq!(y.len(), u.len());
    let yv = SharedMut::new(y);
    pool.par_for(b, |bi| {
        // SAFETY: batch rows partition `y`.
        let yb = unsafe { yv.slice(bi * l * c, l * c) };
        yb.fill(0.0);
        for t in 0..l {
            let yrow = t * c;
            for tap in 0..f.min(t + 1) {
                let urow = (bi * l + (t - tap)) * c;
                for ch in 0..c {
                    yb[yrow + ch] += w[ch * f + tap] * u[urow + ch];
                }
            }
        }
    });
}

/// Short-conv backward: overwrites `du`, accumulates `dw`. Batch rows run
/// in parallel with per-batch `dw` partials reduced in batch order —
/// deterministic and thread-count-invariant (the partial sums reassociate
/// f32 adds relative to a batch-outer serial kernel, so exact agreement is
/// across thread counts, not with pre-partial-scheme outputs).
fn short_conv_bwd_into(
    pool: &WorkerPool,
    w: &[f32],
    u: &[f32],
    dy: &[f32],
    b: usize,
    l: usize,
    c: usize,
    f: usize,
    dw: &mut [f32],
    du: &mut [f32],
    arena: &mut Arena,
) {
    assert_eq!(du.len(), u.len());
    assert_eq!(dw.len(), c * f);
    let mut partial = arena.take(b * c * f);
    {
        let duv = SharedMut::new(du);
        let pv = SharedMut::new(&mut partial);
        pool.par_for(b, |bi| {
            // SAFETY: batch rows partition `du` and `partial`.
            let dub = unsafe { duv.slice(bi * l * c, l * c) };
            dub.fill(0.0);
            // SAFETY: same batch-row partition — task bi exclusively owns
            // `partial[bi·c·f .. (bi+1)·c·f]`.
            let pw = unsafe { pv.slice(bi * c * f, c * f) };
            pw.fill(0.0);
            for t in 0..l {
                let dyrow = (bi * l + t) * c;
                for tap in 0..f.min(t + 1) {
                    let urow = (bi * l + (t - tap)) * c;
                    let du_row = (t - tap) * c;
                    for ch in 0..c {
                        dub[du_row + ch] += w[ch * f + tap] * dy[dyrow + ch];
                        pw[ch * f + tap] += dy[dyrow + ch] * u[urow + ch];
                    }
                }
            }
        });
    }
    for bi in 0..b {
        let pw = &partial[bi * c * f..(bi + 1) * c * f];
        for i in 0..c * f {
            dw[i] += pw[i];
        }
    }
    arena.put(partial);
}

// ---------------------------------------------------------------------------
// activation caches
// ---------------------------------------------------------------------------

struct FilterCache {
    /// Input rows of each FFN layer, `(L, fan_in)`.
    zins: Vec<Vec<f32>>,
    /// Pre-activation rows of each FFN layer, `(L, fan_out)`.
    pres: Vec<Vec<f32>>,
}

struct BlockCache {
    ln1_xhat: Vec<f32>,
    ln1_rstd: Vec<f32>,
    t1: Vec<f32>,
    /// Projection before the short conv, `(B, L, (N+1)D)`.
    zp: Vec<f32>,
    /// Projection after the short conv (gate/value slots), `(B, L, (N+1)D)`.
    zs: Vec<f32>,
    filt: FilterCache,
    /// Windowed filters `(N, D, L)`.
    hfilt: Vec<f32>,
    /// Cached half spectra of every filter row `(N·D, bins)` — computed in
    /// `mixer_fwd`, reused by `mixer_bwd` (no re-FFT of the filters).
    spec_h: SpecBank,
    /// Cached half spectra of every recurrence-state row: one `(B·D, bins)`
    /// bank per order, written as a side effect of the forward convolution
    /// and reused by `mixer_bwd`'s correlation adjoints (no re-FFT of the
    /// states — ROADMAP "cache spec_v").
    spec_v: Vec<SpecBank>,
    /// Recurrence states `v_0..v_N`, each `(B, D, L)`.
    vs: Vec<Vec<f32>>,
    /// Pre-gate responses `c_0..c_{N−1}`, each `(B, D, L)`.
    cs: Vec<Vec<f32>>,
    /// Mixer output in `(B, L, D)` (input of the out projection).
    y_mix: Vec<f32>,
    ln2_xhat: Vec<f32>,
    ln2_rstd: Vec<f32>,
    t2: Vec<f32>,
    mlp_pre: Vec<f32>,
    mlp_tanh: Vec<f32>,
    mlp_act: Vec<f32>,
}

/// Everything the backward pass needs from one forward pass.
pub struct Cache {
    b: usize,
    tokens: Vec<i32>,
    blocks: Vec<BlockCache>,
    lnf_xhat: Vec<f32>,
    lnf_rstd: Vec<f32>,
    uf: Vec<f32>,
}

/// Mixer activations produced by `mixer_fwd` (moved into the block cache).
struct BlockCacheParts {
    zp: Vec<f32>,
    zs: Vec<f32>,
    filt: FilterCache,
    hfilt: Vec<f32>,
    spec_h: SpecBank,
    spec_v: Vec<SpecBank>,
    vs: Vec<Vec<f32>>,
    cs: Vec<Vec<f32>>,
    y_mix: Vec<f32>,
}

/// Borrowed view of the same activations for the backward pass.
#[derive(Clone, Copy)]
struct BlockCachePartsRef<'a> {
    zp: &'a [f32],
    zs: &'a [f32],
    filt: &'a FilterCache,
    hfilt: &'a [f32],
    spec_h: &'a SpecBank,
    spec_v: &'a [SpecBank],
    vs: &'a [Vec<f32>],
    cs: &'a [Vec<f32>],
    y_mix: &'a [f32],
}

// ---------------------------------------------------------------------------
// the model
// ---------------------------------------------------------------------------

/// Parameters + optimizer state + precomputed constants of one native LM.
pub struct NativeModel {
    pub cfg: NativeConfig,
    pub layout: Layout,
    pub params: Vec<f32>,
    /// AdamW moments, allocated on the first training step.
    m: Vec<f32>,
    v: Vec<f32>,
    pub step: u64,
    /// Causal-conv plans at halving bucket lengths; the largest is the full
    /// seqlen plan the training path runs on (`NativeModel::conv`). Holds
    /// the extended monolithic ladder too when `max_context > seqlen`.
    bank: PlanBank,
    /// Longest prompt + generation the serving paths admit. Defaults to
    /// `cfg.seqlen`; raised by [`NativeModel::set_max_context`], which
    /// routes long prefills through the chunked overlap-save engine and
    /// decode through the sliding-window step (DESIGN.md §Long-context).
    max_context: usize,
    /// Positional encoding `(L, 2K+1)` (App. D.3) — constant.
    pe: Vec<f32>,
    /// Decay window `(N, D, L)` (Eq. 7 modulation) — constant.
    window: Vec<f32>,
    /// Worker pool for the row-parallel engine (shared process-wide pool by
    /// default; swap with [`NativeModel::set_threads`]).
    pool: WorkerPool,
    /// Step-scoped workspaces reused across training steps.
    scratch: Scratch,
    /// Persistent inference workspace (`forward_infer` path). Mutex-guarded
    /// because the `Backend` inference surface is `&self`.
    serve: Mutex<ServeState>,
    /// Bumped whenever parameters change (init, optimizer step, restore) so
    /// the serving path knows when its cached filter spectra went stale.
    epoch: u64,
}

impl NativeModel {
    pub fn new(cfg: NativeConfig, seed: i32) -> Result<NativeModel> {
        cfg.validate()?;
        let layout = Layout::new(&cfg);
        let (l, k) = (cfg.seqlen, cfg.pe_features);
        let (n, d) = (cfg.order, cfg.width);

        // Positional encoding: [t_norm, cos(2πkt/L), sin(2πkt/L)].
        let pe_dim = cfg.pe_dim();
        let mut pe = vec![0.0f32; l * pe_dim];
        for t in 0..l {
            let tn = t as f64 / (l.max(2) - 1) as f64;
            pe[t * pe_dim] = tn as f32;
            for ki in 0..k {
                let ang = 2.0 * std::f64::consts::PI * ki as f64 * t as f64 / l as f64;
                pe[t * pe_dim + 1 + ki] = ang.cos() as f32;
                pe[t * pe_dim + 1 + k + ki] = ang.sin() as f32;
            }
        }

        // Exponential-decay window with log-spaced rates across channels.
        let cnt = n * d;
        let (lf, ls) = ((cfg.decay_fast as f64).ln(), (cfg.decay_slow as f64).ln());
        let mut window = vec![0.0f32; cnt * l];
        for idx in 0..cnt {
            let frac = if cnt > 1 { idx as f64 / (cnt - 1) as f64 } else { 0.0 };
            let alpha = (lf + frac * (ls - lf)).exp();
            for t in 0..l {
                let w = (-alpha * t as f64 / (0.3 * l as f64)).exp();
                window[idx * l + t] = w as f32 + cfg.window_shift;
            }
        }

        let mut model = NativeModel {
            bank: PlanBank::new(l, DEFAULT_BUCKET_LEVELS),
            max_context: l,
            params: vec![0.0f32; layout.total],
            m: Vec::new(),
            v: Vec::new(),
            step: 0,
            layout,
            cfg,
            pe,
            window,
            pool: pool::global().clone(),
            scratch: Scratch::default(),
            serve: Mutex::new(ServeState::default()),
            epoch: 0,
        };
        model.init(seed);
        Ok(model)
    }

    /// The full-length causal-conv plan (the training path's plan).
    fn conv(&self) -> &CausalConv {
        self.bank.full()
    }

    /// Bucket signal lengths of the serving plan bank, ascending.
    pub fn bucket_lens(&self) -> Vec<usize> {
        self.bank.lens()
    }

    /// Rebuild the serving plan ladder with `levels` buckets (1 = unbucketed)
    /// and invalidate the serving workspace. The full-length plan is always
    /// kept, so the training path is unaffected; a context extension set via
    /// [`NativeModel::set_max_context`] is preserved.
    pub fn set_bucket_levels(&mut self, levels: usize) {
        self.bank = PlanBank::with_context(self.cfg.seqlen, levels, self.max_context);
        *self.serve.lock().unwrap() = ServeState::default();
    }

    /// Longest prompt + generation the serving paths admit.
    pub fn max_context(&self) -> usize {
        self.max_context
    }

    /// Extend (or restore) the serving context to `n` positions. Prompts
    /// beyond `seqlen` prefill through the chunked overlap-save engine in
    /// O(chunk) memory; decode past `seqlen` runs on a sliding window of the
    /// last `seqlen` positions (the filters' support — DESIGN.md
    /// §Long-context). Also builds the extended monolithic plan ladder
    /// (`2L, 4L, … ≥ n`) used by the exactness-reference path.
    pub fn set_max_context(&mut self, n: usize) -> Result<()> {
        if n < self.cfg.seqlen {
            bail!("max context {n} below the compiled window {}", self.cfg.seqlen);
        }
        self.max_context = n;
        self.bank = PlanBank::with_context(self.cfg.seqlen, self.bank.levels(), n);
        *self.serve.lock().unwrap() = ServeState::default();
        Ok(())
    }

    /// Record that parameters changed out-of-band (checkpoint restore), so
    /// cached serving spectra are rebuilt on the next request.
    pub fn note_params_changed(&mut self) {
        self.epoch += 1;
    }

    /// (Re-)initialize parameters from `seed`; resets the optimizer.
    pub fn init(&mut self, seed: i32) {
        let mut rng = Pcg::with_stream(seed as u32 as u64, 0x4e61_7469_7665);
        for e in &self.layout.entries {
            let data = &mut self.params[e.range()];
            match e.init {
                Init::Zero => data.fill(0.0),
                Init::One => data.fill(1.0),
                Init::Normal(s) => {
                    for x in data.iter_mut() {
                        *x = rng.normal() * s;
                    }
                }
                Init::NormalFan(fan) => {
                    let s = 1.0 / (fan as f32).sqrt();
                    for x in data.iter_mut() {
                        *x = rng.normal() * s;
                    }
                }
                Init::UniformFan(fan) => {
                    let bound = 1.0 / (fan as f32).sqrt();
                    for x in data.iter_mut() {
                        *x = (2.0 * rng.f32() - 1.0) * bound;
                    }
                }
                Init::ShortTap => {
                    let f = *e.shape.last().unwrap();
                    for x in data.iter_mut() {
                        *x = rng.normal() * 0.1;
                    }
                    for ch in 0..e.shape[0] {
                        data[ch * f] += 1.0;
                    }
                }
            }
        }
        self.m.clear();
        self.v.clear();
        self.step = 0;
        self.epoch += 1;
    }

    /// Use a dedicated worker pool with `n` threads for this model (tests,
    /// benches, thread-count sweeps). Models default to the process pool.
    pub fn set_threads(&mut self, n: usize) {
        self.pool = WorkerPool::new(n);
    }

    /// Worker threads this model's parallel loops run on.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn p(&self, ix: usize) -> &[f32] {
        self.layout.slice(&self.params, ix)
    }

    // -- filters ------------------------------------------------------------

    /// Materialize block `bi`'s implicit filters `(N, D, L)` (Fig. 3.1):
    /// sine-FFN over the positional encoding, modulated by the decay window.
    fn filter_fwd_with(&self, bi: usize, sc: &mut Scratch) -> (Vec<f32>, FilterCache) {
        let cfg = &self.cfg;
        let (l, n, d) = (cfg.seqlen, cfg.order, cfg.width);
        let bix = &self.layout.ix.blocks[bi];
        let dims = cfg.filter_layer_dims();
        let depth = dims.len();
        let omega = cfg.sine_freq;
        let pool = &self.pool;

        let mut zins = Vec::with_capacity(depth);
        let mut pres = Vec::with_capacity(depth);
        let mut z = sc.arena.take(self.pe.len());
        z.copy_from_slice(&self.pe);
        for (j, &(fan_in, fan_out)) in dims.iter().enumerate() {
            let w = self.p(bix.filt_w[j]);
            let b = self.p(bix.filt_b[j]);
            let mut pre = sc.arena.take(l * fan_out);
            dense_fwd_into(pool, &z, w, Some(b), l, fan_in, fan_out, &mut pre);
            zins.push(z);
            if j < depth - 1 {
                let mut act = sc.arena.take(l * fan_out);
                for (a, &p) in act.iter_mut().zip(pre.iter()) {
                    *a = (omega * p).sin();
                }
                pres.push(pre);
                z = act;
            } else {
                // The last layer is linear; its pre-activation is never read
                // by filter_bwd, so store a placeholder instead of a clone.
                pres.push(Vec::new());
                z = pre;
            }
        }

        // z is (L, N·D); transpose to (N, D, L) and apply the window.
        let nd = n * d;
        let mut hfilt = sc.arena.take(nd * l);
        for t in 0..l {
            for ch in 0..nd {
                hfilt[ch * l + t] = z[t * nd + ch] * self.window[ch * l + t];
            }
        }
        sc.arena.put(z);
        (hfilt, FilterCache { zins, pres })
    }

    /// Half spectra of `rows` filter rows of `hfilt` (N·D spectra, computed
    /// once per block, in parallel, shared across the batch and the
    /// backward pass).
    fn filter_spectra(&self, hfilt: &[f32], rows: usize, sc: &mut Scratch) -> SpecBank {
        self.spectra_rows(hfilt, rows, self.cfg.seqlen, self.conv(), &sc.conv_ctxs, &mut sc.arena)
    }

    /// Plan-generic spectrum bank of `rows` length-`l` rows of `hfilt` —
    /// shared by the training path (full plan) and the bucketed serving
    /// path (per-bucket plans + workspaces).
    fn spectra_rows(
        &self,
        hfilt: &[f32],
        rows: usize,
        l: usize,
        plan: &CausalConv,
        ctxs: &Mutex<Vec<ConvCtx>>,
        arena: &mut Arena,
    ) -> SpecBank {
        let bins = plan.spec_len();
        let mut re = arena.take(rows * bins);
        let mut im = arena.take(rows * bins);
        {
            let rv = SharedMut::new(&mut re);
            let iv = SharedMut::new(&mut im);
            self.pool.par_for_with(
                rows,
                || take_ctx(ctxs, plan),
                |ctx, r| {
                    // SAFETY: each index owns spectrum row r exclusively.
                    let rrow = unsafe { rv.slice(r * bins, bins) };
                    let irow = unsafe { iv.slice(r * bins, bins) };
                    plan.spectrum_slices_into(&hfilt[r * l..(r + 1) * l], &mut ctx.ws, rrow, irow);
                },
                |ctx| put_ctx(ctxs, ctx),
            );
        }
        SpecBank { re, im, bins }
    }

    /// Backward through the window + FFN; accumulates filter-weight grads.
    fn filter_bwd_with(
        &self,
        bi: usize,
        dhfilt: &[f32],
        cache: &FilterCache,
        grads: &mut [f32],
        sc: &mut Scratch,
    ) {
        let cfg = &self.cfg;
        let (l, n, d) = (cfg.seqlen, cfg.order, cfg.width);
        let bix = &self.layout.ix.blocks[bi];
        let dims = cfg.filter_layer_dims();
        let depth = dims.len();
        let omega = cfg.sine_freq;
        let pool = &self.pool;

        // d(FFN output): un-window and transpose back to (L, N·D).
        let nd = n * d;
        let mut dz = sc.arena.take(l * nd);
        for t in 0..l {
            for ch in 0..nd {
                dz[t * nd + ch] = dhfilt[ch * l + t] * self.window[ch * l + t];
            }
        }

        for j in (0..depth).rev() {
            let (fan_in, fan_out) = dims[j];
            if j < depth - 1 {
                // dz is w.r.t. sin(ω·pre): chain through the activation.
                let pre = &cache.pres[j];
                for (x, &p) in dz.iter_mut().zip(pre.iter()) {
                    *x *= omega * (omega * p).cos();
                }
            }
            let zin = &cache.zins[j];
            dense_bwd_dw_into(
                pool,
                zin,
                &dz,
                l,
                fan_in,
                fan_out,
                self.layout.slice_mut(grads, bix.filt_w[j]),
            );
            dense_bwd_db(&dz, l, fan_out, self.layout.slice_mut(grads, bix.filt_b[j]));
            if j > 0 {
                let mut dzn = sc.arena.take(l * fan_in);
                dense_bwd_dx_into(pool, &dz, self.p(bix.filt_w[j]), l, fan_in, fan_out, &mut dzn);
                sc.arena.put(std::mem::replace(&mut dz, dzn));
            }
        }
        sc.arena.put(dz);
    }

    // -- hyena mixer ---------------------------------------------------------

    /// Order-N Hyena forward (Algorithm 3) on the normalized stream `t1`.
    /// The (batch × channel) convolution rows run on the worker pool.
    fn mixer_fwd(
        &self,
        bi: usize,
        t1: &[f32],
        b: usize,
        sc: &mut Scratch,
    ) -> (Vec<f32>, BlockCacheParts) {
        let cfg = &self.cfg;
        let (l, d, n, f) = (cfg.seqlen, cfg.width, cfg.order, cfg.short_filter);
        let c = (n + 1) * d;
        let bix = &self.layout.ix.blocks[bi];
        let rows = b * l;
        let pool = &self.pool;

        // Algorithm 1: projection + depthwise short conv.
        let mut zp = sc.arena.take(rows * c);
        dense_fwd_into(
            pool,
            t1,
            self.p(bix.proj_w),
            Some(self.p(bix.proj_b)),
            rows,
            d,
            c,
            &mut zp,
        );
        let zs = match bix.short_w {
            Some(sw) => {
                let mut zs = sc.arena.take(rows * c);
                short_conv_fwd_into(pool, self.p(sw), &zp, b, l, c, f, &mut zs);
                zs
            }
            None => {
                let mut zs = sc.arena.take(rows * c);
                zs.copy_from_slice(&zp);
                zs
            }
        };

        // Algorithm 2: materialize the implicit filters and their spectra
        // (spectra cached for the whole block: batch reuse now, mixer_bwd
        // reuse later).
        let (hfilt, filt) = self.filter_fwd_with(bi, sc);
        let spec_h = self.filter_spectra(&hfilt, n * d, sc);

        // Slot 0 is the value v; slots 1..N are the gates x^n. Transpose the
        // value slot into channel-major (B, D, L).
        let mut v0 = sc.arena.take(b * d * l);
        for bb in 0..b {
            for t in 0..l {
                let src = (bb * l + t) * c;
                for ch in 0..d {
                    v0[(bb * d + ch) * l + t] = zs[src + ch];
                }
            }
        }

        // The recurrence (Def. 3.1): v ← x^n ⊙ (h^n ∗ v + bias_n ⊙ v).
        // The spectrum of every recurrence-state row is written into a
        // per-order bank (`spec_v`) as a side effect of the convolution:
        // `mixer_bwd` reuses the cached spectra instead of re-transforming
        // `v` — ~25% of the backward transforms for ~2× recurrence-state
        // activation memory (ROADMAP "cache spec_v"; DESIGN.md §Perf).
        let bias = self.p(bix.bias);
        let bins = self.conv().spec_len();
        let kn = kernels::active();
        let mut vs = vec![v0];
        let mut cs = Vec::with_capacity(n);
        let mut spec_v = Vec::with_capacity(n);
        for order in 0..n {
            let vprev = vs.last().unwrap();
            let mut cbuf = sc.arena.take(b * d * l);
            let mut vnext = sc.arena.take(b * d * l);
            let mut sv_re = sc.arena.take(b * d * bins);
            let mut sv_im = sc.arena.take(b * d * bins);
            {
                let cview = SharedMut::new(&mut cbuf);
                let vview = SharedMut::new(&mut vnext);
                let sre_v = SharedMut::new(&mut sv_re);
                let sim_v = SharedMut::new(&mut sv_im);
                let ctxs = &sc.conv_ctxs;
                pool.par_for_with(
                    b * d,
                    || take_ctx(ctxs, self.conv()),
                    |ctx, rix| {
                        let (bb, ch) = (rix / d, rix % d);
                        let row = rix * l; // (bb·d + ch)·l
                        let vrow = &vprev[row..row + l];
                        // SAFETY: index rix exclusively owns conv/gate row
                        // rix and spectrum-bank row rix.
                        let crow = unsafe { cview.slice(row, l) };
                        let vnrow = unsafe { vview.slice(row, l) };
                        let sre = unsafe { sre_v.slice(rix * bins, bins) };
                        let sim = unsafe { sim_v.slice(rix * bins, bins) };
                        self.conv().spectrum_slices_into(vrow, &mut ctx.ws, sre, sim);
                        let (hre, him) = spec_h.row(order * d + ch);
                        self.conv().conv_spec_slices_into(hre, him, sre, sim, &mut ctx.ws, crow);
                        let bv = bias[order * d + ch];
                        (kn.axpy)(crow, vrow, bv);
                        // Gate x^order lives in slot order+1 of zs (stride
                        // C down the time axis).
                        let gbase = (bb * l) * c + (order + 1) * d + ch;
                        (kn.gate_mul)(vnrow, crow, &zs[gbase..], c);
                    },
                    |ctx| put_ctx(ctxs, ctx),
                );
            }
            cs.push(cbuf);
            vs.push(vnext);
            spec_v.push(SpecBank { re: sv_re, im: sv_im, bins });
        }

        // Back to (B, L, D) and the output projection.
        let vlast = vs.last().unwrap();
        let mut y_mix = sc.arena.take(rows * d);
        for bb in 0..b {
            for t in 0..l {
                let dst = (bb * l + t) * d;
                for ch in 0..d {
                    y_mix[dst + ch] = vlast[(bb * d + ch) * l + t];
                }
            }
        }
        let mut out = sc.arena.take(rows * d);
        dense_fwd_into(
            pool,
            &y_mix,
            self.p(bix.out_w),
            Some(self.p(bix.out_b)),
            rows,
            d,
            d,
            &mut out,
        );
        (out, BlockCacheParts { zp, zs, filt, hfilt, spec_h, spec_v, vs, cs, y_mix })
    }

    /// Mixer backward: returns `d(t1)`, accumulates all mixer grads. The
    /// per-channel recurrence adjoints run on the worker pool (channel ch
    /// exclusively owns its filter-grad row, bias slot, gate slots and
    /// `dvprev` rows, so the partition is write-disjoint), reusing the
    /// filter spectra cached by `mixer_fwd`.
    fn mixer_bwd(
        &self,
        bi: usize,
        dout: &[f32],
        t1: &[f32],
        parts: &BlockCachePartsRef<'_>,
        b: usize,
        grads: &mut [f32],
        sc: &mut Scratch,
    ) -> Vec<f32> {
        let cfg = &self.cfg;
        let (l, d, n, f) = (cfg.seqlen, cfg.width, cfg.order, cfg.short_filter);
        let c = (n + 1) * d;
        let bix = &self.layout.ix.blocks[bi];
        let rows = b * l;
        let pool = &self.pool;
        let BlockCachePartsRef { zp, zs, filt, hfilt: _, spec_h, spec_v, vs, cs, y_mix } = *parts;

        // Out projection.
        dense_bwd_dw_into(pool, y_mix, dout, rows, d, d, self.layout.slice_mut(grads, bix.out_w));
        dense_bwd_db(dout, rows, d, self.layout.slice_mut(grads, bix.out_b));
        let mut dy = sc.arena.take(rows * d);
        dense_bwd_dx_into(pool, dout, self.p(bix.out_w), rows, d, d, &mut dy);

        // (B, L, D) → (B, D, L).
        let mut dv = sc.arena.take(b * d * l);
        for bb in 0..b {
            for t in 0..l {
                let src = (bb * l + t) * d;
                for ch in 0..d {
                    dv[(bb * d + ch) * l + t] = dy[src + ch];
                }
            }
        }
        sc.arena.put(dy);

        // Recurrence backward (reverse order), parallel over channels.
        let bias = self.p(bix.bias);
        let mut dzs = sc.arena.take_zeroed(rows * c);
        let mut dhfilt = sc.arena.take_zeroed(n * d * l);
        for order in (0..n).rev() {
            let vprev = &vs[order];
            let cbuf = &cs[order];
            let mut dvprev = sc.arena.take(b * d * l);
            {
                let dzs_v = SharedMut::new(&mut dzs);
                let dvp_v = SharedMut::new(&mut dvprev);
                let dh_v = SharedMut::new(&mut dhfilt[order * d * l..(order + 1) * d * l]);
                let gbias = &mut self.layout.slice_mut(grads, bix.bias)[order * d..(order + 1) * d];
                let gb_v = SharedMut::new(gbias);
                let ctxs = &sc.conv_ctxs;
                pool.par_for_with(
                    d,
                    || take_ctx(ctxs, self.conv()),
                    |ctx, ch| {
                        let (hre, him) = spec_h.row(order * d + ch);
                        let bv = bias[order * d + ch];
                        let mut bias_acc = 0.0f32;
                        // SAFETY: channel ch exclusively owns dhfilt row ch
                        // (of this order), bias slot ch, the gate slots
                        // `(·)·c + (order+1)·d + ch` of dzs, and rows
                        // (bb, ch) of dvprev.
                        let dh_row = unsafe { dh_v.slice(ch * l, l) };
                        for bb in 0..b {
                            let row = (bb * d + ch) * l;
                            let vrow = &vprev[row..row + l];
                            let crow = &cbuf[row..row + l];
                            let dvrow = &dv[row..row + l];
                            // Gate grad and pre-gate grad (dc = dv ⊙ x).
                            let dc = &mut ctx.a;
                            for t in 0..l {
                                let gix = (bb * l + t) * c + (order + 1) * d + ch;
                                // SAFETY: gate slot gix is in channel ch's
                                // exclusive dzs partition (see above).
                                unsafe {
                                    *dzs_v.at(gix) += dvrow[t] * crow[t];
                                }
                                dc[t] = dvrow[t] * zs[gix];
                            }
                            // Skip-bias grad: c = h∗v + bias⊙v.
                            let mut acc = 0.0f32;
                            for t in 0..l {
                                acc += dc[t] * vrow[t];
                            }
                            bias_acc += acc;
                            // Convolution adjoints:
                            // dh += corr(v, dc); dv = corr(h, dc) + bias⊙dc.
                            // The spectrum of v was cached by mixer_fwd
                            // (`spec_v`), so only dc is transformed here.
                            let mut s_dc = ctx.ws.take_spectrum();
                            self.conv().spectrum_into(dc, &mut ctx.ws, &mut s_dc);
                            let (vre, vim) = spec_v[order].row(bb * d + ch);
                            self.conv().corr_spec_slices_into(
                                vre,
                                vim,
                                &s_dc.re,
                                &s_dc.im,
                                &mut ctx.ws,
                                &mut ctx.b,
                            );
                            for t in 0..l {
                                dh_row[t] += ctx.b[t];
                            }
                            self.conv().corr_spec_slices_into(
                                hre,
                                him,
                                &s_dc.re,
                                &s_dc.im,
                                &mut ctx.ws,
                                &mut ctx.b,
                            );
                            // SAFETY: row (bb, ch) of dvprev is owned by
                            // channel ch alone (see partition note above).
                            let dvp = unsafe { dvp_v.slice(row, l) };
                            for t in 0..l {
                                dvp[t] = ctx.b[t] + bv * dc[t];
                            }
                            ctx.ws.put_spectrum(s_dc);
                        }
                        // SAFETY: bias slot ch belongs to this channel's
                        // exclusive partition.
                        unsafe {
                            *gb_v.at(ch) += bias_acc;
                        }
                    },
                    |ctx| put_ctx(ctxs, ctx),
                );
            }
            sc.arena.put(std::mem::replace(&mut dv, dvprev));
        }
        // Value slot (slot 0) grad.
        for bb in 0..b {
            for t in 0..l {
                let dst = (bb * l + t) * c;
                for ch in 0..d {
                    dzs[dst + ch] += dv[(bb * d + ch) * l + t];
                }
            }
        }
        sc.arena.put(dv);

        // Filters.
        self.filter_bwd_with(bi, &dhfilt, filt, grads, sc);
        sc.arena.put(dhfilt);

        // Short conv, projection.
        let dzp = match bix.short_w {
            Some(sw) => {
                let mut dzp = sc.arena.take(rows * c);
                short_conv_bwd_into(
                    pool,
                    self.p(sw),
                    zp,
                    &dzs,
                    b,
                    l,
                    c,
                    f,
                    self.layout.slice_mut(grads, sw),
                    &mut dzp,
                    &mut sc.arena,
                );
                sc.arena.put(dzs);
                dzp
            }
            None => dzs,
        };
        dense_bwd_dw_into(pool, t1, &dzp, rows, d, c, self.layout.slice_mut(grads, bix.proj_w));
        dense_bwd_db(&dzp, rows, c, self.layout.slice_mut(grads, bix.proj_b));
        let mut dt1 = sc.arena.take(rows * d);
        dense_bwd_dx_into(pool, &dzp, self.p(bix.proj_w), rows, d, c, &mut dt1);
        sc.arena.put(dzp);
        dt1
    }

    // -- full model ----------------------------------------------------------

    /// Forward pass over `tokens` (B·L ids), returning logits `(B, L, V)`
    /// and the activation cache for a subsequent backward pass.
    ///
    /// Transient-scratch convenience around [`NativeModel::train_step`]'s
    /// persistent-workspace path (inference through the `Backend` trait).
    pub fn forward_cached(&self, tokens: &[i32], b: usize) -> Result<(Vec<f32>, Cache)> {
        let mut sc = Scratch::default();
        self.forward_with(tokens, b, &mut sc)
    }

    fn forward_with(&self, tokens: &[i32], b: usize, sc: &mut Scratch) -> Result<(Vec<f32>, Cache)> {
        let cfg = &self.cfg;
        let (l, d, vsz) = (cfg.seqlen, cfg.width, cfg.vocab);
        if tokens.len() != b * l {
            bail!("tokens length {} != batch {b} × seqlen {l}", tokens.len());
        }
        let rows = b * l;
        let pool = &self.pool;

        // Embedding + learned positions.
        let embed = self.p(self.layout.ix.embed);
        let pos = self.p(self.layout.ix.pos);
        let mut u = sc.arena.take(rows * d);
        for bb in 0..b {
            for t in 0..l {
                let tok = (tokens[bb * l + t].max(0) as usize).min(vsz - 1);
                let dst = (bb * l + t) * d;
                let emb = &embed[tok * d..(tok + 1) * d];
                let ps = &pos[t * d..(t + 1) * d];
                for ch in 0..d {
                    u[dst + ch] = emb[ch] + ps[ch];
                }
            }
        }

        let mut blocks = Vec::with_capacity(cfg.depth);
        for bi in 0..cfg.depth {
            let bix = &self.layout.ix.blocks[bi];
            let mut t1 = sc.arena.take(rows * d);
            let mut ln1_xhat = sc.arena.take(rows * d);
            let mut ln1_rstd = sc.arena.take(rows);
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln1_g),
                self.p(bix.ln1_b),
                rows,
                d,
                &mut t1,
                &mut ln1_xhat,
                &mut ln1_rstd,
            );
            let (mix, parts) = self.mixer_fwd(bi, &t1, b, sc);
            let mut h_res = sc.arena.take(rows * d);
            for i in 0..rows * d {
                h_res[i] = u[i] + mix[i];
            }
            sc.arena.put(mix);
            let mut t2 = sc.arena.take(rows * d);
            let mut ln2_xhat = sc.arena.take(rows * d);
            let mut ln2_rstd = sc.arena.take(rows);
            layer_norm_fwd_into(
                &h_res,
                self.p(bix.ln2_g),
                self.p(bix.ln2_b),
                rows,
                d,
                &mut t2,
                &mut ln2_xhat,
                &mut ln2_rstd,
            );
            let dm = cfg.mlp_dim();
            let mut mlp_pre = sc.arena.take(rows * dm);
            dense_fwd_into(
                pool,
                &t2,
                self.p(bix.mlp_w1),
                Some(self.p(bix.mlp_b1)),
                rows,
                d,
                dm,
                &mut mlp_pre,
            );
            let mut mlp_act = sc.arena.take(rows * dm);
            let mut mlp_tanh = sc.arena.take(rows * dm);
            gelu_fwd_into(pool, &mlp_pre, &mut mlp_act, &mut mlp_tanh);
            let mut z = sc.arena.take(rows * d);
            dense_fwd_into(
                pool,
                &mlp_act,
                self.p(bix.mlp_w2),
                Some(self.p(bix.mlp_b2)),
                rows,
                dm,
                d,
                &mut z,
            );
            let mut unew = sc.arena.take(rows * d);
            for i in 0..rows * d {
                unew[i] = h_res[i] + z[i];
            }
            sc.arena.put(z);
            sc.arena.put(h_res);
            blocks.push(BlockCache {
                ln1_xhat,
                ln1_rstd,
                t1,
                zp: parts.zp,
                zs: parts.zs,
                filt: parts.filt,
                hfilt: parts.hfilt,
                spec_h: parts.spec_h,
                spec_v: parts.spec_v,
                vs: parts.vs,
                cs: parts.cs,
                y_mix: parts.y_mix,
                ln2_xhat,
                ln2_rstd,
                t2,
                mlp_pre,
                mlp_tanh,
                mlp_act,
            });
            sc.arena.put(std::mem::replace(&mut u, unew));
        }

        let mut uf = sc.arena.take(rows * d);
        let mut lnf_xhat = sc.arena.take(rows * d);
        let mut lnf_rstd = sc.arena.take(rows);
        layer_norm_fwd_into(
            &u,
            self.p(self.layout.ix.lnf_g),
            self.p(self.layout.ix.lnf_b),
            rows,
            d,
            &mut uf,
            &mut lnf_xhat,
            &mut lnf_rstd,
        );
        sc.arena.put(u);
        let mut logits = sc.arena.take(rows * vsz);
        dense_fwd_into(pool, &uf, self.p(self.layout.ix.head), None, rows, d, vsz, &mut logits);
        Ok((
            logits,
            Cache {
                b,
                tokens: tokens.to_vec(),
                blocks,
                lnf_xhat,
                lnf_rstd,
                uf,
            },
        ))
    }

    /// Masked mean cross-entropy and its logits gradient (model.py `lm_loss`).
    /// `logits` is consumed and overwritten with `d(loss)/d(logits)`.
    ///
    /// The log-sum-exp and the masked loss sum accumulate in **f64** (the
    /// other first-slice item of the f64-accumulation audit): the exp sum
    /// runs over the vocab and the loss sum over `B·L` rows, both of which
    /// drift visibly in f32 at large L (pinned by the drift test below).
    pub fn loss_and_dlogits(
        &self,
        logits: &mut [f32],
        targets: &[i32],
        mask: &[f32],
    ) -> f32 {
        let vsz = self.cfg.vocab;
        let rows = logits.len() / vsz;
        let denom: f32 = mask.iter().sum::<f32>().max(1.0);
        let mut loss = 0.0f64;
        for r in 0..rows {
            let row = &mut logits[r * vsz..(r + 1) * vsz];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut se = 0.0f64;
            for &x in row.iter() {
                se += ((x - mx) as f64).exp();
            }
            let lse = (mx as f64 + se.ln()) as f32;
            let tgt = (targets[r].max(0) as usize).min(vsz - 1);
            let mk = mask[r];
            if mk > 0.0 {
                loss += ((lse - row[tgt]) * mk) as f64;
            }
            // dlogits = (softmax − onehot) · mask / denom.
            let scale = mk / denom;
            for x in row.iter_mut() {
                *x = (*x - lse).exp() * scale;
            }
            row[tgt] -= scale;
        }
        (loss / denom as f64) as f32
    }

    /// Backward from `dlogits` through the whole model into `grads`
    /// (a zeroed buffer of `layout.total` length).
    ///
    /// Transient-scratch convenience (gradcheck, one-shot callers); the
    /// training loop goes through [`NativeModel::train_step`], which reuses
    /// the model's persistent workspaces.
    pub fn backward(&self, dlogits: &[f32], cache: &Cache, grads: &mut [f32]) {
        let mut sc = Scratch::default();
        self.backward_with(dlogits, cache, grads, &mut sc)
    }

    fn backward_with(&self, dlogits: &[f32], cache: &Cache, grads: &mut [f32], sc: &mut Scratch) {
        let cfg = &self.cfg;
        let (l, d, vsz) = (cfg.seqlen, cfg.width, cfg.vocab);
        let b = cache.b;
        let rows = b * l;
        let ix = &self.layout.ix;
        let pool = &self.pool;

        // Head.
        dense_bwd_dw_into(
            pool,
            &cache.uf,
            dlogits,
            rows,
            d,
            vsz,
            self.layout.slice_mut(grads, ix.head),
        );
        let mut duf = sc.arena.take(rows * d);
        dense_bwd_dx_into(pool, dlogits, self.p(ix.head), rows, d, vsz, &mut duf);

        // Final LN.
        let mut du = sc.arena.take(rows * d);
        {
            let mut dg = vec![0.0f32; d];
            let mut db = vec![0.0f32; d];
            layer_norm_bwd_into(
                &duf,
                self.p(ix.lnf_g),
                &cache.lnf_xhat,
                &cache.lnf_rstd,
                rows,
                d,
                &mut dg,
                &mut db,
                &mut du,
            );
            add_into(self.layout.slice_mut(grads, ix.lnf_g), &dg);
            add_into(self.layout.slice_mut(grads, ix.lnf_b), &db);
        }
        sc.arena.put(duf);

        for bi in (0..cfg.depth).rev() {
            let bix = self.layout.ix.blocks[bi].clone();
            let bc = &cache.blocks[bi];
            let dm = cfg.mlp_dim();

            // unew = h_res + mlp(t2): du splits into the residual and MLP paths.
            let dz = &du;
            dense_bwd_dw_into(
                pool,
                &bc.mlp_act,
                dz,
                rows,
                dm,
                d,
                self.layout.slice_mut(grads, bix.mlp_w2),
            );
            dense_bwd_db(dz, rows, d, self.layout.slice_mut(grads, bix.mlp_b2));
            let mut dact = sc.arena.take(rows * dm);
            dense_bwd_dx_into(pool, dz, self.p(bix.mlp_w2), rows, dm, d, &mut dact);
            let mut dpre = sc.arena.take(rows * dm);
            gelu_bwd_into(pool, &dact, &bc.mlp_pre, &bc.mlp_tanh, &mut dpre);
            sc.arena.put(dact);
            dense_bwd_dw_into(
                pool,
                &bc.t2,
                &dpre,
                rows,
                d,
                dm,
                self.layout.slice_mut(grads, bix.mlp_w1),
            );
            dense_bwd_db(&dpre, rows, dm, self.layout.slice_mut(grads, bix.mlp_b1));
            let mut dt2 = sc.arena.take(rows * d);
            dense_bwd_dx_into(pool, &dpre, self.p(bix.mlp_w1), rows, d, dm, &mut dt2);
            sc.arena.put(dpre);

            let mut dh = sc.arena.take(rows * d); // residual branch of unew = h + z
            dh.copy_from_slice(&du);
            {
                let mut dg = vec![0.0f32; d];
                let mut db = vec![0.0f32; d];
                let mut dx = sc.arena.take(rows * d);
                layer_norm_bwd_into(
                    &dt2,
                    self.p(bix.ln2_g),
                    &bc.ln2_xhat,
                    &bc.ln2_rstd,
                    rows,
                    d,
                    &mut dg,
                    &mut db,
                    &mut dx,
                );
                add_into(self.layout.slice_mut(grads, bix.ln2_g), &dg);
                add_into(self.layout.slice_mut(grads, bix.ln2_b), &db);
                for i in 0..rows * d {
                    dh[i] += dx[i];
                }
                sc.arena.put(dx);
            }
            sc.arena.put(dt2);

            // h_res = u + mixer(t1): dh feeds both the mixer and the skip.
            let parts = BlockCachePartsRef {
                zp: &bc.zp,
                zs: &bc.zs,
                filt: &bc.filt,
                hfilt: &bc.hfilt,
                spec_h: &bc.spec_h,
                spec_v: &bc.spec_v,
                vs: &bc.vs,
                cs: &bc.cs,
                y_mix: &bc.y_mix,
            };
            let dt1 = self.mixer_bwd(bi, &dh, &bc.t1, &parts, b, grads, sc);
            let mut du_new = dh;
            {
                let mut dg = vec![0.0f32; d];
                let mut db = vec![0.0f32; d];
                let mut dx = sc.arena.take(rows * d);
                layer_norm_bwd_into(
                    &dt1,
                    self.p(bix.ln1_g),
                    &bc.ln1_xhat,
                    &bc.ln1_rstd,
                    rows,
                    d,
                    &mut dg,
                    &mut db,
                    &mut dx,
                );
                add_into(self.layout.slice_mut(grads, bix.ln1_g), &dg);
                add_into(self.layout.slice_mut(grads, bix.ln1_b), &db);
                for i in 0..rows * d {
                    du_new[i] += dx[i];
                }
                sc.arena.put(dx);
            }
            sc.arena.put(dt1);
            sc.arena.put(std::mem::replace(&mut du, du_new));
        }

        // Embedding + positions.
        {
            let ge = self.layout.slice_mut(grads, ix.embed);
            for bb in 0..b {
                for t in 0..l {
                    let tok = (cache.tokens[bb * l + t].max(0) as usize).min(vsz - 1);
                    let src = (bb * l + t) * d;
                    for ch in 0..d {
                        ge[tok * d + ch] += du[src + ch];
                    }
                }
            }
        }
        {
            let gp = self.layout.slice_mut(grads, ix.pos);
            for bb in 0..b {
                for t in 0..l {
                    let src = (bb * l + t) * d;
                    for ch in 0..d {
                        gp[t * d + ch] += du[src + ch];
                    }
                }
            }
        }
        sc.arena.put(du);
    }

    /// Warmup→cosine LR schedule (train.py `lr_schedule`).
    pub fn lr_at(&self, step: f64) -> f32 {
        let peak = self.cfg.lr as f64;
        let warm = self.cfg.warmup_steps.max(1.0);
        let total = self.cfg.total_steps;
        let lr_min = peak * 0.1;
        if step < warm {
            (peak * (step + 1.0) / warm) as f32
        } else {
            let prog = ((step - warm) / (total - warm).max(1.0)).clamp(0.0, 1.0);
            (lr_min + 0.5 * (peak - lr_min) * (1.0 + (std::f64::consts::PI * prog).cos())) as f32
        }
    }

    /// Gradient clip + AdamW parameter update (train.py `adamw_step`).
    pub fn apply_grads(&mut self, grads: &mut [f32]) {
        if self.m.is_empty() {
            self.m = vec![0.0f32; self.layout.total];
            self.v = vec![0.0f32; self.layout.total];
        }
        // Global-norm clip.
        let gnorm = grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt();
        let clip = self.cfg.grad_clip as f64;
        let scale = (clip / gnorm.max(1e-9)).min(1.0) as f32;

        let step = self.step as f64;
        let lr = self.lr_at(step);
        let (b1, b2) = (self.cfg.beta1, self.cfg.beta2);
        let eps = self.cfg.adam_eps;
        let wd = self.cfg.weight_decay;
        let t = step + 1.0;
        let bc1 = 1.0 - (b1 as f64).powf(t) as f32;
        let bc2 = 1.0 - (b2 as f64).powf(t) as f32;

        for e in &self.layout.entries {
            let decay = if e.shape.len() >= 2 { wd } else { 0.0 };
            for i in e.range() {
                let g = grads[i] * scale;
                let m = b1 * self.m[i] + (1.0 - b1) * g;
                let v = b2 * self.v[i] + (1.0 - b2) * g * g;
                self.m[i] = m;
                self.v[i] = v;
                let mut upd = (m / bc1) / ((v / bc2).sqrt() + eps);
                upd += decay * self.params[i];
                self.params[i] -= lr * upd;
            }
        }
        self.step += 1;
        self.epoch += 1;
    }

    /// One optimizer step on `[tokens, targets, mask]` host data; returns
    /// the scalar loss. Runs on the model's persistent workspaces — after
    /// the first step all large activation/gradient buffers are reused and
    /// the per-row inner loops allocate nothing (what remains per step is
    /// small bookkeeping: the cached token ids, per-layer `d`-sized LN grad
    /// pairs, and the `Vec` containers holding recycled buffers).
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        targets: &[i32],
        mask: &[f32],
        b: usize,
    ) -> Result<f32> {
        let mut sc = std::mem::take(&mut self.scratch);
        let fwd = self.forward_with(tokens, b, &mut sc);
        let (mut logits, cache) = match fwd {
            Ok(x) => x,
            Err(e) => {
                self.scratch = sc;
                return Err(e);
            }
        };
        let loss = self.loss_and_dlogits(&mut logits, targets, mask);
        let mut grads = sc.arena.take_zeroed(self.layout.total);
        self.backward_with(&logits, &cache, &mut grads, &mut sc);
        self.apply_grads(&mut grads);
        sc.arena.put(grads);
        sc.arena.put(logits);
        sc.recycle(cache);
        self.scratch = sc;
        Ok(loss)
    }

    // -- inference-only forward (bucketed serving path) ----------------------

    /// Materialize block `bi`'s implicit filters truncated to the leading
    /// `lq` positions, `(N, D, lq)` — the serving-path sibling of
    /// `filter_fwd_with`. Filter positions are a prefix of the full-length
    /// filters: the FFN rows and decay-window entries are the same values
    /// (bitwise), just fewer of them.
    fn filter_fwd_len(&self, bi: usize, lq: usize, arena: &mut Arena) -> Vec<f32> {
        let cfg = &self.cfg;
        let (lfull, n, d) = (cfg.seqlen, cfg.order, cfg.width);
        debug_assert!(lq <= lfull);
        let bix = &self.layout.ix.blocks[bi];
        let dims = cfg.filter_layer_dims();
        let depth = dims.len();
        let omega = cfg.sine_freq;
        let pool = &self.pool;
        let pe_dim = cfg.pe_dim();

        let mut z = arena.take(lq * pe_dim);
        z.copy_from_slice(&self.pe[..lq * pe_dim]);
        for (j, &(fan_in, fan_out)) in dims.iter().enumerate() {
            let w = self.p(bix.filt_w[j]);
            let bv = self.p(bix.filt_b[j]);
            let mut pre = arena.take(lq * fan_out);
            dense_fwd_into(pool, &z, w, Some(bv), lq, fan_in, fan_out, &mut pre);
            if j < depth - 1 {
                // Same per-element arithmetic as the training path's
                // separate `act` buffer, just in place.
                for x in pre.iter_mut() {
                    *x = (omega * *x).sin();
                }
            }
            arena.put(std::mem::replace(&mut z, pre));
        }

        // z is (lq, N·D); transpose to (N, D, lq) and apply the window
        // (whose rows stride the full L).
        let nd = n * d;
        let mut hfilt = arena.take(nd * lq);
        for t in 0..lq {
            for ch in 0..nd {
                hfilt[ch * lq + t] = z[t * nd + ch] * self.window[ch * lfull + t];
            }
        }
        arena.put(z);
        hfilt
    }

    /// Order-N Hyena forward on the normalized stream — the cache-free
    /// serving sibling of `mixer_fwd`: identical per-row arithmetic, but
    /// recurrence states ping-pong through two arena buffers and the
    /// filters arrive as the bucket's cached spectra.
    ///
    /// `capture`, when set (single-row decode prefill), receives the
    /// streaming side products: the short-conv tail (last `F−1` projection
    /// rows) and the first `lq` positions of every long-conv input history
    /// `v_0..v_{N−1}` — exactly the state `decode_step_into` needs to
    /// continue the sequence one position at a time.
    fn mixer_infer(
        &self,
        bi: usize,
        t1: &[f32],
        b: usize,
        lb: usize,
        plan: &CausalConv,
        spec_h: &SpecBank,
        ctxs: &Mutex<Vec<ConvCtx>>,
        arena: &mut Arena,
        mut capture: Option<(&mut DecodeBlockState, usize)>,
    ) -> Vec<f32> {
        let cfg = &self.cfg;
        let (d, n, f) = (cfg.width, cfg.order, cfg.short_filter);
        let c = (n + 1) * d;
        let bix = &self.layout.ix.blocks[bi];
        let rows = b * lb;
        let pool = &self.pool;

        let mut zp = arena.take(rows * c);
        dense_fwd_into(
            pool,
            t1,
            self.p(bix.proj_w),
            Some(self.p(bix.proj_b)),
            rows,
            d,
            c,
            &mut zp,
        );
        if let Some((ds, lq)) = capture.as_mut() {
            debug_assert_eq!(b, 1, "decode prefill captures a single row");
            let f1 = f.saturating_sub(1);
            if f1 > 0 && bix.short_w.is_some() {
                // Ring slots for the last F−1 prompt positions (earlier
                // rows are out of every future tap's reach).
                for p in lq.saturating_sub(f1)..*lq {
                    let slot = (p % f1) * c;
                    ds.short_tail[slot..slot + c].copy_from_slice(&zp[p * c..(p + 1) * c]);
                }
            }
        }
        let zs = match bix.short_w {
            Some(sw) => {
                let mut zs = arena.take(rows * c);
                short_conv_fwd_into(pool, self.p(sw), &zp, b, lb, c, f, &mut zs);
                arena.put(zp);
                zs
            }
            None => zp,
        };

        // Value slot → channel-major (B, D, lb).
        let mut vcur = arena.take(b * d * lb);
        for bb in 0..b {
            for t in 0..lb {
                let src = (bb * lb + t) * c;
                for ch in 0..d {
                    vcur[(bb * d + ch) * lb + t] = zs[src + ch];
                }
            }
        }

        // The recurrence (Def. 3.1): v ← x^n ⊙ (h^n ∗ v + bias_n ⊙ v).
        let bias = self.p(bix.bias);
        let kn = kernels::active();
        let mut vnext = arena.take(b * d * lb);
        for order in 0..n {
            if let Some((ds, lq)) = capture.as_mut() {
                // vcur holds the conv input v_order; bank its first lq
                // positions as the session's channel history.
                let lfull = self.cfg.seqlen;
                let dst = &mut ds.hist[order];
                for ch in 0..d {
                    dst[ch * lfull..ch * lfull + *lq]
                        .copy_from_slice(&vcur[ch * lb..ch * lb + *lq]);
                }
            }
            {
                let vview = SharedMut::new(&mut vnext);
                pool.par_for_with(
                    b * d,
                    || take_ctx(ctxs, plan),
                    |ctx, rix| {
                        let (bb, ch) = (rix / d, rix % d);
                        let row = rix * lb;
                        let vrow = &vcur[row..row + lb];
                        // SAFETY: index rix exclusively owns output row rix.
                        let vnrow = unsafe { vview.slice(row, lb) };
                        let mut sv = ctx.ws.take_spectrum();
                        plan.spectrum_into(vrow, &mut ctx.ws, &mut sv);
                        let (hre, him) = spec_h.row(order * d + ch);
                        let crow = &mut ctx.a[..lb];
                        plan.conv_spec_slices_into(hre, him, &sv.re, &sv.im, &mut ctx.ws, crow);
                        ctx.ws.put_spectrum(sv);
                        let bv = bias[order * d + ch];
                        (kn.axpy)(crow, vrow, bv);
                        // Gate x^order lives in slot order+1 of zs.
                        let gbase = (bb * lb) * c + (order + 1) * d + ch;
                        (kn.gate_mul)(vnrow, crow, &zs[gbase..], c);
                    },
                    |ctx| put_ctx(ctxs, ctx),
                );
            }
            std::mem::swap(&mut vcur, &mut vnext);
        }
        arena.put(vnext);
        arena.put(zs);

        // Back to (B, lb, D) and the output projection.
        let mut y_mix = arena.take(rows * d);
        for bb in 0..b {
            for t in 0..lb {
                let dst = (bb * lb + t) * d;
                for ch in 0..d {
                    y_mix[dst + ch] = vcur[(bb * d + ch) * lb + t];
                }
            }
        }
        arena.put(vcur);
        let mut out = arena.take(rows * d);
        dense_fwd_into(
            pool,
            &y_mix,
            self.p(bix.out_w),
            Some(self.p(bix.out_b)),
            rows,
            d,
            d,
            &mut out,
        );
        arena.put(y_mix);
        out
    }

    /// Inference-only forward: no activation cache, no transient scratch.
    ///
    /// `tokens` is `b` rows of `lq` ids (`1 ≤ lq ≤ seqlen`). The request is
    /// routed to the smallest plan bucket covering `lq`; rows are padded to
    /// the bucket length with token 0 (the full-pad convention, so causality
    /// makes logits at positions `< lq` independent of the padding), and
    /// logits `(b, lq, V)` are written into `out`. Returns the bucket
    /// length used.
    ///
    /// Every buffer comes from the persistent mutex-guarded serving
    /// workspace; per-bucket filter spectra are cached across requests and
    /// invalidated when parameters change. In steady state a request
    /// allocates nothing (pinned via [`ArenaStats::allocs`] by the e2e
    /// tests). At the largest bucket (`lq` routed to the full plan) the
    /// logits are bitwise identical to `forward_cached`'s; smaller buckets
    /// transform at a smaller FFT size, so they agree with the full-pad
    /// path to f32 round-off rather than bitwise (DESIGN.md §Serving).
    pub fn forward_infer_into(
        &self,
        tokens: &[i32],
        b: usize,
        lq: usize,
        out: &mut Vec<f32>,
    ) -> Result<usize> {
        if lq > self.cfg.seqlen && b == 1 {
            return self.forward_infer_chunked_impl(tokens, lq, out, true, None);
        }
        self.forward_infer_impl(tokens, b, lq, out, None)
    }

    /// The bucketed inference forward, optionally capturing streaming
    /// decode state (`capture` ⇒ `b == 1`): the prefill side of
    /// [`NativeModel::decode_begin_state`].
    fn forward_infer_impl(
        &self,
        tokens: &[i32],
        b: usize,
        lq: usize,
        out: &mut Vec<f32>,
        mut capture: Option<&mut DecodeState>,
    ) -> Result<usize> {
        let cfg = &self.cfg;
        let (d, vsz, lfull) = (cfg.width, cfg.vocab, cfg.seqlen);
        if b == 0 {
            bail!("infer wants at least one row");
        }
        if capture.is_some() && b != 1 {
            bail!("decode prefill captures exactly one row, got {b}");
        }
        if lq == 0 || lq > lfull {
            bail!("infer length {lq} out of range 1..={lfull}");
        }
        if tokens.len() != b * lq {
            bail!("tokens length {} != batch {b} × length {lq}", tokens.len());
        }
        let bucket_ix = self.bank.bucket_index(lq).expect("lq ≤ seqlen has a bucket");
        let plan = self.bank.plan(bucket_ix);
        let lb = plan.len();
        let rows = b * lb;
        let pool = &self.pool;

        let mut guard = self.serve.lock().unwrap();
        let st = &mut *guard;
        st.sync(self.epoch, self.bank.levels());

        // Materialize this bucket's filter spectra once per params epoch.
        if st.buckets[bucket_ix].spec.is_empty() {
            for blk in 0..cfg.depth {
                let hfilt = self.filter_fwd_len(blk, lb, &mut st.arena);
                let spec = self.spectra_rows(
                    &hfilt,
                    cfg.order * d,
                    lb,
                    plan,
                    &st.buckets[bucket_ix].ctxs,
                    &mut st.arena,
                );
                st.arena.put(hfilt);
                st.buckets[bucket_ix].spec.push(spec);
            }
        }

        let ServeState { arena, buckets, .. } = &mut *st;
        let bucket = &buckets[bucket_ix];
        let ctxs = &bucket.ctxs;

        // Embedding + learned positions over the bucket length (token-0 pad).
        let embed = self.p(self.layout.ix.embed);
        let pos = self.p(self.layout.ix.pos);
        let mut u = arena.take(rows * d);
        for bb in 0..b {
            for t in 0..lb {
                let id = if t < lq { tokens[bb * lq + t] } else { 0 };
                let tok = (id.max(0) as usize).min(vsz - 1);
                let dst = (bb * lb + t) * d;
                let emb = &embed[tok * d..(tok + 1) * d];
                let ps = &pos[t * d..(t + 1) * d];
                for ch in 0..d {
                    u[dst + ch] = emb[ch] + ps[ch];
                }
            }
        }

        // One LN output buffer + scratch pair serves every norm site.
        let mut t1 = arena.take(rows * d);
        let mut xhat = arena.take(rows * d);
        let mut rstd = arena.take(rows);
        let dm = cfg.mlp_dim();
        for blk in 0..cfg.depth {
            let bix = &self.layout.ix.blocks[blk];
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln1_g),
                self.p(bix.ln1_b),
                rows,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            let cap_blk = capture.as_deref_mut().map(|s| (&mut s.blocks[blk], lq));
            let mix =
                self.mixer_infer(blk, &t1, b, lb, plan, &bucket.spec[blk], ctxs, arena, cap_blk);
            for i in 0..rows * d {
                u[i] += mix[i];
            }
            arena.put(mix);
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln2_g),
                self.p(bix.ln2_b),
                rows,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            let mut pre = arena.take(rows * dm);
            dense_fwd_into(
                pool,
                &t1,
                self.p(bix.mlp_w1),
                Some(self.p(bix.mlp_b1)),
                rows,
                d,
                dm,
                &mut pre,
            );
            let mut act = arena.take(rows * dm);
            let mut th = arena.take(rows * dm);
            gelu_fwd_into(pool, &pre, &mut act, &mut th);
            arena.put(th);
            arena.put(pre);
            let mut z = arena.take(rows * d);
            dense_fwd_into(
                pool,
                &act,
                self.p(bix.mlp_w2),
                Some(self.p(bix.mlp_b2)),
                rows,
                dm,
                d,
                &mut z,
            );
            arena.put(act);
            for i in 0..rows * d {
                u[i] += z[i];
            }
            arena.put(z);
        }
        let ix = &self.layout.ix;
        layer_norm_fwd_into(
            &u,
            self.p(ix.lnf_g),
            self.p(ix.lnf_b),
            rows,
            d,
            &mut t1,
            &mut xhat,
            &mut rstd,
        );
        let mut logits = arena.take(rows * vsz);
        dense_fwd_into(pool, &t1, self.p(ix.head), None, rows, d, vsz, &mut logits);

        // Hand back the requested (b, lq, V) prefix rows.
        out.clear();
        out.reserve(b * lq * vsz);
        for bb in 0..b {
            out.extend_from_slice(&logits[(bb * lb) * vsz..(bb * lb + lq) * vsz]);
        }
        arena.put(logits);
        arena.put(rstd);
        arena.put(xhat);
        arena.put(t1);
        arena.put(u);

        st.forwards += 1;
        st.buckets[bucket_ix].hits += 1;
        Ok(lb)
    }

    /// Allocating convenience around [`NativeModel::forward_infer_into`]:
    /// returns the `(b, lq, V)` logits and the bucket length used.
    pub fn forward_infer(&self, tokens: &[i32], b: usize, lq: usize) -> Result<(Vec<f32>, usize)> {
        let mut out = Vec::new();
        let lb = self.forward_infer_into(tokens, b, lq, &mut out)?;
        Ok((out, lb))
    }

    // -- chunked overlap-save prefill (extended context) ---------------------

    /// Chunked overlap-save prefill (DESIGN.md §Long-context): stream one
    /// row of `lq` tokens (`lq ≤ max_context`) through the network in
    /// `⌈lq / L⌉` blocks of the compiled length `L = seqlen`, carrying the
    /// temporal state between blocks — the `F−1` preceding projection rows
    /// for each short conv and the `L−1` preceding inputs for each long
    /// conv (the filters' support is `L`, so positions further back are a
    /// sliding window the model never reads). Every activation is sized by
    /// the chunk, so a 64K+ prompt never allocates an O(L_prompt) buffer;
    /// the per-call working set is recorded in `prefill_chunk_bytes`.
    ///
    /// The overlap-save plan runs at the full bucket's FFT size, so the
    /// bucket's cached filter spectra and conv workspaces are reused and a
    /// prompt of exactly `L` tokens (one full chunk, empty carries) is
    /// **bitwise identical** to the monolithic full-bucket path; multi-chunk
    /// prompts agree with the monolithic extended reference
    /// ([`NativeModel::forward_infer_ext_reference`]) to f32 round-off
    /// (≤ 1e-4 rel at the conv, pinned by tests). Positions beyond `L`
    /// share the last learned position-embedding row.
    ///
    /// `want_all` ⇒ `out` gets all `(lq, V)` logits; otherwise only the
    /// final position's `(V,)` row (the decode-prefill shape, keeping the
    /// output O(V) instead of O(lq·V)). `capture` receives the streaming
    /// decode state exactly as the bucketed prefill would produce it.
    /// Returns the chunk length (= the full bucket length).
    fn forward_infer_chunked_impl(
        &self,
        tokens: &[i32],
        lq: usize,
        out: &mut Vec<f32>,
        want_all: bool,
        mut capture: Option<&mut DecodeState>,
    ) -> Result<usize> {
        let cfg = &self.cfg;
        let (d, vsz, lfull) = (cfg.width, cfg.vocab, cfg.seqlen);
        let (n, f) = (cfg.order, cfg.short_filter);
        let c = (n + 1) * d;
        let f1 = f.saturating_sub(1);
        let dm = cfg.mlp_dim();
        if lq == 0 || lq > self.max_context {
            bail!("chunked infer length {lq} out of range 1..={}", self.max_context);
        }
        if tokens.len() != lq {
            bail!("tokens length {} != length {lq} (chunked prefill is single-row)", tokens.len());
        }
        let chunk = lfull;
        let wlen = lfull - 1;
        let nchunks = lq.div_ceil(chunk);
        let bucket_ix = self.bank.levels() - 1;
        let plan = self.bank.full();
        let nfft = plan.fft_size();
        let pool = &self.pool;

        let mut guard = self.serve.lock().unwrap();
        let st = &mut *guard;
        st.sync(self.epoch, self.bank.levels());

        // The overlap-save plan shares the full bucket's FFT size (chunk ==
        // filter == L ⇒ chunk + L − 1 ≤ next_pow2(2L)).
        if st.chunked.as_ref().map(|p| p.fft_size()) != Some(nfft) {
            st.chunked = Some(ChunkedCausalConv::with_fft_size(chunk, lfull, nfft));
        }
        // Materialize the full bucket's filter spectra once per params epoch
        // (identical to the monolithic path's cache — same transform size).
        if st.buckets[bucket_ix].spec.is_empty() {
            for blk in 0..cfg.depth {
                let hfilt = self.filter_fwd_len(blk, lfull, &mut st.arena);
                let spec = self.spectra_rows(
                    &hfilt,
                    n * d,
                    lfull,
                    plan,
                    &st.buckets[bucket_ix].ctxs,
                    &mut st.arena,
                );
                st.arena.put(hfilt);
                st.buckets[bucket_ix].spec.push(spec);
            }
        }

        let ServeState { arena, buckets, chunked, chunk_bufs, .. } = &mut *st;
        let chunked = chunked.as_ref().expect("overlap-save plan built above");
        let bucket = &buckets[bucket_ix];
        let ctxs = &bucket.ctxs;

        // Per-call working set, all O(chunk): activations sized by the
        // chunk plus the per-block carries. `taken` tallies every checkout
        // so `prefill_chunk_bytes` is a measured gauge, not an estimate.
        let mut taken = 0usize;
        let mut take = |arena: &mut Arena, len: usize| {
            taken += len;
            arena.take(len)
        };
        let mut u = take(arena, chunk * d);
        let mut t1 = take(arena, chunk * d);
        let mut xhat = take(arena, chunk * d);
        let mut rstd = take(arena, chunk);
        let mut zp = take(arena, chunk * c);
        let mut zs = take(arena, chunk * c);
        let mut vcur = take(arena, chunk * d);
        let mut vnext = take(arena, chunk * d);
        let mut y_mix = take(arena, chunk * d);
        let mut mix = take(arena, chunk * d);
        let mut pre = take(arena, chunk * dm);
        let mut act = take(arena, chunk * dm);
        let mut th = take(arena, chunk * dm);
        let mut z = take(arena, chunk * d);
        let mut logits = take(arena, chunk * vsz);
        let has_short = f1 > 0 && self.layout.ix.blocks.iter().all(|b| b.short_w.is_some());
        let mut short_carry: Vec<Vec<f32>> = (0..cfg.depth)
            .map(|_| if has_short { take(arena, f1 * c) } else { Vec::new() })
            .collect();
        let mut long_carry: Vec<Vec<f32>> =
            (0..cfg.depth * n).map(|_| take(arena, d * wlen)).collect();

        let embed = self.p(self.layout.ix.embed);
        let posw = self.p(self.layout.ix.pos);
        let ix = &self.layout.ix;
        let kn = kernels::active();

        out.clear();
        if want_all {
            out.reserve(lq * vsz);
        }

        let (mut g0, mut ck) = (0usize, 0usize);
        while g0 < lq {
            let chunk_t0 = crate::obs::clock::now_us();
            let cl = chunk.min(lq - g0);
            let rows = cl;

            // Embedding + learned positions (clamped to the last row beyond
            // the compiled window — the sliding-window convention shared
            // with the extended decode step).
            for t in 0..cl {
                let tok = (tokens[g0 + t].max(0) as usize).min(vsz - 1);
                let pt = (g0 + t).min(lfull - 1);
                let dst = t * d;
                let emb = &embed[tok * d..(tok + 1) * d];
                let ps = &posw[pt * d..(pt + 1) * d];
                for ch in 0..d {
                    u[dst + ch] = emb[ch] + ps[ch];
                }
            }

            for blk in 0..cfg.depth {
                let bix = &self.layout.ix.blocks[blk];
                layer_norm_fwd_into(
                    &u[..rows * d],
                    self.p(bix.ln1_g),
                    self.p(bix.ln1_b),
                    rows,
                    d,
                    &mut t1[..rows * d],
                    &mut xhat[..rows * d],
                    &mut rstd[..rows],
                );
                dense_fwd_into(
                    pool,
                    &t1[..rows * d],
                    self.p(bix.proj_w),
                    Some(self.p(bix.proj_b)),
                    rows,
                    d,
                    c,
                    &mut zp[..rows * c],
                );
                if let Some(stt) = capture.as_deref_mut() {
                    if f1 > 0 && bix.short_w.is_some() {
                        // Ring slots for the last F−1 prompt positions that
                        // fall inside this chunk (global slot index, the
                        // layout `decode_step_into` reads).
                        let ds = &mut stt.blocks[blk];
                        for p0 in lq.saturating_sub(f1).max(g0)..g0 + cl {
                            let slot = (p0 % f1) * c;
                            let t = p0 - g0;
                            ds.short_tail[slot..slot + c]
                                .copy_from_slice(&zp[t * c..(t + 1) * c]);
                        }
                    }
                }
                // Depthwise short conv, taps beyond the chunk head served
                // from the carried projection rows (same zero-init +
                // ascending-tap accumulation as `short_conv_fwd_into`, so
                // the first chunk is bitwise the monolithic conv).
                match bix.short_w {
                    Some(sw) => {
                        let w = self.p(sw);
                        let zsr = &mut zs[..rows * c];
                        zsr.fill(0.0);
                        for t in 0..cl {
                            let yrow = t * c;
                            for tap in 0..f.min(g0 + t + 1) {
                                let row: &[f32] = if tap <= t {
                                    &zp[(t - tap) * c..(t - tap + 1) * c]
                                } else {
                                    let j = f1 - (tap - t);
                                    &short_carry[blk][j * c..(j + 1) * c]
                                };
                                for ch in 0..c {
                                    zsr[yrow + ch] += w[ch * f + tap] * row[ch];
                                }
                            }
                        }
                        if g0 + cl < lq && f1 > 0 {
                            debug_assert!(cl >= f1, "chunk shorter than the short-conv carry");
                            short_carry[blk].copy_from_slice(&zp[(cl - f1) * c..cl * c]);
                        }
                    }
                    None => zs[..rows * c].copy_from_slice(&zp[..rows * c]),
                }

                // Value slot → channel-major (D, cl).
                for t in 0..cl {
                    let src = t * c;
                    for ch in 0..d {
                        vcur[ch * cl + t] = zs[src + ch];
                    }
                }

                // The recurrence (Def. 3.1), long convs via overlap-save.
                let bias = self.p(bix.bias);
                let spec_h = &bucket.spec[blk];
                let w_cur = if ck == 0 { 0 } else { wlen };
                for order in 0..n {
                    if let Some(stt) = capture.as_deref_mut() {
                        // Feed the session's sliding channel history: keep
                        // the last `lfull` conv-input samples seen so far.
                        let dst = &mut stt.blocks[blk].hist[order];
                        let fill = g0.min(lfull);
                        if cl >= lfull {
                            for ch in 0..d {
                                dst[ch * lfull..(ch + 1) * lfull].copy_from_slice(
                                    &vcur[ch * cl + (cl - lfull)..ch * cl + cl],
                                );
                            }
                        } else if fill + cl <= lfull {
                            for ch in 0..d {
                                dst[ch * lfull + fill..ch * lfull + fill + cl]
                                    .copy_from_slice(&vcur[ch * cl..ch * cl + cl]);
                            }
                        } else {
                            let shift = fill + cl - lfull;
                            for ch in 0..d {
                                let row = &mut dst[ch * lfull..(ch + 1) * lfull];
                                row.copy_within(shift..fill, 0);
                                row[fill - shift..fill - shift + cl]
                                    .copy_from_slice(&vcur[ch * cl..ch * cl + cl]);
                            }
                        }
                    }
                    {
                        let carry_all = &long_carry[blk * n + order];
                        let zs_ro = &zs[..rows * c];
                        let vview = SharedMut::new(&mut vnext[..d * cl]);
                        pool.par_for_with(
                            d,
                            || (take_ctx(ctxs, plan), take_chunk_buf(chunk_bufs, nfft)),
                            |(ctx, buf), ch| {
                                let vrow = &vcur[ch * cl..ch * cl + cl];
                                let carry = &carry_all[ch * wlen..ch * wlen + w_cur];
                                let (hre, him) = spec_h.row(order * d + ch);
                                let crow = &mut ctx.a[..cl];
                                chunked.process_chunk_slices_into(
                                    hre,
                                    him,
                                    carry,
                                    vrow,
                                    &mut ctx.ws,
                                    buf,
                                    crow,
                                );
                                let bv = bias[order * d + ch];
                                (kn.axpy)(crow, vrow, bv);
                                // SAFETY: channel ch exclusively owns output
                                // row ch of vnext.
                                let vnrow = unsafe { vview.slice(ch * cl, cl) };
                                // Gate x^order lives in slot order+1 of zs.
                                let gbase = (order + 1) * d + ch;
                                (kn.gate_mul)(vnrow, crow, &zs_ro[gbase..], c);
                            },
                            |(ctx, buf)| {
                                put_ctx(ctxs, ctx);
                                put_chunk_buf(chunk_bufs, buf);
                            },
                        );
                    }
                    // Roll the long-conv carry (last L−1 inputs of this
                    // order) before vcur becomes the next order's input.
                    if g0 + cl < lq && wlen > 0 {
                        debug_assert_eq!(cl, chunk, "only the final chunk may be ragged");
                        let dst = &mut long_carry[blk * n + order];
                        for ch in 0..d {
                            dst[ch * wlen..(ch + 1) * wlen]
                                .copy_from_slice(&vcur[ch * cl + (cl - wlen)..ch * cl + cl]);
                        }
                    }
                    std::mem::swap(&mut vcur, &mut vnext);
                }

                // Back to (cl, D) and the output projection + residual.
                for t in 0..cl {
                    let dst = t * d;
                    for ch in 0..d {
                        y_mix[dst + ch] = vcur[ch * cl + t];
                    }
                }
                dense_fwd_into(
                    pool,
                    &y_mix[..rows * d],
                    self.p(bix.out_w),
                    Some(self.p(bix.out_b)),
                    rows,
                    d,
                    d,
                    &mut mix[..rows * d],
                );
                for i in 0..rows * d {
                    u[i] += mix[i];
                }
                layer_norm_fwd_into(
                    &u[..rows * d],
                    self.p(bix.ln2_g),
                    self.p(bix.ln2_b),
                    rows,
                    d,
                    &mut t1[..rows * d],
                    &mut xhat[..rows * d],
                    &mut rstd[..rows],
                );
                dense_fwd_into(
                    pool,
                    &t1[..rows * d],
                    self.p(bix.mlp_w1),
                    Some(self.p(bix.mlp_b1)),
                    rows,
                    d,
                    dm,
                    &mut pre[..rows * dm],
                );
                gelu_fwd_into(
                    pool,
                    &pre[..rows * dm],
                    &mut act[..rows * dm],
                    &mut th[..rows * dm],
                );
                dense_fwd_into(
                    pool,
                    &act[..rows * dm],
                    self.p(bix.mlp_w2),
                    Some(self.p(bix.mlp_b2)),
                    rows,
                    dm,
                    d,
                    &mut z[..rows * d],
                );
                for i in 0..rows * d {
                    u[i] += z[i];
                }
            }

            layer_norm_fwd_into(
                &u[..rows * d],
                self.p(ix.lnf_g),
                self.p(ix.lnf_b),
                rows,
                d,
                &mut t1[..rows * d],
                &mut xhat[..rows * d],
                &mut rstd[..rows],
            );
            dense_fwd_into(
                pool,
                &t1[..rows * d],
                self.p(ix.head),
                None,
                rows,
                d,
                vsz,
                &mut logits[..rows * vsz],
            );
            if want_all {
                out.extend_from_slice(&logits[..rows * vsz]);
            } else if g0 + cl >= lq {
                out.extend_from_slice(&logits[(cl - 1) * vsz..cl * vsz]);
            }

            // Per-chunk span on the ambient trace (set by the coordinator
            // around decode_begin); no-op outside a traced prefill.
            crate::obs::trace::span_current(
                "prefill_chunk",
                chunk_t0,
                crate::obs::clock::now_us().saturating_sub(chunk_t0),
                ck as u64,
            );
            g0 += cl;
            ck += 1;
        }

        for v in [u, t1, xhat, rstd, zp, zs, vcur, vnext, y_mix, mix, pre, act, th, z, logits] {
            arena.put(v);
        }
        for v in short_carry {
            if v.capacity() > 0 {
                arena.put(v);
            }
        }
        for v in long_carry {
            arena.put(v);
        }
        let buf_elems: usize = chunk_bufs.lock().unwrap().iter().map(|b| b.capacity()).sum();

        st.forwards += 1;
        st.buckets[bucket_ix].hits += 1;
        st.prefill_chunked += 1;
        st.prefill_chunks += nchunks as u64;
        st.prefill_chunk_elems = st.prefill_chunk_elems.max(taken + buf_elems);
        Ok(chunk)
    }

    /// Allocating convenience around the chunked prefill: all `(lq, V)`
    /// logits of a single row plus the chunk length used (tests/benches).
    pub fn forward_infer_chunked(&self, tokens: &[i32], lq: usize) -> Result<(Vec<f32>, usize)> {
        let mut out = Vec::new();
        let lb = self.forward_infer_chunked_impl(tokens, lq, &mut out, true, None)?;
        Ok((out, lb))
    }

    /// Monolithic extended-context forward — the allocating reference the
    /// chunked engine is validated against (unit/e2e tests and the longctx
    /// bench gate). One row, `lq ≤ bank.max_len()`, each long conv run as a
    /// single FFT on the extended plan covering `lq` with the filter
    /// zero-extended past its support `L` — the same sliding-window
    /// semantics the chunked path streams, without the chunking. O(lq)
    /// memory by construction; not a serving path.
    pub fn forward_infer_ext_reference(&self, tokens: &[i32], lq: usize) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, vsz, lfull) = (cfg.width, cfg.vocab, cfg.seqlen);
        let (n, f) = (cfg.order, cfg.short_filter);
        let c = (n + 1) * d;
        let dm = cfg.mlp_dim();
        if lq == 0 || lq > self.bank.max_len() {
            bail!("reference length {lq} out of range 1..={}", self.bank.max_len());
        }
        if tokens.len() != lq {
            bail!("tokens length {} != length {lq}", tokens.len());
        }
        let plan = self.bank.ext_plan(lq).expect("lq ≤ max_len has a plan");
        let lp = plan.len();
        let pool = &self.pool;

        let embed = self.p(self.layout.ix.embed);
        let posw = self.p(self.layout.ix.pos);
        let mut u = vec![0.0f32; lq * d];
        for t in 0..lq {
            let tok = (tokens[t].max(0) as usize).min(vsz - 1);
            let pt = t.min(lfull - 1);
            for ch in 0..d {
                u[t * d + ch] = embed[tok * d + ch] + posw[pt * d + ch];
            }
        }

        let mut arena = Arena::default();
        let mut t1 = vec![0.0f32; lq * d];
        let mut xhat = vec![0.0f32; lq * d];
        let mut rstd = vec![0.0f32; lq];
        for blk in 0..cfg.depth {
            let bix = &self.layout.ix.blocks[blk];
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln1_g),
                self.p(bix.ln1_b),
                lq,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            let mut zp = vec![0.0f32; lq * c];
            dense_fwd_into(
                pool,
                &t1,
                self.p(bix.proj_w),
                Some(self.p(bix.proj_b)),
                lq,
                d,
                c,
                &mut zp,
            );
            let mut zs = vec![0.0f32; lq * c];
            match bix.short_w {
                Some(sw) => short_conv_fwd_into(pool, self.p(sw), &zp, 1, lq, c, f, &mut zs),
                None => zs.copy_from_slice(&zp),
            }

            // Channel-major conv inputs; filters zero-extended to lp.
            let hfilt = self.filter_fwd_len(blk, lfull, &mut arena);
            let bias = self.p(bix.bias);
            let mut vcur = vec![0.0f32; d * lq];
            for t in 0..lq {
                for ch in 0..d {
                    vcur[ch * lq + t] = zs[t * c + ch];
                }
            }
            let mut h_pad = vec![0.0f32; lp];
            let mut v_pad = vec![0.0f32; lp];
            for order in 0..n {
                let mut vnext = vec![0.0f32; d * lq];
                for ch in 0..d {
                    h_pad.fill(0.0);
                    h_pad[..lfull].copy_from_slice(
                        &hfilt[(order * d + ch) * lfull..(order * d + ch + 1) * lfull],
                    );
                    v_pad.fill(0.0);
                    v_pad[..lq].copy_from_slice(&vcur[ch * lq..(ch + 1) * lq]);
                    let y = plan.conv(&h_pad, &v_pad);
                    let bv = bias[order * d + ch];
                    for t in 0..lq {
                        let yt = y[t] + bv * vcur[ch * lq + t];
                        vnext[ch * lq + t] = zs[t * c + (order + 1) * d + ch] * yt;
                    }
                }
                vcur = vnext;
            }
            arena.put(hfilt);

            let mut y_mix = vec![0.0f32; lq * d];
            for t in 0..lq {
                for ch in 0..d {
                    y_mix[t * d + ch] = vcur[ch * lq + t];
                }
            }
            let mut mixo = vec![0.0f32; lq * d];
            dense_fwd_into(
                pool,
                &y_mix,
                self.p(bix.out_w),
                Some(self.p(bix.out_b)),
                lq,
                d,
                d,
                &mut mixo,
            );
            for i in 0..lq * d {
                u[i] += mixo[i];
            }
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln2_g),
                self.p(bix.ln2_b),
                lq,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            let mut pre = vec![0.0f32; lq * dm];
            dense_fwd_into(
                pool,
                &t1,
                self.p(bix.mlp_w1),
                Some(self.p(bix.mlp_b1)),
                lq,
                d,
                dm,
                &mut pre,
            );
            let mut act = vec![0.0f32; lq * dm];
            let mut th = vec![0.0f32; lq * dm];
            gelu_fwd_into(pool, &pre, &mut act, &mut th);
            let mut z = vec![0.0f32; lq * d];
            dense_fwd_into(
                pool,
                &act,
                self.p(bix.mlp_w2),
                Some(self.p(bix.mlp_b2)),
                lq,
                dm,
                d,
                &mut z,
            );
            for i in 0..lq * d {
                u[i] += z[i];
            }
        }
        let ix = &self.layout.ix;
        layer_norm_fwd_into(
            &u,
            self.p(ix.lnf_g),
            self.p(ix.lnf_b),
            lq,
            d,
            &mut t1,
            &mut xhat,
            &mut rstd,
        );
        let mut logits = vec![0.0f32; lq * vsz];
        dense_fwd_into(pool, &t1, self.p(ix.head), None, lq, d, vsz, &mut logits);
        Ok(logits)
    }

    // -- streaming decode (per-request recurrence state) ---------------------

    /// Materialize the reversed time-domain filters of every block (the
    /// decode dot kernel's layout) into the serving workspace, once per
    /// params epoch. Caller holds the serve lock.
    fn ensure_decode_filters(&self, st: &mut ServeState) {
        if !st.decode_filt.is_empty() {
            return;
        }
        let (l, n, d) = (self.cfg.seqlen, self.cfg.order, self.cfg.width);
        for bi in 0..self.cfg.depth {
            let hfilt = self.filter_fwd_len(bi, l, &mut st.arena);
            let mut rev = st.arena.take(n * d * l);
            for ch in 0..n * d {
                let src = &hfilt[ch * l..(ch + 1) * l];
                let dst = &mut rev[ch * l..(ch + 1) * l];
                for t in 0..l {
                    dst[t] = src[l - 1 - t];
                }
            }
            st.arena.put(hfilt);
            st.decode_filt.push(rev);
        }
    }

    /// Begin a streaming decode session: prefill `prompt` (capturing the
    /// per-block recurrence state as a side effect), write the last
    /// position's `(V,)` logits into `logits`, and return the live state.
    /// Prompts that fit the compiled window run the bucketed FFT path;
    /// longer ones (up to `max_context − 1`) stream through the chunked
    /// overlap-save prefill. Every state buffer is drawn from the serving
    /// arena; [`NativeModel::decode_end_state`] returns them, so
    /// steady-state session churn allocates nothing.
    pub fn decode_begin_state(
        &self,
        prompt: &[i32],
        logits: &mut Vec<f32>,
    ) -> Result<DecodeState> {
        self.decode_begin_impl(prompt, logits, false)
    }

    /// [`NativeModel::decode_begin_state`] forced through the chunked
    /// overlap-save prefill even when the prompt fits the compiled window —
    /// the equivalence-test entry: greedy streams seeded by the chunked and
    /// bucketed prefills must be token-identical.
    pub fn decode_begin_state_chunked(
        &self,
        prompt: &[i32],
        logits: &mut Vec<f32>,
    ) -> Result<DecodeState> {
        self.decode_begin_impl(prompt, logits, true)
    }

    fn decode_begin_impl(
        &self,
        prompt: &[i32],
        logits: &mut Vec<f32>,
        force_chunked: bool,
    ) -> Result<DecodeState> {
        let cfg = &self.cfg;
        let (l, d, n, f, vsz) = (cfg.seqlen, cfg.width, cfg.order, cfg.short_filter, cfg.vocab);
        let mc = self.max_context;
        if prompt.is_empty() || prompt.len() >= mc {
            bail!("prompt length {} out of range (1..{mc})", prompt.len());
        }
        let p = prompt.len();
        let c = (n + 1) * d;
        let f1 = f.saturating_sub(1);
        let use_chunked = force_chunked || p >= l;

        // Check the state's buffers (and, for the bucketed path, a
        // full-logits scratch) out of the serving arena. The history rows
        // stay (D, L) regardless of prompt length: beyond the window they
        // hold the last L conv inputs (the filters' support), the sliding
        // window the extended decode step maintains.
        let (mut state, mut scratch) = {
            let mut guard = self.serve.lock().unwrap();
            let st = &mut *guard;
            st.sync(self.epoch, self.bank.levels());
            let blocks = (0..cfg.depth)
                .map(|_| DecodeBlockState {
                    short_tail: if f1 > 0 { st.arena.take(f1 * c) } else { Vec::new() },
                    hist: (0..n).map(|_| st.arena.take(d * l)).collect(),
                })
                .collect();
            let state = DecodeState { pos: 0, epoch: self.epoch, blocks };
            st.sessions_live += 1;
            st.sessions_total += 1;
            st.decode_state_elems += state.elems();
            let scratch = if use_chunked { Vec::new() } else { st.arena.take(p * vsz) };
            (state, scratch)
        };

        let res = if use_chunked {
            // The chunked engine writes the final row's logits directly
            // (want_all = false): the output stays O(V), not O(p·V).
            self.forward_infer_chunked_impl(prompt, p, logits, false, Some(&mut state))
                .map(|_| ())
        } else {
            let r = self
                .forward_infer_impl(prompt, 1, p, &mut scratch, Some(&mut state))
                .map(|_| ());
            if r.is_ok() {
                logits.clear();
                logits.extend_from_slice(&scratch[(p - 1) * vsz..p * vsz]);
            }
            self.serve.lock().unwrap().arena.put(scratch);
            r
        };
        match res {
            Ok(()) => {
                state.pos = p;
                Ok(state)
            }
            Err(e) => {
                self.decode_end_state(state);
                Err(e)
            }
        }
    }

    /// Advance a session by one token at position `state.pos()`: the long
    /// convolutions are evaluated as O(t) time-domain dots against the
    /// buffered histories (no FFT), every other op runs at a single
    /// position, and all step scratch round-trips through the serving
    /// arena. Writes the `(V,)` logits row for the new position.
    ///
    /// Beyond the compiled window (`t ≥ seqlen`, reachable once
    /// `max_context > seqlen`) the step keeps decoding against the sliding
    /// window: the filters' support is `seqlen`, so the history rows shift
    /// left by one and the position embedding clamps to its last row.
    ///
    /// Fails at the context edge or when the state predates a parameter
    /// update (the session layer then re-prefills from its tokens).
    ///
    /// KEEP IN SYNC with [`NativeModel::decode_step_batch_into`]: the two
    /// bodies are the same per-token forward at rows = 1 vs rows = N, and
    /// `decode_step_batch_is_bitwise_identical_to_serial_steps` pins their
    /// bitwise agreement — any arithmetic change must land in both.
    pub fn decode_step_into(
        &self,
        state: &mut DecodeState,
        token: i32,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (lfull, d, n, f, vsz) =
            (cfg.seqlen, cfg.width, cfg.order, cfg.short_filter, cfg.vocab);
        let c = (n + 1) * d;
        let dm = cfg.mlp_dim();
        let t = state.pos;
        if t >= self.max_context {
            bail!("decode session is at the context edge (length {})", self.max_context);
        }
        if state.epoch != self.epoch {
            bail!("decode state predates a parameter update (re-prefill the session)");
        }
        let pool = &self.pool;

        let mut guard = self.serve.lock().unwrap();
        let st = &mut *guard;
        st.sync(self.epoch, self.bank.levels());
        self.ensure_decode_filters(st);
        let ServeState { arena, decode_filt, .. } = &mut *st;

        // Single-position residual stream: embedding + learned position.
        let embed = self.p(self.layout.ix.embed);
        let posw = self.p(self.layout.ix.pos);
        let tok = (token.max(0) as usize).min(vsz - 1);
        let pt = t.min(lfull - 1);
        let mut u = arena.take(d);
        for ch in 0..d {
            u[ch] = embed[tok * d + ch] + posw[pt * d + ch];
        }

        let mut t1 = arena.take(d);
        let mut xhat = arena.take(d);
        let mut rstd = arena.take(1);
        let mut zp = arena.take(c);
        let mut zs = arena.take(c);
        let mut va = arena.take(d);
        let mut vb = arena.take(d);
        let mut pre = arena.take(dm);
        let mut act = arena.take(dm);
        let mut th = arena.take(dm);
        let mut z = arena.take(d);

        for blk in 0..cfg.depth {
            let bix = &self.layout.ix.blocks[blk];
            let ds = &mut state.blocks[blk];
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln1_g),
                self.p(bix.ln1_b),
                1,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            dense_fwd_into(
                pool,
                &t1,
                self.p(bix.proj_w),
                Some(self.p(bix.proj_b)),
                1,
                d,
                c,
                &mut zp,
            );
            // Depthwise short conv at one position, taps 1.. served from
            // the ring of recent projection rows.
            match bix.short_w {
                Some(sw) => {
                    let w = self.p(sw);
                    for ch in 0..c {
                        zs[ch] = w[ch * f] * zp[ch];
                    }
                    let f1 = f - 1;
                    for tap in 1..f.min(t + 1) {
                        let slot = ((t - tap) % f1) * c;
                        let row = &ds.short_tail[slot..slot + c];
                        for ch in 0..c {
                            zs[ch] += w[ch * f + tap] * row[ch];
                        }
                    }
                    if f1 > 0 {
                        let slot = (t % f1) * c;
                        ds.short_tail[slot..slot + c].copy_from_slice(&zp);
                    }
                }
                None => zs.copy_from_slice(&zp),
            }

            // The recurrence (Def. 3.1) at one position: each long conv is
            // an O(t) dot of the reversed filter against the history.
            let bias = self.p(bix.bias);
            let hrev_all = &decode_filt[blk];
            va.copy_from_slice(&zs[..d]);
            let hl = (t + 1).min(lfull);
            for order in 0..n {
                {
                    // Append v_order[t] to the history, then dot. Beyond
                    // the window the row slides left by one: the filters'
                    // support is lfull, so older samples are never read.
                    let histo = &mut ds.hist[order];
                    if t < lfull {
                        for ch in 0..d {
                            histo[ch * lfull + t] = va[ch];
                        }
                    } else {
                        for ch in 0..d {
                            let row = &mut histo[ch * lfull..(ch + 1) * lfull];
                            row.copy_within(1.., 0);
                            row[lfull - 1] = va[ch];
                        }
                    }
                }
                {
                    let histo = &ds.hist[order];
                    let vview = SharedMut::new(&mut vb);
                    pool.par_for(blocks_of(d, DECODE_CH_BLOCK), |cb| {
                        let c0 = cb * DECODE_CH_BLOCK;
                        let c1 = (c0 + DECODE_CH_BLOCK).min(d);
                        // SAFETY: channel blocks partition `vb`.
                        let outb = unsafe { vview.slice(c0, c1 - c0) };
                        for (j, ch) in (c0..c1).enumerate() {
                            let row = (order * d + ch) * lfull;
                            let hrev = &hrev_all[row..row + lfull];
                            let hist = &histo[ch * lfull..ch * lfull + hl];
                            let y = crate::backend::fft::causal_dot_step(hrev, hist)
                                + bias[order * d + ch] * va[ch];
                            // Gate x^order lives in slot order+1 of zs.
                            outb[j] = zs[(order + 1) * d + ch] * y;
                        }
                    });
                }
                std::mem::swap(&mut va, &mut vb);
            }

            // Out projection + residual, then the MLP half of the block.
            dense_fwd_into(
                pool,
                &va,
                self.p(bix.out_w),
                Some(self.p(bix.out_b)),
                1,
                d,
                d,
                &mut z,
            );
            for ch in 0..d {
                u[ch] += z[ch];
            }
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln2_g),
                self.p(bix.ln2_b),
                1,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            dense_fwd_into(
                pool,
                &t1,
                self.p(bix.mlp_w1),
                Some(self.p(bix.mlp_b1)),
                1,
                d,
                dm,
                &mut pre,
            );
            gelu_fwd_into(pool, &pre, &mut act, &mut th);
            dense_fwd_into(
                pool,
                &act,
                self.p(bix.mlp_w2),
                Some(self.p(bix.mlp_b2)),
                1,
                dm,
                d,
                &mut z,
            );
            for ch in 0..d {
                u[ch] += z[ch];
            }
        }

        let ix = &self.layout.ix;
        layer_norm_fwd_into(
            &u,
            self.p(ix.lnf_g),
            self.p(ix.lnf_b),
            1,
            d,
            &mut t1,
            &mut xhat,
            &mut rstd,
        );
        logits.clear();
        logits.resize(vsz, 0.0);
        dense_fwd_into(pool, &t1, self.p(ix.head), None, 1, d, vsz, logits);

        for v in [u, t1, xhat, rstd, zp, zs, va, vb, pre, act, th, z] {
            arena.put(v);
        }
        st.decode_steps += 1;
        state.pos = t + 1;
        Ok(())
    }

    /// Advance `rows` decode sessions by one token each in a **single
    /// engine call** (ROADMAP "batched decode steps"): the current
    /// positions of all live sessions are stacked into one `(rows, ·)`
    /// dense pass per block — LN, projection, out/MLP matmuls and the head
    /// all run at `rows` rows, recovering the dense microkernel's row
    /// blocking that per-session stepping forfeits at rows = 1 — while the
    /// per-session state stays per-session: short-conv rings and channel
    /// histories are appended row-by-row and the long-conv dots read each
    /// row's own history (parallel over rows × channel blocks).
    ///
    /// Per-row arithmetic is exactly [`NativeModel::decode_step_into`]'s
    /// (the dense kernels compute each row independently, LN/GELU are
    /// per-row/per-element), so batched logits are **bitwise identical** to
    /// stepping the same sessions serially — pinned by tests and the
    /// batched-decode bench. All scratch comes from the serving arena:
    /// steady-state rounds at a fixed occupancy allocate nothing.
    ///
    /// Writes `rows` `(V,)` logits rows, packed, into `logits`. Fails
    /// without touching any state if a session is at the window edge or
    /// stale (callers pre-filter and route those through the serial path).
    ///
    /// KEEP IN SYNC with [`NativeModel::decode_step_into`] (same body at
    /// rows = 1; bitwise agreement is test-pinned — change both or
    /// neither).
    pub fn decode_step_batch_into(
        &self,
        states: &mut [&mut DecodeState],
        tokens: &[i32],
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (lfull, d, n, f, vsz) =
            (cfg.seqlen, cfg.width, cfg.order, cfg.short_filter, cfg.vocab);
        let c = (n + 1) * d;
        let dm = cfg.mlp_dim();
        let rows = states.len();
        if rows == 0 {
            bail!("decode_step_batch wants at least one session");
        }
        if tokens.len() != rows {
            bail!("{} tokens for {rows} sessions", tokens.len());
        }
        // Validate every row before mutating anything: a batch either runs
        // whole or fails whole (the backend layer pre-filters, so a failure
        // here is a caller bug, not a serving condition).
        for (r, st) in states.iter().enumerate() {
            if st.pos >= self.max_context {
                bail!("session {r} is at the context edge (length {})", self.max_context);
            }
            if st.epoch != self.epoch {
                bail!("session {r} predates a parameter update (re-prefill it)");
            }
        }
        let pos0: Vec<usize> = states.iter().map(|s| s.pos).collect();
        let pool = &self.pool;

        let mut guard = self.serve.lock().unwrap();
        let st = &mut *guard;
        st.sync(self.epoch, self.bank.levels());
        self.ensure_decode_filters(st);
        let ServeState { arena, decode_filt, .. } = &mut *st;

        // Stacked single-position residual stream (rows, D).
        let embed = self.p(self.layout.ix.embed);
        let posw = self.p(self.layout.ix.pos);
        let mut u = arena.take(rows * d);
        for r in 0..rows {
            let tok = (tokens[r].max(0) as usize).min(vsz - 1);
            let pt = pos0[r].min(lfull - 1);
            for ch in 0..d {
                u[r * d + ch] = embed[tok * d + ch] + posw[pt * d + ch];
            }
        }

        let mut t1 = arena.take(rows * d);
        let mut xhat = arena.take(rows * d);
        let mut rstd = arena.take(rows);
        let mut zp = arena.take(rows * c);
        let mut zs = arena.take(rows * c);
        let mut va = arena.take(rows * d);
        let mut vb = arena.take(rows * d);
        let mut pre = arena.take(rows * dm);
        let mut act = arena.take(rows * dm);
        let mut th = arena.take(rows * dm);
        let mut z = arena.take(rows * d);

        for blk in 0..cfg.depth {
            let bix = &self.layout.ix.blocks[blk];
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln1_g),
                self.p(bix.ln1_b),
                rows,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            dense_fwd_into(
                pool,
                &t1,
                self.p(bix.proj_w),
                Some(self.p(bix.proj_b)),
                rows,
                d,
                c,
                &mut zp,
            );
            // Depthwise short conv at one position per row, taps 1..
            // served from each session's ring of recent projection rows.
            match bix.short_w {
                Some(sw) => {
                    let w = self.p(sw);
                    let f1 = f - 1;
                    for r in 0..rows {
                        let t = pos0[r];
                        let ds = &mut states[r].blocks[blk];
                        let zpr = &zp[r * c..(r + 1) * c];
                        let zsr = &mut zs[r * c..(r + 1) * c];
                        for ch in 0..c {
                            zsr[ch] = w[ch * f] * zpr[ch];
                        }
                        for tap in 1..f.min(t + 1) {
                            let slot = ((t - tap) % f1) * c;
                            let row = &ds.short_tail[slot..slot + c];
                            for ch in 0..c {
                                zsr[ch] += w[ch * f + tap] * row[ch];
                            }
                        }
                        if f1 > 0 {
                            let slot = (t % f1) * c;
                            ds.short_tail[slot..slot + c].copy_from_slice(zpr);
                        }
                    }
                }
                None => zs.copy_from_slice(&zp),
            }

            // The recurrence at one position per row: histories append
            // per-session, then every (row, channel-block) dot runs on the
            // pool against that row's own history.
            let bias = self.p(bix.bias);
            let hrev_all = &decode_filt[blk];
            for r in 0..rows {
                va[r * d..(r + 1) * d].copy_from_slice(&zs[r * c..r * c + d]);
            }
            for order in 0..n {
                for r in 0..rows {
                    let t = pos0[r];
                    let hist = &mut states[r].blocks[blk].hist[order];
                    if t < lfull {
                        for ch in 0..d {
                            hist[ch * lfull + t] = va[r * d + ch];
                        }
                    } else {
                        // Sliding window beyond the compiled length (see
                        // decode_step_into).
                        for ch in 0..d {
                            let row = &mut hist[ch * lfull..(ch + 1) * lfull];
                            row.copy_within(1.., 0);
                            row[lfull - 1] = va[r * d + ch];
                        }
                    }
                }
                {
                    let sref: &[&mut DecodeState] = &*states;
                    let vview = SharedMut::new(&mut vb);
                    let nblk = blocks_of(d, DECODE_CH_BLOCK);
                    pool.par_for(rows * nblk, |task| {
                        let (r, cb) = (task / nblk, task % nblk);
                        let t = pos0[r];
                        let histo = &sref[r].blocks[blk].hist[order];
                        let c0 = cb * DECODE_CH_BLOCK;
                        let c1 = (c0 + DECODE_CH_BLOCK).min(d);
                        // SAFETY: (row, channel-block) tasks partition `vb`.
                        let outb = unsafe { vview.slice(r * d + c0, c1 - c0) };
                        let hl = (t + 1).min(lfull);
                        for (j, ch) in (c0..c1).enumerate() {
                            let rowix = (order * d + ch) * lfull;
                            let hrev = &hrev_all[rowix..rowix + lfull];
                            let hist = &histo[ch * lfull..ch * lfull + hl];
                            let y = crate::backend::fft::causal_dot_step(hrev, hist)
                                + bias[order * d + ch] * va[r * d + ch];
                            // Gate x^order lives in slot order+1 of zs.
                            outb[j] = zs[r * c + (order + 1) * d + ch] * y;
                        }
                    });
                }
                std::mem::swap(&mut va, &mut vb);
            }

            // Out projection + residual, then the MLP half of the block.
            dense_fwd_into(
                pool,
                &va,
                self.p(bix.out_w),
                Some(self.p(bix.out_b)),
                rows,
                d,
                d,
                &mut z,
            );
            for i in 0..rows * d {
                u[i] += z[i];
            }
            layer_norm_fwd_into(
                &u,
                self.p(bix.ln2_g),
                self.p(bix.ln2_b),
                rows,
                d,
                &mut t1,
                &mut xhat,
                &mut rstd,
            );
            dense_fwd_into(
                pool,
                &t1,
                self.p(bix.mlp_w1),
                Some(self.p(bix.mlp_b1)),
                rows,
                d,
                dm,
                &mut pre,
            );
            gelu_fwd_into(pool, &pre, &mut act, &mut th);
            dense_fwd_into(
                pool,
                &act,
                self.p(bix.mlp_w2),
                Some(self.p(bix.mlp_b2)),
                rows,
                dm,
                d,
                &mut z,
            );
            for i in 0..rows * d {
                u[i] += z[i];
            }
        }

        let ix = &self.layout.ix;
        layer_norm_fwd_into(
            &u,
            self.p(ix.lnf_g),
            self.p(ix.lnf_b),
            rows,
            d,
            &mut t1,
            &mut xhat,
            &mut rstd,
        );
        logits.clear();
        logits.resize(rows * vsz, 0.0);
        dense_fwd_into(pool, &t1, self.p(ix.head), None, rows, d, vsz, logits);

        for v in [u, t1, xhat, rstd, zp, zs, va, vb, pre, act, th, z] {
            arena.put(v);
        }
        st.decode_steps += rows as u64;
        st.step_batch_calls += 1;
        st.step_batch_rows += rows as u64;
        for s in states.iter_mut() {
            s.pos += 1;
        }
        Ok(())
    }

    /// Finish a session: every ring/history buffer returns to the serving
    /// arena and the live-session accounting is released.
    pub fn decode_end_state(&self, state: DecodeState) {
        let mut guard = self.serve.lock().unwrap();
        let st = &mut *guard;
        st.decode_state_elems = st.decode_state_elems.saturating_sub(state.elems());
        st.sessions_live = st.sessions_live.saturating_sub(1);
        for blk in state.blocks {
            if blk.short_tail.capacity() > 0 {
                st.arena.put(blk.short_tail);
            }
            for h in blk.hist {
                st.arena.put(h);
            }
        }
    }

    /// Whether `state` predates the current parameters (the session layer
    /// re-prefills stale sessions from their token history).
    pub fn decode_state_stale(&self, state: &DecodeState) -> bool {
        state.epoch != self.epoch
    }

    /// Serving-workspace snapshot: inference-forward counts, arena
    /// accounting, cached spectra bytes, per-bucket hit counts.
    pub fn serve_stats(&self) -> ServeStats {
        let st = self.serve.lock().unwrap();
        let mut bucket_hits = vec![0u64; self.bank.levels()];
        for (h, bkt) in bucket_hits.iter_mut().zip(st.buckets.iter()) {
            *h = bkt.hits;
        }
        ServeStats {
            forwards: st.forwards,
            arena: st.arena.stats(),
            spec_bytes: st.spec_bytes(),
            bucket_lens: self.bank.lens(),
            bucket_hits,
            decode_sessions_live: st.sessions_live,
            decode_sessions_total: st.sessions_total,
            decode_steps: st.decode_steps,
            decode_step_batches: st.step_batch_calls,
            decode_step_batch_rows: st.step_batch_rows,
            decode_state_bytes: st.decode_state_elems * std::mem::size_of::<f32>(),
            max_context: self.max_context,
            ext_bucket_lens: self.bank.ext_lens(),
            prefill_chunked: st.prefill_chunked,
            prefill_chunks: st.prefill_chunks,
            prefill_chunk_bytes: st.prefill_chunk_elems * std::mem::size_of::<f32>(),
            params_epoch: self.epoch,
        }
    }

    /// Training-scratch arena snapshot (per-step high-water metrics).
    pub fn train_arena_stats(&self) -> ArenaStats {
        self.scratch.arena.stats()
    }

    /// Block-0 filters `(N, D, L)` for the Fig. D.5 dump.
    pub fn filters_block0(&self) -> Vec<f32> {
        let mut sc = Scratch::default();
        self.filter_fwd_with(0, &mut sc).0
    }
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro() -> NativeModel {
        NativeModel::new(NativeConfig::builtin("native_micro").unwrap(), 0).unwrap()
    }

    /// golden_tiny (L = 16) is the smallest builtin with a two-bucket plan
    /// ladder ([8, 16]); native_micro (L = 8) collapses to a single bucket.
    fn tiny() -> NativeModel {
        NativeModel::new(NativeConfig::builtin("golden_tiny").unwrap(), 0).unwrap()
    }

    #[test]
    fn layout_is_sorted_and_matches_python_counts() {
        // Pinned against python: golden_tiny has 27 tensors / 16320 elements,
        // lm_hyena_s has 93 tensors / 960768 elements.
        let g = Layout::new(&NativeConfig::builtin("golden_tiny").unwrap());
        assert_eq!(g.entries.len(), 27);
        assert_eq!(g.total, 16320);
        let s = Layout::new(&NativeConfig::builtin("lm_hyena_s").unwrap());
        assert_eq!(s.entries.len(), 93);
        assert_eq!(s.total, 960768);
        for w in g.entries.windows(2) {
            assert!(w[0].name < w[1].name, "{} !< {}", w[0].name, w[1].name);
        }
        let mut offset = 0;
        for e in &g.entries {
            assert_eq!(e.offset, offset);
            offset += e.numel();
        }
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let a = micro();
        let b = micro();
        let c = NativeModel::new(NativeConfig::builtin("native_micro").unwrap(), 1).unwrap();
        assert_eq!(a.params, b.params);
        assert_ne!(a.params, c.params);
        // LN gains start at exactly 1.
        let lnf = a.layout.slice(&a.params, a.layout.ix.lnf_g);
        assert!(lnf.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = micro();
        let (b, l, v) = (m.cfg.batch, m.cfg.seqlen, m.cfg.vocab);
        let tokens: Vec<i32> = (0..(b * l) as i32).map(|i| i % v as i32).collect();
        let (logits, _) = m.forward_cached(&tokens, b).unwrap();
        assert_eq!(logits.len(), b * l * v);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_is_thread_count_invariant() {
        // Disjoint-row parallelism with fixed per-row arithmetic: logits
        // must be bitwise identical for any worker count.
        let mut m1 = micro();
        let mut m3 = micro();
        m1.set_threads(1);
        m3.set_threads(3);
        assert_eq!(m1.threads(), 1);
        assert_eq!(m3.threads(), 3);
        let (b, l, v) = (m1.cfg.batch, m1.cfg.seqlen, m1.cfg.vocab);
        let tokens: Vec<i32> = (0..(b * l) as i32).map(|i| (i * 5 + 1) % v as i32).collect();
        let (la, _) = m1.forward_cached(&tokens, b).unwrap();
        let (lb, _) = m3.forward_cached(&tokens, b).unwrap();
        assert_eq!(la, lb, "thread count changed forward results");
    }

    #[test]
    fn train_step_is_thread_count_invariant() {
        let mut m1 = micro();
        let mut m2 = micro();
        m1.set_threads(1);
        m2.set_threads(2);
        let (b, l, v) = (m1.cfg.batch, m1.cfg.seqlen, m1.cfg.vocab);
        let mut rng = Pcg::new(21);
        let tokens: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
        let targets: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
        let mask = vec![1.0f32; b * l];
        for step in 0..4 {
            let la = m1.train_step(&tokens, &targets, &mask, b).unwrap();
            let lb = m2.train_step(&tokens, &targets, &mask, b).unwrap();
            assert_eq!(la, lb, "thread count changed loss at step {step}");
        }
        assert_eq!(m1.params, m2.params, "thread count changed parameters");
    }

    #[test]
    fn forward_is_causal() {
        // Perturbing tokens at position ≥ t0 must not change logits before t0.
        let m = micro();
        let (b, l, v) = (1usize, m.cfg.seqlen, m.cfg.vocab);
        let tokens: Vec<i32> = (0..l as i32).map(|i| (i * 7 + 3) % v as i32).collect();
        let t0 = l / 2;
        let mut tokens2 = tokens.clone();
        for t in t0..l {
            tokens2[t] = (tokens2[t] + 1) % v as i32;
        }
        let (la, _) = m.forward_cached(&tokens, b).unwrap();
        let (lb, _) = m.forward_cached(&tokens2, b).unwrap();
        for t in 0..t0 {
            for ch in 0..v {
                let (x, y) = (la[t * v + ch], lb[t * v + ch]);
                assert!(
                    (x - y).abs() <= 1e-4 * (1.0 + x.abs()),
                    "position {t} saw the future: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn gradcheck_against_finite_differences() {
        // Central differences on a sample of parameters from every tensor.
        // f32 arithmetic: expect ~1e-2 relative agreement at eps = 1e-3·scale.
        let mut m = micro();
        let (b, l, v) = (m.cfg.batch, m.cfg.seqlen, m.cfg.vocab);
        let mut rng = Pcg::new(42);
        let tokens: Vec<i32> =
            (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
        let targets: Vec<i32> =
            (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
        let mask: Vec<f32> = (0..b * l).map(|_| if rng.f32() < 0.8 { 1.0 } else { 0.0 }).collect();

        let (mut logits, cache) = m.forward_cached(&tokens, b).unwrap();
        let _ = m.loss_and_dlogits(&mut logits, &targets, &mask);
        let mut grads = vec![0.0f32; m.layout.total];
        m.backward(&logits, &cache, &mut grads);

        let loss_at = |m: &NativeModel| -> f32 {
            let (mut lg, _) = m.forward_cached(&tokens, b).unwrap();
            m.loss_and_dlogits(&mut lg, &targets, &mask)
        };

        let entries = m.layout.entries.clone();
        let mut checked = 0usize;
        for e in &entries {
            for probe in 0..2usize {
                let i = e.offset + (probe * 31 + 7) % e.numel();
                let orig = m.params[i];
                // eps balances truncation against f32 round-off in the loss.
                let eps = 1e-2 * (1.0 + orig.abs());
                m.params[i] = orig + eps;
                let lp = loss_at(&m);
                m.params[i] = orig - eps;
                let lm = loss_at(&m);
                m.params[i] = orig;
                let num = (lp - lm) / (2.0 * eps);
                let ana = grads[i];
                let tol = 2e-2 * (num.abs() + ana.abs()) + 2e-3;
                assert!(
                    (num - ana).abs() <= tol,
                    "{}[{}]: numeric {num} vs analytic {ana}",
                    e.name,
                    i - e.offset
                );
                checked += 1;
            }
        }
        assert!(checked >= 40, "gradcheck probed too few parameters");
    }

    #[test]
    fn fixed_batch_training_reduces_loss() {
        let mut m = micro();
        let (b, l, v) = (m.cfg.batch, m.cfg.seqlen, m.cfg.vocab);
        let mut rng = Pcg::new(7);
        let tokens: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
        let targets: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
        let mask = vec![1.0f32; b * l];
        let mut first = None;
        let mut last = f32::INFINITY;
        // The equivalent f64 prototype drops ~0.52 nats by step 120 on this
        // config; 0.25 leaves 2× margin for f32/init variation.
        for _ in 0..120 {
            last = m.train_step(&tokens, &targets, &mask, b).unwrap();
            first.get_or_insert(last);
        }
        let first = first.unwrap();
        assert!(last.is_finite());
        assert!(last < first - 0.25, "loss did not drop: {first} -> {last}");
        assert_eq!(m.step, 120);
    }

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let m = micro();
        let peak = m.cfg.lr;
        assert!(m.lr_at(0.0) < peak * 0.05);
        let warm_end = m.lr_at(m.cfg.warmup_steps - 1.0);
        assert!((warm_end - peak).abs() < peak * 0.05);
        assert!(m.lr_at(m.cfg.total_steps) <= peak * 0.11);
    }

    #[test]
    fn infer_at_full_bucket_is_bitwise_equal_to_forward() {
        // The serving path at the largest bucket runs the exact kernels of
        // the training forward, so the logits must agree bit-for-bit.
        let m = micro();
        let (l, v) = (m.cfg.seqlen, m.cfg.vocab);
        let b = 2usize;
        let tokens: Vec<i32> = (0..(b * l) as i32).map(|i| (i * 3 + 1) % v as i32).collect();
        let (want, _) = m.forward_cached(&tokens, b).unwrap();
        let (got, lb) = m.forward_infer(&tokens, b, l).unwrap();
        assert_eq!(lb, l);
        assert_eq!(got, want, "full-bucket infer diverged from forward_cached");
    }

    #[test]
    fn infer_at_small_bucket_matches_full_pad_prefix() {
        // A short prompt through its small bucket must reproduce the
        // full-pad logits at every prompt position. The FFT sizes differ
        // between the plans, so agreement is within f32 round-off.
        let m = tiny();
        let (l, v) = (m.cfg.seqlen, m.cfg.vocab);
        assert!(m.bucket_lens().len() > 1, "golden_tiny should have ≥ 2 buckets");
        let lq = m.bucket_lens()[0]; // smallest bucket
        assert!(lq < l);
        let tokens: Vec<i32> = (0..lq as i32).map(|i| (i * 5 + 2) % v as i32).collect();
        // Full-pad reference: pad to L with token 0 (the serving convention).
        let mut padded = tokens.clone();
        padded.resize(l, 0);
        let (full, _) = m.forward_cached(&padded, 1).unwrap();
        let (got, lb) = m.forward_infer(&tokens, 1, lq).unwrap();
        assert_eq!(lb, lq);
        assert_eq!(got.len(), lq * v);
        for t in 0..lq {
            for ch in 0..v {
                let (a, b_) = (got[t * v + ch], full[t * v + ch]);
                assert!(
                    (a - b_).abs() <= 1e-4 * (1.0 + a.abs().max(b_.abs())),
                    "bucketed logits diverged at t={t} ch={ch}: {a} vs {b_}"
                );
            }
        }
    }

    #[test]
    fn infer_steady_state_allocates_nothing() {
        let m = tiny();
        let (l, v) = (m.cfg.seqlen, m.cfg.vocab);
        let lq = l / 2;
        let tokens: Vec<i32> = (0..lq as i32).map(|i| i % v as i32).collect();
        let mut out = Vec::new();
        // Warm until the arena accounting stabilizes (first pass builds the
        // spectra cache and grows buffers; LIFO reuse then converges).
        m.forward_infer_into(&tokens, 1, lq, &mut out).unwrap();
        let mut warm = m.serve_stats();
        for _ in 0..8 {
            m.forward_infer_into(&tokens, 1, lq, &mut out).unwrap();
            let now = m.serve_stats();
            let settled = now.arena == warm.arena;
            warm = now;
            if settled {
                break;
            }
        }
        let first = out.clone();
        for _ in 0..8 {
            m.forward_infer_into(&tokens, 1, lq, &mut out).unwrap();
        }
        let after = m.serve_stats();
        assert_eq!(out, first, "steady-state infer changed its answer");
        assert_eq!(
            warm.arena.allocs, after.arena.allocs,
            "steady-state serving still allocates"
        );
        assert_eq!(
            warm.arena.hiwater_bytes, after.arena.hiwater_bytes,
            "steady-state serving grew the arena high-water mark"
        );
        assert_eq!(after.forwards, warm.forwards + 8);
        assert!(after.spec_bytes > 0, "filter spectra should be cached");
    }

    #[test]
    fn infer_tracks_param_changes() {
        // Cached spectra must be invalidated when the optimizer steps; the
        // serving path re-agrees with the training forward afterwards.
        let mut m = micro();
        let (b, l, v) = (m.cfg.batch, m.cfg.seqlen, m.cfg.vocab);
        let tokens: Vec<i32> = (0..(b * l) as i32).map(|i| (i * 7 + 1) % v as i32).collect();
        let (before, _) = m.forward_infer(&tokens, b, l).unwrap();
        let targets = tokens.clone();
        let mask = vec![1.0f32; b * l];
        m.train_step(&tokens, &targets, &mask, b).unwrap();
        let (want, _) = m.forward_cached(&tokens, b).unwrap();
        let (after, _) = m.forward_infer(&tokens, b, l).unwrap();
        assert_ne!(before, after, "infer ignored a parameter update");
        assert_eq!(after, want, "infer out of sync with forward after train step");
    }

    #[test]
    fn infer_is_thread_count_invariant() {
        let mut m1 = tiny();
        let mut m3 = tiny();
        m1.set_threads(1);
        m3.set_threads(3);
        let (l, v) = (m1.cfg.seqlen, m1.cfg.vocab);
        let lq = l / 2;
        let tokens: Vec<i32> = (0..(2 * lq) as i32).map(|i| (i * 3 + 2) % v as i32).collect();
        let (a, _) = m1.forward_infer(&tokens, 2, lq).unwrap();
        let (b, _) = m3.forward_infer(&tokens, 2, lq).unwrap();
        assert_eq!(a, b, "thread count changed bucketed inference results");
    }

    #[test]
    fn bucket_ladder_respects_levels_override() {
        let mut m = tiny();
        let l = m.cfg.seqlen;
        assert_eq!(m.bucket_lens(), vec![8, l]);
        m.set_bucket_levels(1);
        assert_eq!(m.bucket_lens(), vec![l]);
        let (_, lb) = m
            .forward_infer(&(0..2).map(|i| i as i32).collect::<Vec<_>>(), 1, 2)
            .unwrap();
        assert_eq!(lb, l, "levels=1 must route everything to the full plan");
    }

    #[test]
    fn train_arena_stats_reach_steady_state() {
        let mut m = micro();
        let (b, l, v) = (m.cfg.batch, m.cfg.seqlen, m.cfg.vocab);
        let tokens: Vec<i32> = (0..(b * l) as i32).map(|i| i % v as i32).collect();
        let mask = vec![1.0f32; b * l];
        // Warm until the per-step accounting stabilizes.
        m.train_step(&tokens, &tokens, &mask, b).unwrap();
        let mut warm = m.train_arena_stats();
        for _ in 0..8 {
            m.train_step(&tokens, &tokens, &mask, b).unwrap();
            let now = m.train_arena_stats();
            let settled = now == warm;
            warm = now;
            if settled {
                break;
            }
        }
        assert!(warm.hiwater_bytes > 0);
        for _ in 0..3 {
            m.train_step(&tokens, &tokens, &mask, b).unwrap();
        }
        let after = m.train_arena_stats();
        assert_eq!(warm.allocs, after.allocs, "steady-state training still allocates");
        assert_eq!(warm.hiwater_bytes, after.hiwater_bytes);
    }

    /// Greedy argmax (mirror of `coordinator::generation::argmax` to keep
    /// the backend tests free of coordinator imports).
    fn amax(row: &[f32]) -> i32 {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap()
    }

    /// Recompute reference: decode `gen` greedy tokens by re-running the
    /// growing prefix through the bucketed infer path each round, returning
    /// the token stream and every sampled-position logits row.
    fn recompute_decode(m: &NativeModel, prompt: &[i32], gen: usize) -> (Vec<i32>, Vec<Vec<f32>>) {
        let v = m.cfg.vocab;
        let mut seq = prompt.to_vec();
        let (mut toks, mut rows) = (Vec::new(), Vec::new());
        for _ in 0..gen {
            let (lg, _) = m.forward_infer(&seq, 1, seq.len()).unwrap();
            let row = lg[(seq.len() - 1) * v..seq.len() * v].to_vec();
            let tok = amax(&row);
            seq.push(tok);
            toks.push(tok);
            rows.push(row);
        }
        (toks, rows)
    }

    /// Streamed: one prefill, then `decode_step_into` per token.
    fn streamed_decode(m: &NativeModel, prompt: &[i32], gen: usize) -> (Vec<i32>, Vec<Vec<f32>>) {
        let mut logits = Vec::new();
        let mut st = m.decode_begin_state(prompt, &mut logits).unwrap();
        let (mut toks, mut rows) = (Vec::new(), Vec::new());
        rows.push(logits.clone());
        toks.push(amax(&logits));
        for _ in 1..gen {
            let tok = *toks.last().unwrap();
            m.decode_step_into(&mut st, tok, &mut logits).unwrap();
            rows.push(logits.clone());
            toks.push(amax(&logits));
        }
        m.decode_end_state(st);
        (toks, rows)
    }

    #[test]
    fn streamed_decode_matches_recompute_across_bucket_boundaries() {
        // golden_tiny buckets at [8, 16]: a 6-token prompt prefills in the
        // small bucket and the stream crosses into full-window territory
        // mid-generation. Greedy tokens must be identical to the
        // full-recompute path; logits agree to f32 round-off (the FFT of
        // the recompute path and the time-domain dot of the streamed path
        // round differently — bitwise equality is impossible in principle,
        // DESIGN.md §Decode).
        let m = tiny();
        let prompt = vec![3i32, 5, 7, 2, 9, 4];
        let gen = 8;
        let (rec_toks, rec_rows) = recompute_decode(&m, &prompt, gen);
        let (str_toks, str_rows) = streamed_decode(&m, &prompt, gen);
        assert_eq!(str_toks, rec_toks, "streamed greedy decode diverged from recompute");
        for (k, (a, b)) in str_rows.iter().zip(rec_rows.iter()).enumerate() {
            for (ch, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-3 * (1.0 + x.abs().max(y.abs())),
                    "step {k} ch {ch}: streamed {x} vs recompute {y}"
                );
            }
        }
    }

    #[test]
    fn streamed_decode_is_thread_count_invariant() {
        // The per-channel dots are serial within a channel and channels
        // partition the output, so streamed logits must be bitwise equal
        // for any worker count.
        let mut m1 = tiny();
        let mut m3 = tiny();
        m1.set_threads(1);
        m3.set_threads(3);
        let prompt = vec![1i32, 8, 2, 6];
        let (t1, r1) = streamed_decode(&m1, &prompt, 6);
        let (t3, r3) = streamed_decode(&m3, &prompt, 6);
        assert_eq!(t1, t3);
        assert_eq!(r1, r3, "thread count changed streamed decode logits");
    }

    #[test]
    fn decode_session_churn_reaches_zero_alloc_steady_state() {
        // Repeated begin → step → end cycles must stop growing the serving
        // arena: all session state round-trips through it.
        let m = tiny();
        let prompt = vec![2i32, 4, 6];
        let mut warm = None;
        for _ in 0..10 {
            streamed_decode(&m, &prompt, 5);
            let s = m.serve_stats();
            let snap = (s.arena.allocs, s.arena.hiwater_bytes);
            if warm == Some(snap) {
                break;
            }
            warm = Some(snap);
        }
        let warm = warm.unwrap();
        for _ in 0..6 {
            streamed_decode(&m, &prompt, 5);
        }
        let s = m.serve_stats();
        assert_eq!(
            (s.arena.allocs, s.arena.hiwater_bytes),
            warm,
            "steady-state decode sessions kept allocating"
        );
        assert_eq!(s.decode_sessions_live, 0, "sessions leaked");
        // Warm loop runs ≥ 2 cycles before settling, plus the 6 pinned ones.
        assert!(s.decode_sessions_total >= 8);
        assert!(s.decode_steps >= 8 * 4);
        assert_eq!(s.decode_state_bytes, 0, "state bytes leaked after decode_end");
        assert!(s.spec_bytes > 0, "decode filters should be cached");
    }

    #[test]
    fn decode_state_goes_stale_on_param_updates() {
        let mut m = micro();
        let prompt = vec![1i32, 2, 3];
        let mut logits = Vec::new();
        let mut st = m.decode_begin_state(&prompt, &mut logits).unwrap();
        m.decode_step_into(&mut st, 5, &mut logits).unwrap();
        assert!(!m.decode_state_stale(&st));
        // An optimizer step bumps the params epoch: the streamed state must
        // refuse to keep extrapolating from pre-update histories.
        let (b, l, v) = (m.cfg.batch, m.cfg.seqlen, m.cfg.vocab);
        let tokens: Vec<i32> = (0..(b * l) as i32).map(|i| i % v as i32).collect();
        let mask = vec![1.0f32; b * l];
        m.train_step(&tokens, &tokens, &mask, b).unwrap();
        assert!(m.decode_state_stale(&st));
        assert!(m.decode_step_into(&mut st, 5, &mut logits).is_err());
        m.decode_end_state(st);
        // A fresh session tracks the new parameters.
        let st2 = m.decode_begin_state(&prompt, &mut logits).unwrap();
        assert!(!m.decode_state_stale(&st2));
        m.decode_end_state(st2);
    }

    #[test]
    fn decode_rejects_out_of_window_sessions() {
        let m = micro(); // L = 8
        let mut logits = Vec::new();
        assert!(m.decode_begin_state(&[], &mut logits).is_err());
        assert!(m.decode_begin_state(&[1; 8], &mut logits).is_err());
        let mut st = m.decode_begin_state(&[1; 7], &mut logits).unwrap();
        m.decode_step_into(&mut st, 2, &mut logits).unwrap(); // position 7
        let err = m.decode_step_into(&mut st, 2, &mut logits);
        assert!(err.is_err(), "stepped past the window edge");
        m.decode_end_state(st);
    }

    #[test]
    fn decode_step_batch_is_bitwise_identical_to_serial_steps() {
        // The batched round runs each row through exactly the serial
        // step's arithmetic (dense kernels are per-row independent, LN and
        // GELU are per-row/per-element, dots read per-session histories),
        // so logits must agree bit-for-bit — including rows at different
        // positions.
        let m = tiny();
        let prompts: [&[i32]; 3] = [&[3, 5, 7], &[9, 1, 2, 6, 11], &[4, 4]];
        let mut lg = Vec::new();
        let mut serial: Vec<DecodeState> =
            prompts.iter().map(|p| m.decode_begin_state(p, &mut lg).unwrap()).collect();
        let mut batched: Vec<DecodeState> =
            prompts.iter().map(|p| m.decode_begin_state(p, &mut lg).unwrap()).collect();
        let v = m.cfg.vocab;
        let mut packed = Vec::new();
        for round in 0..5 {
            let toks: Vec<i32> = (0..3).map(|r| ((round * 3 + r) % v) as i32).collect();
            let mut want = Vec::new();
            for (r, st) in serial.iter_mut().enumerate() {
                m.decode_step_into(st, toks[r], &mut lg).unwrap();
                want.extend_from_slice(&lg);
            }
            let mut refs: Vec<&mut DecodeState> = batched.iter_mut().collect();
            m.decode_step_batch_into(&mut refs, &toks, &mut packed).unwrap();
            assert_eq!(packed, want, "batched logits diverged at round {round}");
        }
        for st in serial.into_iter().chain(batched) {
            m.decode_end_state(st);
        }
        let stats = m.serve_stats();
        assert_eq!(stats.decode_step_batches, 5);
        assert_eq!(stats.decode_step_batch_rows, 15);
        // Serial steps + batched rows both count as streamed tokens.
        assert_eq!(stats.decode_steps, 30);
    }

    #[test]
    fn decode_step_batch_validates_rows_before_touching_state() {
        let mut m = micro(); // L = 8
        let mut lg = Vec::new();
        let mut packed = Vec::new();
        // Window edge: a full session in the batch fails the whole call.
        let mut edge = m.decode_begin_state(&[1; 7], &mut lg).unwrap();
        m.decode_step_into(&mut edge, 2, &mut lg).unwrap(); // position 7
        let pos_before = edge.pos();
        {
            let mut refs: Vec<&mut DecodeState> = vec![&mut edge];
            assert!(m.decode_step_batch_into(&mut refs, &[3], &mut packed).is_err());
        }
        assert_eq!(edge.pos(), pos_before, "failed batch advanced a session");
        m.decode_end_state(edge);
        // Stale epoch: refused (the backend layer re-prefills instead).
        let mut st = m.decode_begin_state(&[1, 2, 3], &mut lg).unwrap();
        let (b, l, v) = (m.cfg.batch, m.cfg.seqlen, m.cfg.vocab);
        let tokens: Vec<i32> = (0..(b * l) as i32).map(|i| i % v as i32).collect();
        let mask = vec![1.0f32; b * l];
        m.train_step(&tokens, &tokens, &mask, b).unwrap();
        {
            let mut refs: Vec<&mut DecodeState> = vec![&mut st];
            assert!(m.decode_step_batch_into(&mut refs, &[1], &mut packed).is_err());
        }
        m.decode_end_state(st);
    }

    #[test]
    fn decode_step_batch_steady_state_is_zero_alloc() {
        // Repeated (begin 3 sessions → 4 batched rounds → end) cycles must
        // stop growing the serving arena, like the serial session churn.
        let m = tiny();
        let prompts: [&[i32]; 3] = [&[2, 4, 6], &[1, 3], &[5, 7, 9, 11]];
        let mut cycle = || {
            let mut lg = Vec::new();
            let mut packed = Vec::new();
            let mut states: Vec<DecodeState> =
                prompts.iter().map(|p| m.decode_begin_state(p, &mut lg).unwrap()).collect();
            for round in 0..4 {
                let toks = [round as i32, round as i32 + 1, round as i32 + 2];
                let mut refs: Vec<&mut DecodeState> = states.iter_mut().collect();
                m.decode_step_batch_into(&mut refs, &toks, &mut packed).unwrap();
            }
            for st in states {
                m.decode_end_state(st);
            }
        };
        let mut warm = None;
        for _ in 0..10 {
            cycle();
            let s = m.serve_stats();
            let snap = (s.arena.allocs, s.arena.hiwater_bytes);
            if warm == Some(snap) {
                break;
            }
            warm = Some(snap);
        }
        let warm = warm.unwrap();
        for _ in 0..6 {
            cycle();
        }
        let s = m.serve_stats();
        assert_eq!(
            (s.arena.allocs, s.arena.hiwater_bytes),
            warm,
            "steady-state batched decode kept allocating"
        );
        assert_eq!(s.decode_sessions_live, 0, "sessions leaked");
        assert_eq!(s.decode_state_bytes, 0, "state bytes leaked");
    }

    #[test]
    fn f64_accumulation_bounds_drift_at_8k() {
        // First slice of the f64-accumulation audit (DESIGN.md §Decode):
        // LN statistics and the CE log-sum-exp accumulate in f64. Pin the
        // drift at reduction width 8192 against (a) an exact f64 reference
        // and (b) the old f32-accumulated arithmetic.
        let d = 8192usize;
        let mut rng = Pcg::new(99);
        // Large common mode: the f32 mean sum loses absolute precision and
        // the variance then suffers cancellation.
        let x: Vec<f32> = (0..d).map(|_| 3.0e4 + rng.normal()).collect();
        let mu64 = x.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var64 =
            x.iter().map(|&v| (v as f64 - mu64) * (v as f64 - mu64)).sum::<f64>() / d as f64;
        let rs_ref = 1.0 / (var64 + LN_EPS as f64).sqrt();
        // Old path: f32 accumulators (the pre-PR-4 kernel, inlined).
        let mut mu32 = 0.0f32;
        for &v in &x {
            mu32 += v;
        }
        mu32 /= d as f32;
        let mut var32 = 0.0f32;
        for &v in &x {
            var32 += (v - mu32) * (v - mu32);
        }
        var32 /= d as f32;
        let rs32 = 1.0 / (var32 + LN_EPS).sqrt();
        // Shipped kernel.
        let (g, b) = (vec![1.0f32; d], vec![0.0f32; d]);
        let (mut y, mut xh, mut rstd) = (vec![0.0f32; d], vec![0.0f32; d], vec![0.0f32; 1]);
        layer_norm_fwd_into(&x, &g, &b, 1, d, &mut y, &mut xh, &mut rstd);
        let err_new = ((rstd[0] as f64) - rs_ref).abs() / rs_ref;
        let err_old = ((rs32 as f64) - rs_ref).abs() / rs_ref;
        assert!(err_new <= 5e-6, "f64-accumulated rstd drifted: {err_new}");
        assert!(
            err_new <= err_old,
            "f64 accumulation did not improve on f32: {err_new} vs {err_old}"
        );

        // Log-sum-exp over an 8192-wide support: f64 accumulation of the
        // exp sum must track the exact value tighter than the f32 sum.
        let logits: Vec<f32> = (0..d).map(|_| rng.normal() * 3.0).collect();
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse_ref = mx as f64
            + logits.iter().map(|&v| ((v - mx) as f64).exp()).sum::<f64>().ln();
        let mut se32 = 0.0f32;
        for &v in &logits {
            se32 += (v - mx).exp();
        }
        let lse32 = (mx + se32.ln()) as f64;
        let mut se64 = 0.0f64;
        for &v in &logits {
            se64 += ((v - mx) as f64).exp();
        }
        let lse64 = (mx as f64 + se64.ln()) as f32 as f64; // shipped: f64 sum, f32 store
        assert!((lse64 - lse_ref).abs() <= (lse32 - lse_ref).abs() + 1e-6);
        assert!((lse64 - lse_ref).abs() / lse_ref.abs() <= 1e-6);

        // End-to-end: the shipped masked CE at 8K rows stays within 1e-5
        // relative of a full-f64 mirror.
        let m = micro();
        let v = m.cfg.vocab;
        let rows = 8192usize;
        let mut lg: Vec<f32> = (0..rows * v).map(|_| rng.normal() * 2.0).collect();
        let targets: Vec<i32> = (0..rows).map(|_| rng.usize_below(v) as i32).collect();
        let mask = vec![1.0f32; rows];
        let mut ref_loss = 0.0f64;
        for r in 0..rows {
            let row = &lg[r * v..(r + 1) * v];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let lse = mx + row.iter().map(|&x| (x as f64 - mx).exp()).sum::<f64>().ln();
            ref_loss += lse - row[targets[r] as usize] as f64;
        }
        ref_loss /= rows as f64;
        let got = m.loss_and_dlogits(&mut lg, &targets, &mask) as f64;
        assert!(
            (got - ref_loss).abs() / ref_loss.abs() <= 1e-5,
            "CE drifted from the f64 mirror: {got} vs {ref_loss}"
        );
    }

    #[test]
    fn filters_have_filter_shape_and_decay() {
        let m = micro();
        let h = m.filters_block0();
        let (n, d, l) = (m.cfg.order, m.cfg.width, m.cfg.seqlen);
        assert_eq!(h.len(), n * d * l);
        assert!(h.iter().all(|x| x.is_finite()));
        // The decay window must shrink filter magnitude envelopes over t on
        // average (early positions louder than late ones).
        let early: f32 = (0..n * d).map(|ch| h[ch * l].abs()).sum();
        let late: f32 = (0..n * d).map(|ch| h[ch * l + l - 1].abs()).sum();
        assert!(early > late, "window not decaying: {early} vs {late}");
    }

    // -- chunked overlap-save prefill / extended context ---------------------

    fn assert_rel_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "{what}: elem {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn longctx_chunked_prefill_is_bitwise_monolithic_at_full_bucket() {
        // One full chunk with empty carries runs the exact monolithic op
        // sequence at the same FFT size: the ISSUE's bitwise gate.
        let m = tiny(); // L = 16
        let l = m.cfg.seqlen;
        let tokens: Vec<i32> = (0..l as i32).map(|i| (i * 5 + 1) % m.cfg.vocab as i32).collect();
        let (want, _) = m.forward_infer(&tokens, 1, l).unwrap();
        let (got, chunk) = m.forward_infer_chunked(&tokens, l).unwrap();
        assert_eq!(chunk, l);
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "elem {i}: chunked {g} != monolithic {w}");
        }
        let s = m.serve_stats();
        assert_eq!(s.prefill_chunked, 1);
        assert_eq!(s.prefill_chunks, 1);
    }

    #[test]
    fn longctx_multi_chunk_prefill_matches_ext_reference() {
        // Prompts past the compiled window stream in L-sized chunks with
        // carried state; the monolithic extended plan (one big FFT, filters
        // zero-extended past their support) is the oracle.
        let mut m = tiny(); // L = 16
        m.set_max_context(64).unwrap();
        assert_eq!(m.max_context(), 64);
        for lq in [17usize, 32, 40, 61] {
            let tokens: Vec<i32> =
                (0..lq as i32).map(|i| (i * 7 + 3) % m.cfg.vocab as i32).collect();
            let (got, _) = m.forward_infer_chunked(&tokens, lq).unwrap();
            let want = m.forward_infer_ext_reference(&tokens, lq).unwrap();
            assert_rel_close(&got, &want, 1e-3, &format!("chunked vs ext reference at {lq}"));
        }
        let s = m.serve_stats();
        assert_eq!(s.ext_bucket_lens, vec![32, 64]);
        assert_eq!(s.prefill_chunks, 2 + 2 + 3 + 4);
        // forward_infer_into routes long single-row requests automatically.
        let tokens: Vec<i32> = (0..20).collect();
        let mut out = Vec::new();
        m.forward_infer_into(&tokens, 1, 20, &mut out).unwrap();
        assert_eq!(out.len(), 20 * m.cfg.vocab);
    }

    #[test]
    fn longctx_decode_beyond_window_matches_ext_reference() {
        // A 40-token prompt (2.5 chunks) prefills a decode session; each
        // subsequent step runs the sliding-window recurrence past the
        // compiled length. The growing-prefix ext reference pins every
        // logits row.
        let mut m = tiny(); // L = 16
        m.set_max_context(64).unwrap();
        let v = m.cfg.vocab;
        let prompt: Vec<i32> = (0..40i32).map(|i| (i * 3 + 2) % v as i32).collect();
        let mut lg = Vec::new();
        let mut st = m.decode_begin_state(&prompt, &mut lg).unwrap();
        assert_eq!(st.pos(), 40);
        let mut seq = prompt.clone();
        for step in 0..6 {
            let want = m.forward_infer_ext_reference(&seq, seq.len()).unwrap();
            let last = &want[(seq.len() - 1) * v..seq.len() * v];
            assert_rel_close(&lg, last, 1e-3, &format!("decode step {step}"));
            let tok = amax(&lg);
            assert_eq!(tok, amax(last), "greedy token diverged at step {step}");
            m.decode_step_into(&mut st, tok, &mut lg).unwrap();
            seq.push(tok);
        }
        m.decode_end_state(st);
    }

    #[test]
    fn longctx_forced_chunked_begin_matches_bucketed_begin() {
        // Below the window the two prefills transform the same math at the
        // same full-bucket FFT size (the bucketed path pads rows, the
        // chunked path doesn't), so logits agree to round-off and greedy
        // continuations are token-identical.
        let m = tiny();
        let prompt = vec![3i32, 5, 7, 2, 9, 4, 1, 8, 6, 2, 4, 10];
        let (mut lg_a, mut lg_b) = (Vec::new(), Vec::new());
        let mut sa = m.decode_begin_state(&prompt, &mut lg_a).unwrap();
        let mut sb = m.decode_begin_state_chunked(&prompt, &mut lg_b).unwrap();
        assert_rel_close(&lg_a, &lg_b, 1e-3, "prefill logits");
        for step in 0..6 {
            let (ta, tb) = (amax(&lg_a), amax(&lg_b));
            assert_eq!(ta, tb, "greedy streams diverged at step {step}");
            m.decode_step_into(&mut sa, ta, &mut lg_a).unwrap();
            m.decode_step_into(&mut sb, tb, &mut lg_b).unwrap();
            assert_rel_close(&lg_a, &lg_b, 1e-3, &format!("step {step} logits"));
        }
        m.decode_end_state(sa);
        m.decode_end_state(sb);
    }

    #[test]
    fn longctx_prefill_activation_bytes_are_o_chunk() {
        // The ISSUE's memory gate at model scale: the chunked working set
        // is sized by the chunk, so a prompt 8× longer must not move the
        // per-prefill high-water gauge.
        let mut m = tiny(); // L = 16
        m.set_max_context(2048).unwrap();
        let v = m.cfg.vocab as i32;
        let short: Vec<i32> = (0..100i32).map(|i| i % v).collect();
        let mut out = Vec::new();
        m.forward_infer_chunked_impl(&short, short.len(), &mut out, false, None).unwrap();
        let gauge = m.serve_stats().prefill_chunk_bytes;
        assert!(gauge > 0);
        let long: Vec<i32> = (0..800i32).map(|i| i % v).collect();
        m.forward_infer_chunked_impl(&long, long.len(), &mut out, false, None).unwrap();
        let s = m.serve_stats();
        assert_eq!(
            s.prefill_chunk_bytes, gauge,
            "chunked prefill working set grew with prompt length"
        );
        assert_eq!(s.prefill_chunked, 2);
        assert_eq!(s.prefill_chunks, 7 + 50);
    }

    #[test]
    fn longctx_set_max_context_validates_and_rebuilds_ladder() {
        let mut m = tiny(); // L = 16
        assert_eq!(m.max_context(), 16);
        assert!(m.set_max_context(8).is_err(), "shrinking below seqlen must fail");
        m.set_max_context(100).unwrap();
        assert_eq!(m.serve_stats().ext_bucket_lens, vec![32, 64, 128]);
        // The bucketed serving ladder is unchanged.
        assert_eq!(m.serve_stats().bucket_lens, vec![8, 16]);
        // Prompts past max_context are still rejected.
        let tokens = vec![1i32; 101];
        assert!(m.forward_infer_chunked(&tokens, 101).is_err());
        let mut lg = Vec::new();
        assert!(m.decode_begin_state(&vec![1i32; 100], &mut lg).is_err());
    }
}
