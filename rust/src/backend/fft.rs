//! Iterative radix-2 FFT, a real-input (rfft) plan on top of it, and the
//! causal FFT convolution they power.
//!
//! This is the native backend's replacement for the XLA `Fft` op: the
//! O(L log L) "FFTConv" of the paper (Sec. 2, "Fast Methods for
//! Convolutions"). A causal aperiodic convolution of two length-L signals is
//! computed by zero-padding both to the next power of two ≥ 2L, multiplying
//! spectra, and truncating the circular result back to L.
//!
//! Two throughput decisions shape the API (DESIGN.md §Perf):
//!
//! * **Real-input transforms.** Every signal in the model is real, so
//!   [`RealFft`] packs two real samples into one complex sample and runs a
//!   *half-size* complex FFT (the classic pack-trick rfft), then untangles
//!   the half spectrum `n/2 + 1` bins. Half the butterflies, half the
//!   spectrum memory of the full complex transform PR 1 shipped (kept as
//!   [`ComplexCausalConv`] for benches and cross-checks).
//! * **Caller-owned workspaces.** The hot entry points
//!   ([`CausalConv::spectrum_into`], [`CausalConv::conv_spec_into`],
//!   [`CausalConv::corr_spec_into`]) write into caller-provided buffers and
//!   borrow scratch from a [`ConvWorkspace`], so the per-row inner loops of
//!   the model allocate nothing. The allocating conveniences (`spectrum`,
//!   `conv`, `corr`, ...) remain for tests and cold paths.
//!
//! [`CausalConv`] is a small *plan*: it owns the twiddle tables for one
//! transform size so repeated convolutions at a fixed sequence length (the
//! hot path of every Hyena block) pay the trigonometry once. Gradients reuse
//! the same plan: the adjoint of `conv(h, ·)` is correlation with `h`
//! ([`CausalConv::corr`]), i.e. multiplication by the conjugate spectrum.

// Index-based butterfly/untangle loops mirror the validated reference math
// (and the Python mirror used to derive it) one-to-one.
#![allow(clippy::needless_range_loop)]

use crate::backend::native::kernels;
use crate::util::rng::Pcg;

/// Radix-2 decimation-in-time FFT plan for one power-of-two size.
pub struct Fft {
    n: usize,
    /// Twiddles `w_k = exp(-2πik/n)` for `k < n/2`.
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl Fft {
    /// Build a plan for transform size `n` (must be a power of two ≥ 1).
    pub fn new(n: usize) -> Fft {
        assert!(n.is_power_of_two(), "FFT size {n} is not a power of two");
        let half = n / 2;
        let mut tw_re = Vec::with_capacity(half);
        let mut tw_im = Vec::with_capacity(half);
        for k in 0..half {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        Fft { n, tw_re, tw_im }
    }

    /// Transform size.
    pub fn size(&self) -> usize {
        self.n
    }

    /// In-place forward transform of `(re, im)`.
    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, false);
    }

    /// In-place inverse transform (includes the 1/n scale).
    pub fn inverse(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, true);
    }

    fn run(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        // HYENA_PROF hook: one timer per plan run (forward or inverse
        // pass), not per stage — the disabled check is one relaxed load.
        let prof_t0 = if crate::obs::prof::enabled() {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let n = self.n;
        assert_eq!(re.len(), n, "re buffer length != plan size");
        assert_eq!(im.len(), n, "im buffer length != plan size");
        if n == 1 {
            if let Some(t0) = prof_t0 {
                crate::obs::prof::FFT.record(t0.elapsed().as_nanos() as u64);
            }
            return;
        }

        // Bit-reversal permutation.
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }

        // Butterflies; at stage `len`, butterfly j uses twiddle w_{j·(n/len)}.
        // Each stage runs through the dispatched kernel (scalar = the
        // original loop verbatim; SIMD = 8-lane, bitwise-identical math —
        // DESIGN.md §Kernels).
        let k = kernels::active();
        let mut len = 2usize;
        while len <= n {
            (k.butterfly_pass)(re, im, &self.tw_re, &self.tw_im, len, inverse);
            len <<= 1;
        }

        if inverse {
            let scale = 1.0 / n as f32;
            for x in re.iter_mut() {
                *x *= scale;
            }
            for x in im.iter_mut() {
                *x *= scale;
            }
        }
        if let Some(t0) = prof_t0 {
            crate::obs::prof::FFT.record(t0.elapsed().as_nanos() as u64);
        }
    }
}

// ---------------------------------------------------------------------------
// real-input FFT (pack-two-reals trick)
// ---------------------------------------------------------------------------

/// Real-input FFT plan of size `n` (power of two ≥ 2) built on one complex
/// FFT of size `n/2`.
///
/// Forward packs `z[j] = x[2j] + i·x[2j+1]`, transforms at half size, and
/// untangles the conjugate-symmetric half spectrum `X[0..=n/2]` with
/// `X[k] = Ze[k] + w^k·Zo[k]`, `w = exp(-2πi/n)`. Inverse entangles the half
/// spectrum back into `Z` and unpacks the half-size inverse transform; the
/// half plan's `1/(n/2)` scale is exactly the rfft normalization (validated
/// against `numpy.fft.rfft/irfft` in a 1:1 Python mirror).
pub struct RealFft {
    n: usize,
    half: Fft,
    /// Untangle twiddles `w_k = exp(-2πik/n)` for `k ≤ n/2`.
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl RealFft {
    /// Build a plan for real transform size `n` (power of two ≥ 2).
    pub fn new(n: usize) -> RealFft {
        assert!(n.is_power_of_two() && n >= 2, "rfft size {n} must be a power of two ≥ 2");
        let m = n / 2;
        let mut tw_re = Vec::with_capacity(m + 1);
        let mut tw_im = Vec::with_capacity(m + 1);
        for k in 0..=m {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        RealFft { n, half: Fft::new(m), tw_re, tw_im }
    }

    /// Real transform size.
    pub fn size(&self) -> usize {
        self.n
    }

    /// Number of half-spectrum bins: `n/2 + 1`.
    pub fn spec_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// Half spectrum of the real signal `x` zero-padded to the plan size.
    ///
    /// `sre`/`sim` are scratch of length `n/2`; `out_re`/`out_im` receive
    /// the `n/2 + 1` spectrum bins. `x.len()` may be anything ≤ `n`.
    pub fn forward(
        &self,
        x: &[f32],
        sre: &mut [f32],
        sim: &mut [f32],
        out_re: &mut [f32],
        out_im: &mut [f32],
    ) {
        let (n, m) = (self.n, self.n / 2);
        assert!(x.len() <= n, "signal length {} > rfft size {n}", x.len());
        assert_eq!(sre.len(), m, "rfft scratch length != n/2");
        assert_eq!(sim.len(), m, "rfft scratch length != n/2");
        assert_eq!(out_re.len(), m + 1, "rfft spectrum length != n/2+1");
        assert_eq!(out_im.len(), m + 1, "rfft spectrum length != n/2+1");

        // Pack z[j] = x[2j] + i·x[2j+1], zero beyond the signal.
        let l = x.len();
        for j in 0..m {
            let e = 2 * j;
            sre[j] = if e < l { x[e] } else { 0.0 };
            sim[j] = if e + 1 < l { x[e + 1] } else { 0.0 };
        }
        self.half.forward(sre, sim);

        // Untangle: X[k] = Ze[k] + w^k·Zo[k], k = 0..=m, with Z[m] ≡ Z[0].
        for k in 0..=m {
            let zk = k % m;
            let zc = (m - k) % m;
            let (zr, zi) = (sre[zk], sim[zk]);
            let (cr, ci) = (sre[zc], -sim[zc]); // conj(Z[m−k])
            let (er, ei) = (0.5 * (zr + cr), 0.5 * (zi + ci)); // Ze[k]
            let (dr, di) = (0.5 * (zr - cr), 0.5 * (zi - ci));
            let (or_, oi) = (di, -dr); // Zo[k] = −i·(Z[k]−conj(Z[m−k]))/2
            let (wr, wi) = (self.tw_re[k], self.tw_im[k]);
            out_re[k] = er + or_ * wr - oi * wi;
            out_im[k] = ei + or_ * wi + oi * wr;
        }
    }

    /// Real inverse of a half spectrum, writing `out.len()` ≤ `n` leading
    /// samples (circular-result truncation). Includes the 1/n scale.
    pub fn inverse(
        &self,
        spec_re: &[f32],
        spec_im: &[f32],
        sre: &mut [f32],
        sim: &mut [f32],
        out: &mut [f32],
    ) {
        let (n, m) = (self.n, self.n / 2);
        assert_eq!(spec_re.len(), m + 1, "rfft spectrum length != n/2+1");
        assert_eq!(spec_im.len(), m + 1, "rfft spectrum length != n/2+1");
        assert_eq!(sre.len(), m, "rfft scratch length != n/2");
        assert_eq!(sim.len(), m, "rfft scratch length != n/2");
        assert!(out.len() <= n, "output length {} > rfft size {n}", out.len());

        // Entangle: Z[k] = Ze[k] + i·Zo[k] with Ze[k] = (X[k]+conj(X[m−k]))/2
        // and Zo[k] = w^{−k}·(X[k]−conj(X[m−k]))/2.
        for k in 0..m {
            let (xr, xi) = (spec_re[k], spec_im[k]);
            let (cr, ci) = (spec_re[m - k], -spec_im[m - k]);
            let (er, ei) = (0.5 * (xr + cr), 0.5 * (xi + ci));
            let (dr, di) = (0.5 * (xr - cr), 0.5 * (xi - ci));
            let (wr, wi) = (self.tw_re[k], -self.tw_im[k]); // w^{−k} = conj(w^k)
            let (or_, oi) = (dr * wr - di * wi, dr * wi + di * wr);
            sre[k] = er - oi;
            sim[k] = ei + or_;
        }
        self.half.inverse(sre, sim);

        // Unpack x[2j] = Re z[j], x[2j+1] = Im z[j]. The entangle step
        // reconstructs Z = FFT_{n/2}(packed x) exactly, so the half plan's
        // 1/(n/2) scale is the whole normalization — no extra factor.
        for t in 0..out.len() {
            out[t] = if t % 2 == 0 { sre[t / 2] } else { sim[t / 2] };
        }
    }
}

// ---------------------------------------------------------------------------
// spectra + workspaces
// ---------------------------------------------------------------------------

/// Half spectrum of a real signal: `n/2 + 1` bins of the rfft of the
/// zero-padded input (conjugate symmetry makes the upper half redundant).
#[derive(Clone)]
pub struct Spectrum {
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl Spectrum {
    /// Number of bins.
    pub fn len(&self) -> usize {
        self.re.len()
    }
    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }
}

/// Reusable scratch for one [`CausalConv`] size: packed-transform re/im
/// buffers plus a pool of [`Spectrum`]s. Own one per worker thread — every
/// `*_into` entry point borrows one mutably, so the per-row hot loops of the
/// model allocate nothing after warm-up.
pub struct ConvWorkspace {
    n: usize,
    sre: Vec<f32>,
    sim: Vec<f32>,
    pool: Vec<Spectrum>,
}

impl ConvWorkspace {
    /// Workspace sized for `plan` (usable with any plan of the same size).
    pub fn new(plan: &CausalConv) -> ConvWorkspace {
        Self::with_fft_size(plan.fft_size())
    }

    /// Workspace for a raw FFT size (shared by [`CausalConv`] and
    /// [`ChunkedCausalConv`] plans of the same transform size).
    pub fn with_fft_size(n: usize) -> ConvWorkspace {
        ConvWorkspace { n, sre: vec![0.0; n / 2], sim: vec![0.0; n / 2], pool: Vec::new() }
    }

    /// FFT size the workspace serves.
    pub fn fft_size(&self) -> usize {
        self.n
    }

    /// Pop a spectrum buffer (or allocate one on first use).
    pub fn take_spectrum(&mut self) -> Spectrum {
        self.pool.pop().unwrap_or_else(|| Spectrum {
            re: vec![0.0; self.n / 2 + 1],
            im: vec![0.0; self.n / 2 + 1],
        })
    }

    /// Return a spectrum buffer to the pool for reuse.
    pub fn put_spectrum(&mut self, s: Spectrum) {
        debug_assert_eq!(s.re.len(), self.n / 2 + 1);
        self.pool.push(s);
    }
}

// ---------------------------------------------------------------------------
// causal convolution plans
// ---------------------------------------------------------------------------

/// Causal-convolution plan for signals of length `l` (real-FFT engine).
pub struct CausalConv {
    l: usize,
    rfft: RealFft,
}

impl CausalConv {
    pub fn new(l: usize) -> CausalConv {
        assert!(l >= 1);
        let n = (2 * l).next_power_of_two();
        CausalConv { l, rfft: RealFft::new(n) }
    }

    /// Signal length the plan convolves.
    pub fn len(&self) -> usize {
        self.l
    }
    pub fn is_empty(&self) -> bool {
        self.l == 0
    }

    /// FFT size the plan transforms at (≥ 2·len, power of two).
    pub fn fft_size(&self) -> usize {
        self.rfft.size()
    }

    /// Half-spectrum bins per signal: `fft_size()/2 + 1`.
    pub fn spec_len(&self) -> usize {
        self.rfft.spec_len()
    }

    /// Allocate a workspace sized for this plan.
    pub fn workspace(&self) -> ConvWorkspace {
        ConvWorkspace::new(self)
    }

    /// Half spectrum of a real length-`l` signal into `out` (zero-alloc).
    pub fn spectrum_into(&self, x: &[f32], ws: &mut ConvWorkspace, out: &mut Spectrum) {
        self.spectrum_slices_into(x, ws, &mut out.re, &mut out.im);
    }

    /// Slice-based [`CausalConv::spectrum_into`] for spectra kept in flat
    /// banks (e.g. the model's per-block filter-spectrum cache).
    pub fn spectrum_slices_into(
        &self,
        x: &[f32],
        ws: &mut ConvWorkspace,
        out_re: &mut [f32],
        out_im: &mut [f32],
    ) {
        assert_eq!(x.len(), self.l);
        assert_eq!(ws.n, self.fft_size(), "workspace size != plan size");
        self.rfft.forward(x, &mut ws.sre, &mut ws.sim, out_re, out_im);
    }

    /// `irfft(A · B)[..l]` into `out` — causal convolution, zero-alloc.
    pub fn conv_spec_into(
        &self,
        a: &Spectrum,
        b: &Spectrum,
        ws: &mut ConvWorkspace,
        out: &mut [f32],
    ) {
        self.conv_spec_slices_into(&a.re, &a.im, &b.re, &b.im, ws, out);
    }

    /// Slice-based [`CausalConv::conv_spec_into`].
    pub fn conv_spec_slices_into(
        &self,
        a_re: &[f32],
        a_im: &[f32],
        b_re: &[f32],
        b_im: &[f32],
        ws: &mut ConvWorkspace,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), self.l);
        assert_eq!(ws.n, self.fft_size(), "workspace size != plan size");
        let bins = self.spec_len();
        let mut p = ws.take_spectrum();
        (kernels::active().spec_mul)(
            &a_re[..bins],
            &a_im[..bins],
            &b_re[..bins],
            &b_im[..bins],
            &mut p.re[..bins],
            &mut p.im[..bins],
        );
        self.rfft.inverse(&p.re, &p.im, &mut ws.sre, &mut ws.sim, out);
        ws.put_spectrum(p);
    }

    /// `irfft(conj(A) · B)[..l]` into `out` — causal correlation, zero-alloc.
    ///
    /// This is the adjoint of [`CausalConv::conv_spec_into`] in either
    /// argument: with `y = conv(h, v)` and upstream `dy`, `dv = corr(h, dy)`
    /// and `dh = corr(v, dy)`.
    pub fn corr_spec_into(
        &self,
        a: &Spectrum,
        b: &Spectrum,
        ws: &mut ConvWorkspace,
        out: &mut [f32],
    ) {
        self.corr_spec_slices_into(&a.re, &a.im, &b.re, &b.im, ws, out);
    }

    /// Slice-based [`CausalConv::corr_spec_into`].
    pub fn corr_spec_slices_into(
        &self,
        a_re: &[f32],
        a_im: &[f32],
        b_re: &[f32],
        b_im: &[f32],
        ws: &mut ConvWorkspace,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), self.l);
        assert_eq!(ws.n, self.fft_size(), "workspace size != plan size");
        let bins = self.spec_len();
        let mut p = ws.take_spectrum();
        (kernels::active().spec_mul_conj)(
            &a_re[..bins],
            &a_im[..bins],
            &b_re[..bins],
            &b_im[..bins],
            &mut p.re[..bins],
            &mut p.im[..bins],
        );
        self.rfft.inverse(&p.re, &p.im, &mut ws.sre, &mut ws.sim, out);
        ws.put_spectrum(p);
    }

    // -- allocating conveniences (tests, cold paths) -------------------------

    /// Spectrum of a real length-`l` signal (allocating convenience).
    pub fn spectrum(&self, x: &[f32]) -> Spectrum {
        let mut ws = self.workspace();
        let mut s = ws.take_spectrum();
        self.spectrum_into(x, &mut ws, &mut s);
        s
    }

    /// Causal convolution from two spectra (allocating convenience).
    pub fn conv_spec(&self, a: &Spectrum, b: &Spectrum) -> Vec<f32> {
        let mut ws = self.workspace();
        let mut out = vec![0.0f32; self.l];
        self.conv_spec_into(a, b, &mut ws, &mut out);
        out
    }

    /// Causal correlation from two spectra (allocating convenience).
    pub fn corr_spec(&self, a: &Spectrum, b: &Spectrum) -> Vec<f32> {
        let mut ws = self.workspace();
        let mut out = vec![0.0f32; self.l];
        self.corr_spec_into(a, b, &mut ws, &mut out);
        out
    }

    /// Causal convolution `y[t] = Σ_{s≤t} h[t−s]·v[s]` in O(L log L).
    pub fn conv(&self, h: &[f32], v: &[f32]) -> Vec<f32> {
        self.conv_spec(&self.spectrum(h), &self.spectrum(v))
    }

    /// Causal correlation `y[s] = Σ_{t≥s} a[t−s]·g[t]` in O(L log L).
    pub fn corr(&self, a: &[f32], g: &[f32]) -> Vec<f32> {
        self.corr_spec(&self.spectrum(a), &self.spectrum(g))
    }
}

// ---------------------------------------------------------------------------
// chunked (overlap-save) causal convolution
// ---------------------------------------------------------------------------

/// Overlap-save causal convolution: stream an arbitrary-length signal
/// through fixed-size FFT chunks (DESIGN.md §Long-context).
///
/// For a filter of support `F` (zero beyond `F−1` taps back) the causal conv
/// at position `p` only reads `v[p−F+1 ..= p]`. Overlap-save exploits this:
/// each block transforms `[carry (last W = F−1 input samples) ++ chunk]`,
/// multiplies by the filter spectrum, inverse-transforms, and keeps only the
/// `chunk` outputs past the carry — those are *exactly* the linear-conv
/// outputs, because every one of them has its full `F`-tap history inside
/// the block. Work is O(chunk·log chunk) per chunk and the working set is
/// O(chunk), independent of the total stream length; the result is the same
/// linear convolution the monolithic FFT computes (same math, different
/// rounding — and *bitwise* identical on the first chunk, where the empty
/// carry makes the block transform literally the monolithic transform).
///
/// Wraparound safety: outputs are read at block positions `p ≥ w` (the
/// carry length actually present, `w ≤ W`). Circular contamination from the
/// linear support `w + cl + F − 1 > n` only lands at positions
/// `p ≤ w + cl + F − 2 − n`, and the plan guarantees
/// `n ≥ chunk + F − 1 ≥ cl + F − 1`, so every contaminated position sits
/// strictly below `w` — never read.
///
/// The invariant `chunk ≥ filter` keeps the carry no longer than one chunk
/// (`try_new` rejects `chunk < filter`); the degenerate `chunk == filter`
/// case is legal and tested.
pub struct ChunkedCausalConv {
    chunk: usize,
    filter: usize,
    rfft: RealFft,
}

impl ChunkedCausalConv {
    /// Plan for `chunk`-sample blocks under a filter of support `filter`.
    /// Returns `None` when `filter == 0` or `chunk < filter` (the carry
    /// would outgrow the block and overlap-save no longer applies).
    pub fn try_new(chunk: usize, filter: usize) -> Option<ChunkedCausalConv> {
        if filter == 0 || chunk < filter {
            return None;
        }
        let n = (chunk + filter - 1).next_power_of_two().max(2);
        Some(ChunkedCausalConv { chunk, filter, rfft: RealFft::new(n) })
    }

    /// Panicking [`ChunkedCausalConv::try_new`].
    pub fn new(chunk: usize, filter: usize) -> ChunkedCausalConv {
        Self::try_new(chunk, filter)
            .unwrap_or_else(|| panic!("invalid overlap-save plan: chunk {chunk} < filter {filter}"))
    }

    /// Plan at an explicit FFT size `n` (power of two ≥ chunk + filter − 1).
    ///
    /// The model passes its full bucket's `fft_size()` here: with
    /// `chunk == filter == L` that is `next_pow2(2L)` — the *same* transform
    /// the monolithic path runs, so cached filter spectra, workspaces and
    /// first-chunk bitwise equality all carry over.
    pub fn with_fft_size(chunk: usize, filter: usize, n: usize) -> ChunkedCausalConv {
        assert!(filter >= 1 && chunk >= filter, "chunk {chunk} < filter {filter}");
        assert!(
            n.is_power_of_two() && n >= (chunk + filter - 1).max(2),
            "fft size {n} cannot hold chunk {chunk} + filter {filter} - 1"
        );
        ChunkedCausalConv { chunk, filter, rfft: RealFft::new(n) }
    }

    /// Block length streamed per transform.
    pub fn chunk_len(&self) -> usize {
        self.chunk
    }

    /// Filter support (taps beyond this are treated as zero).
    pub fn filter_len(&self) -> usize {
        self.filter
    }

    /// Overlap carried between blocks: `filter − 1` input samples.
    pub fn carry_len(&self) -> usize {
        self.filter - 1
    }

    /// FFT size the plan transforms at.
    pub fn fft_size(&self) -> usize {
        self.rfft.size()
    }

    /// Half-spectrum bins per signal: `fft_size()/2 + 1`.
    pub fn spec_len(&self) -> usize {
        self.rfft.spec_len()
    }

    /// Allocate a workspace sized for this plan (interchangeable with any
    /// [`CausalConv`] workspace of the same FFT size).
    pub fn workspace(&self) -> ConvWorkspace {
        ConvWorkspace::with_fft_size(self.fft_size())
    }

    /// Half spectrum of the filter (computed once per stream; `h.len()` may
    /// be anything ≤ `filter`, shorter filters are zero-extended).
    pub fn filter_spectrum_slices_into(
        &self,
        h: &[f32],
        ws: &mut ConvWorkspace,
        out_re: &mut [f32],
        out_im: &mut [f32],
    ) {
        assert!(h.len() <= self.filter, "filter longer than the plan's support");
        assert_eq!(ws.n, self.fft_size(), "workspace size != plan size");
        self.rfft.forward(h, &mut ws.sre, &mut ws.sim, out_re, out_im);
    }

    /// One overlap-save block: convolve `chunk_in` (`1 ..= chunk` samples —
    /// the final block of a stream may be ragged) against the cached filter
    /// spectrum `(h_re, h_im)`, given `carry` = the input samples
    /// immediately preceding this block (all history so far, capped at
    /// `carry_len()`; empty on the first block). Writes the `chunk_in.len()`
    /// linear-convolution outputs for this block's positions into `out`.
    ///
    /// `buf` is caller scratch of length ≥ `carry.len() + chunk_in.len()`
    /// (at most `fft_size()`); it holds the block input and then its inverse
    /// transform, so a worker can reuse one buffer across every block.
    pub fn process_chunk_slices_into(
        &self,
        h_re: &[f32],
        h_im: &[f32],
        carry: &[f32],
        chunk_in: &[f32],
        ws: &mut ConvWorkspace,
        buf: &mut [f32],
        out: &mut [f32],
    ) {
        let (w, cl) = (carry.len(), chunk_in.len());
        assert!(w < self.filter, "carry {w} ≥ filter support {}", self.filter);
        assert!(cl >= 1 && cl <= self.chunk, "chunk input {cl} outside 1..={}", self.chunk);
        assert_eq!(out.len(), cl, "output length != chunk input length");
        assert!(buf.len() >= w + cl, "scratch buffer shorter than carry + chunk");
        assert_eq!(ws.n, self.fft_size(), "workspace size != plan size");

        buf[..w].copy_from_slice(carry);
        buf[w..w + cl].copy_from_slice(chunk_in);
        let bins = self.spec_len();
        let mut x = ws.take_spectrum();
        self.rfft.forward(&buf[..w + cl], &mut ws.sre, &mut ws.sim, &mut x.re, &mut x.im);
        let mut p = ws.take_spectrum();
        (kernels::active().spec_mul)(
            &h_re[..bins],
            &h_im[..bins],
            &x.re[..bins],
            &x.im[..bins],
            &mut p.re[..bins],
            &mut p.im[..bins],
        );
        self.rfft.inverse(&p.re, &p.im, &mut ws.sre, &mut ws.sim, &mut buf[..w + cl]);
        out.copy_from_slice(&buf[w..w + cl]);
        ws.put_spectrum(x);
        ws.put_spectrum(p);
    }

    /// Roll `chunk_in` into `carry` so the next block sees the last
    /// `carry_len()` input samples (fewer while the stream is still shorter
    /// than the carry).
    pub fn update_carry(&self, carry: &mut Vec<f32>, chunk_in: &[f32]) {
        let w = self.filter - 1;
        if w == 0 {
            carry.clear();
            return;
        }
        let cl = chunk_in.len();
        if cl >= w {
            carry.clear();
            carry.extend_from_slice(&chunk_in[cl - w..]);
        } else {
            let keep = (carry.len() + cl).min(w) - cl;
            let drop = carry.len() - keep;
            carry.drain(..drop);
            carry.extend_from_slice(chunk_in);
        }
    }

    /// Stream a whole signal through the plan (allocating convenience — the
    /// reference driver the tests, benches and numpy mirror all share).
    /// `h.len()` ≤ `filter`; returns the `v.len()` causal-conv outputs.
    pub fn conv_streaming(&self, h: &[f32], v: &[f32]) -> Vec<f32> {
        assert!(h.len() <= self.filter, "filter longer than the plan's support");
        let mut ws = self.workspace();
        let mut hs = ws.take_spectrum();
        self.filter_spectrum_slices_into(h, &mut ws, &mut hs.re, &mut hs.im);
        let mut buf = vec![0.0f32; self.fft_size()];
        let mut carry: Vec<f32> = Vec::new();
        let mut y = vec![0.0f32; v.len()];
        let mut g0 = 0usize;
        while g0 < v.len() {
            let cl = self.chunk.min(v.len() - g0);
            let chunk_in = &v[g0..g0 + cl];
            self.process_chunk_slices_into(
                &hs.re,
                &hs.im,
                &carry,
                chunk_in,
                &mut ws,
                &mut buf,
                &mut y[g0..g0 + cl],
            );
            self.update_carry(&mut carry, chunk_in);
            g0 += cl;
        }
        ws.put_spectrum(hs);
        y
    }
}

// ---------------------------------------------------------------------------
// shape-bucketed plan bank
// ---------------------------------------------------------------------------

/// Minimum bucket length the bank will build a plan for. Below this the FFT
/// setup cost dwarfs the transform itself and bucketing stops paying.
pub const MIN_BUCKET_LEN: usize = 8;

/// A ladder of [`CausalConv`] plans at halving sequence lengths — the
/// serving-side answer to "every request pads to the full compiled L".
///
/// The largest plan is always exactly the model length; below it the bank
/// holds `levels − 1` plans at `L/2, L/4, ...` (stopping at
/// [`MIN_BUCKET_LEN`]). A request of length `l` routes to the *smallest*
/// plan that fits, so a short prompt transforms at a fraction of the full
/// FFT size instead of paying `O(L log L)` for padding it never reads.
/// Plans are immutable after construction and shared by reference.
pub struct PlanBank {
    /// Plans sorted ascending by signal length; the last is the full length.
    plans: Vec<CausalConv>,
    /// Extended-context plans at doubling lengths above the full bucket
    /// (`2L, 4L, …` until the configured max context is covered). These are
    /// the *monolithic* long-context plans — the reference/validation path
    /// for prompts beyond the compiled window, while the chunked
    /// overlap-save engine does the streaming work (DESIGN.md
    /// §Long-context). Empty unless built [`PlanBank::with_context`].
    ext: Vec<CausalConv>,
}

impl PlanBank {
    /// Build a bank for model length `full` with up to `levels` buckets
    /// (`levels == 1` reproduces the unbucketed single-plan behaviour).
    pub fn new(full: usize, levels: usize) -> PlanBank {
        assert!(full >= 1, "plan bank needs a nonzero length");
        let mut lens = vec![full];
        let mut l = full;
        for _ in 1..levels.max(1) {
            l /= 2;
            if l < MIN_BUCKET_LEN {
                break;
            }
            lens.push(l);
        }
        lens.sort_unstable();
        lens.dedup();
        PlanBank { plans: lens.into_iter().map(CausalConv::new).collect(), ext: Vec::new() }
    }

    /// [`PlanBank::new`] plus an extended ladder of monolithic plans at
    /// doubling lengths `2·full, 4·full, …` until `max_context` is covered
    /// (`max_context ≤ full` leaves the ladder empty).
    pub fn with_context(full: usize, levels: usize, max_context: usize) -> PlanBank {
        let mut bank = Self::new(full, levels);
        let mut l = full;
        while l < max_context {
            l *= 2;
            bank.ext.push(CausalConv::new(l));
        }
        bank
    }

    /// Bucket signal lengths, ascending (the last is the full length).
    pub fn lens(&self) -> Vec<usize> {
        self.plans.iter().map(|p| p.len()).collect()
    }

    /// Number of buckets.
    pub fn levels(&self) -> usize {
        self.plans.len()
    }

    /// Index of the smallest bucket whose plan covers length `l`
    /// (`None` when `l` exceeds the full length).
    pub fn bucket_index(&self, l: usize) -> Option<usize> {
        self.plans.iter().position(|p| p.len() >= l)
    }

    /// The plan at bucket `i` (ascending by length).
    pub fn plan(&self, i: usize) -> &CausalConv {
        &self.plans[i]
    }

    /// The full-length plan (the training path's single plan).
    pub fn full(&self) -> &CausalConv {
        self.plans.last().expect("plan bank is never empty")
    }

    /// Extended-ladder signal lengths, ascending (empty without
    /// [`PlanBank::with_context`]).
    pub fn ext_lens(&self) -> Vec<usize> {
        self.ext.iter().map(|p| p.len()).collect()
    }

    /// Longest length any plan in the bank covers (the admission bound for
    /// extended-context prefill).
    pub fn max_len(&self) -> usize {
        self.ext.last().map_or_else(|| self.full().len(), |p| p.len())
    }

    /// Smallest plan — full bucket or extended ladder — covering length `l`
    /// (`None` above [`PlanBank::max_len`]).
    pub fn ext_plan(&self, l: usize) -> Option<&CausalConv> {
        if l <= self.full().len() {
            return Some(self.full());
        }
        self.ext.iter().find(|p| p.len() >= l)
    }
}

/// The PR-1 engine: causal convolution via *full complex* FFTs. Kept as the
/// baseline the real-FFT path is benchmarked and property-tested against.
pub struct ComplexCausalConv {
    l: usize,
    fft: Fft,
}

impl ComplexCausalConv {
    pub fn new(l: usize) -> ComplexCausalConv {
        assert!(l >= 1);
        let n = (2 * l).next_power_of_two();
        ComplexCausalConv { l, fft: Fft::new(n) }
    }

    fn full_spectrum(&self, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let n = self.fft.size();
        let mut re = vec![0.0f32; n];
        re[..x.len()].copy_from_slice(x);
        let mut im = vec![0.0f32; n];
        self.fft.forward(&mut re, &mut im);
        (re, im)
    }

    /// Causal convolution via full complex spectra (the PR-1 hot path).
    pub fn conv(&self, h: &[f32], v: &[f32]) -> Vec<f32> {
        assert_eq!(h.len(), self.l);
        assert_eq!(v.len(), self.l);
        let n = self.fft.size();
        let (ar, ai) = self.full_spectrum(h);
        let (br, bi) = self.full_spectrum(v);
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        for k in 0..n {
            re[k] = ar[k] * br[k] - ai[k] * bi[k];
            im[k] = ar[k] * bi[k] + ai[k] * br[k];
        }
        self.fft.inverse(&mut re, &mut im);
        re.truncate(self.l);
        re
    }

    /// Causal correlation via full complex spectra.
    pub fn corr(&self, a: &[f32], g: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), self.l);
        assert_eq!(g.len(), self.l);
        let n = self.fft.size();
        let (ar, ai) = self.full_spectrum(a);
        let (br, bi) = self.full_spectrum(g);
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        for k in 0..n {
            re[k] = ar[k] * br[k] + ai[k] * bi[k];
            im[k] = ar[k] * bi[k] - ai[k] * br[k];
        }
        self.fft.inverse(&mut re, &mut im);
        re.truncate(self.l);
        re
    }
}

// ---------------------------------------------------------------------------
// streaming decode kernel
// ---------------------------------------------------------------------------

/// One streaming causal-convolution output — the decode-path replacement
/// for the FFT (DESIGN.md §Decode).
///
/// For a filter `h` and signal `v`, the causal conv at position `t` is
/// `y[t] = Σ_{s≤t} h[t−s]·v[s]`. During decode the signal history
/// `v[0..=t]` is appended one position per token, so the new output is a
/// single O(t) dot product instead of an O(L log L) transform.
///
/// History layout: `hist` is the signal history `v[0..=t]` in forward time
/// order (an append-only prefix of a length-`L` row). `hrev` is the filter
/// **reversed** (`hrev[k] = h[L−1−k]`, length `L ≥ hist.len()`): reversing
/// the filter once at cache-build time turns the convolution's backward
/// walk into a forward dot of two contiguous slices.
///
/// The dot runs through the dispatched kernel table (DESIGN.md §Kernels):
/// the scalar kernel is the original serial f32 accumulation verbatim; the
/// SIMD kernel accumulates paired 8-lane partials and reduces them in f64,
/// which agrees to f32 round-off and stays inside the f64-accumulation
/// audit bounds. Either way the accumulation order is fixed per call, so
/// results are bitwise identical for any thread count.
#[inline]
pub fn causal_dot_step(hrev: &[f32], hist: &[f32]) -> f32 {
    let n = hist.len();
    assert!(n >= 1 && hrev.len() >= n, "filter shorter than history");
    let tail = &hrev[hrev.len() - n..];
    (kernels::active().dot)(tail, hist)
}

/// Reference O(L²) causal convolution (tests + the bench baseline).
pub fn causal_conv_direct(h: &[f32], v: &[f32]) -> Vec<f32> {
    let l = v.len();
    assert_eq!(h.len(), l);
    let mut y = vec![0.0f32; l];
    for t in 0..l {
        let mut acc = 0.0f32;
        for s in 0..=t {
            acc += h[t - s] * v[s];
        }
        y[t] = acc;
    }
    y
}

/// Reference O(L²) causal correlation (tests).
pub fn causal_corr_direct(a: &[f32], g: &[f32]) -> Vec<f32> {
    let l = g.len();
    assert_eq!(a.len(), l);
    let mut y = vec![0.0f32; l];
    for s in 0..l {
        let mut acc = 0.0f32;
        for t in s..l {
            acc += a[t - s] * g[t];
        }
        y[s] = acc;
    }
    y
}

/// Random signal helper shared by the property tests and the bench.
pub fn random_signal(rng: &mut Pcg, l: usize) -> Vec<f32> {
    (0..l).map(|_| rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::kernels;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn forward_matches_naive_dft() {
        let mut rng = Pcg::new(11);
        for n in [1usize, 2, 4, 8, 32] {
            let re_in = random_signal(&mut rng, n);
            let im_in = random_signal(&mut rng, n);
            let (mut re, mut im) = (re_in.clone(), im_in.clone());
            Fft::new(n).forward(&mut re, &mut im);
            for k in 0..n {
                let (mut wr, mut wi) = (0.0f64, 0.0f64);
                for t in 0..n {
                    let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                    let (c, s) = (ang.cos(), ang.sin());
                    wr += re_in[t] as f64 * c - im_in[t] as f64 * s;
                    wi += re_in[t] as f64 * s + im_in[t] as f64 * c;
                }
                assert!(close(re[k], wr as f32, 1e-4), "n={n} k={k}: {} vs {wr}", re[k]);
                assert!(close(im[k], wi as f32, 1e-4), "n={n} k={k}: {} vs {wi}", im[k]);
            }
        }
    }

    #[test]
    fn roundtrip_is_identity() {
        Prop::new("fft roundtrip").cases(64).check(|rng| {
            let n = 1usize << (1 + rng.usize_below(9)); // 2..=512
            let fft = Fft::new(n);
            let re0 = random_signal(rng, n);
            let im0 = random_signal(rng, n);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            fft.forward(&mut re, &mut im);
            fft.inverse(&mut re, &mut im);
            for t in 0..n {
                prop_assert!(close(re[t], re0[t], 1e-4), "re[{t}]: {} vs {}", re[t], re0[t]);
                prop_assert!(close(im[t], im0[t], 1e-4), "im[{t}]: {} vs {}", im[t], im0[t]);
            }
            Ok(())
        });
    }

    #[test]
    fn rfft_matches_full_complex_fft() {
        // The real-input path must reproduce the lower half of the full
        // complex spectrum bin-for-bin (conjugate symmetry covers the rest).
        Prop::new("rfft == complex fft half").cases(64).check(|rng| {
            let n = 1usize << (1 + rng.usize_below(9)); // 2..=512
            let x = random_signal(rng, n);
            let (mut fre, mut fim) = (x.clone(), vec![0.0f32; n]);
            Fft::new(n).forward(&mut fre, &mut fim);

            let plan = RealFft::new(n);
            let m = n / 2;
            let (mut sre, mut sim) = (vec![0.0f32; m], vec![0.0f32; m]);
            let (mut hre, mut him) = (vec![0.0f32; m + 1], vec![0.0f32; m + 1]);
            plan.forward(&x, &mut sre, &mut sim, &mut hre, &mut him);
            for k in 0..=m {
                prop_assert!(close(hre[k], fre[k], 1e-4), "re[{k}]: {} vs {}", hre[k], fre[k]);
                prop_assert!(close(him[k], fim[k], 1e-4), "im[{k}]: {} vs {}", him[k], fim[k]);
            }
            Ok(())
        });
    }

    #[test]
    fn rfft_roundtrip_is_identity() {
        Prop::new("rfft roundtrip").cases(64).check(|rng| {
            let n = 1usize << (1 + rng.usize_below(9)); // 2..=512
            let x = random_signal(rng, n);
            let plan = RealFft::new(n);
            let m = n / 2;
            let (mut sre, mut sim) = (vec![0.0f32; m], vec![0.0f32; m]);
            let (mut hre, mut him) = (vec![0.0f32; m + 1], vec![0.0f32; m + 1]);
            plan.forward(&x, &mut sre, &mut sim, &mut hre, &mut him);
            let mut back = vec![0.0f32; n];
            plan.inverse(&hre, &him, &mut sre, &mut sim, &mut back);
            for t in 0..n {
                prop_assert!(close(back[t], x[t], 1e-4), "x[{t}]: {} vs {}", back[t], x[t]);
            }
            Ok(())
        });
    }

    #[test]
    fn fft_conv_matches_direct() {
        Prop::new("fft conv == direct conv").cases(64).check(|rng| {
            let l = 1 + rng.usize_below(96);
            let plan = CausalConv::new(l);
            let h = random_signal(rng, l);
            let v = random_signal(rng, l);
            let fast = plan.conv(&h, &v);
            let slow = causal_conv_direct(&h, &v);
            for t in 0..l {
                prop_assert!(close(fast[t], slow[t], 2e-3), "t={t}: {} vs {}", fast[t], slow[t]);
            }
            Ok(())
        });
    }

    #[test]
    fn fft_corr_matches_direct() {
        Prop::new("fft corr == direct corr").cases(64).check(|rng| {
            let l = 1 + rng.usize_below(96);
            let plan = CausalConv::new(l);
            let a = random_signal(rng, l);
            let g = random_signal(rng, l);
            let fast = plan.corr(&a, &g);
            let slow = causal_corr_direct(&a, &g);
            for t in 0..l {
                prop_assert!(close(fast[t], slow[t], 2e-3), "t={t}: {} vs {}", fast[t], slow[t]);
            }
            Ok(())
        });
    }

    #[test]
    fn real_and_complex_engines_agree() {
        // The real-FFT workspace path must match the PR-1 full-complex path
        // within f32 round-off on both conv and corr.
        Prop::new("real-fft == complex-fft").cases(64).check(|rng| {
            let l = 1 + rng.usize_below(128);
            let plan = CausalConv::new(l);
            let reference = ComplexCausalConv::new(l);
            let h = random_signal(rng, l);
            let v = random_signal(rng, l);

            let mut ws = plan.workspace();
            let (mut sh, mut sv) = (ws.take_spectrum(), ws.take_spectrum());
            plan.spectrum_into(&h, &mut ws, &mut sh);
            plan.spectrum_into(&v, &mut ws, &mut sv);
            let mut conv = vec![0.0f32; l];
            plan.conv_spec_into(&sh, &sv, &mut ws, &mut conv);
            let mut corr = vec![0.0f32; l];
            plan.corr_spec_into(&sh, &sv, &mut ws, &mut corr);
            ws.put_spectrum(sh);
            ws.put_spectrum(sv);

            let conv_ref = reference.conv(&h, &v);
            let corr_ref = reference.corr(&h, &v);
            for t in 0..l {
                prop_assert!(
                    close(conv[t], conv_ref[t], 1e-3),
                    "conv t={t}: {} vs {}",
                    conv[t],
                    conv_ref[t]
                );
                prop_assert!(
                    close(corr[t], corr_ref[t], 1e-3),
                    "corr t={t}: {} vs {}",
                    corr[t],
                    corr_ref[t]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn workspace_reuse_is_stable() {
        // Repeated _into calls through one workspace must keep producing the
        // same answers (no stale state leaks between calls).
        let mut rng = Pcg::new(3);
        let l = 50;
        let plan = CausalConv::new(l);
        let mut ws = plan.workspace();
        let h = random_signal(&mut rng, l);
        let v = random_signal(&mut rng, l);
        let want = causal_conv_direct(&h, &v);
        let mut sh = ws.take_spectrum();
        let mut sv = ws.take_spectrum();
        let mut out = vec![0.0f32; l];
        for round in 0..4 {
            plan.spectrum_into(&h, &mut ws, &mut sh);
            plan.spectrum_into(&v, &mut ws, &mut sv);
            plan.conv_spec_into(&sh, &sv, &mut ws, &mut out);
            for t in 0..l {
                assert!(close(out[t], want[t], 2e-3), "round {round} t={t}");
            }
        }
        ws.put_spectrum(sh);
        ws.put_spectrum(sv);
        // Pool round-trips buffers instead of allocating.
        let s1 = ws.take_spectrum();
        let s2 = ws.take_spectrum();
        assert_eq!(s1.len(), plan.spec_len());
        assert_eq!(s2.len(), plan.spec_len());
        ws.put_spectrum(s1);
        ws.put_spectrum(s2);
    }

    #[test]
    fn conv_is_causal() {
        // Changing v[t0..] must not change y[..t0].
        Prop::new("conv causality").cases(32).check(|rng| {
            let l = 2 + rng.usize_below(62);
            let plan = CausalConv::new(l);
            let h = random_signal(rng, l);
            let v = random_signal(rng, l);
            let t0 = 1 + rng.usize_below(l - 1);
            let mut v2 = v.clone();
            for x in v2[t0..].iter_mut() {
                *x += 1.0 + rng.f32();
            }
            let y1 = plan.conv(&h, &v);
            let y2 = plan.conv(&h, &v2);
            for t in 0..t0 {
                prop_assert!(close(y1[t], y2[t], 1e-4), "future leaked into t={t} (t0={t0})");
            }
            Ok(())
        });
    }

    #[test]
    fn spectra_reuse_matches_one_shot() {
        let mut rng = Pcg::new(5);
        let l = 40;
        let plan = CausalConv::new(l);
        let h = random_signal(&mut rng, l);
        let v = random_signal(&mut rng, l);
        let hs = plan.spectrum(&h);
        let vs = plan.spectrum(&v);
        let a = plan.conv_spec(&hs, &vs);
        let b = plan.conv(&h, &v);
        for t in 0..l {
            assert!(close(a[t], b[t], 1e-5));
        }
    }

    #[test]
    fn causal_dot_step_matches_direct_conv_position_by_position() {
        // Streaming the history one position at a time through the reversed
        // filter must reproduce every output of the direct O(L²) conv. On
        // the scalar kernel table both sides accumulate h[t−s]·v[s] in
        // ascending s — identical arithmetic, so equality is exact; the
        // SIMD dot reduces lane partials in f64, so it agrees to round-off
        // (≤ 1e-5 rel, the kernel contract — DESIGN.md §Kernels).
        let scalar_active = kernels::active_name() == "scalar";
        Prop::new("causal dot step == direct conv").cases(64).check(move |rng| {
            let l = 1 + rng.usize_below(96);
            let h = random_signal(rng, l);
            let v = random_signal(rng, l);
            let hrev: Vec<f32> = h.iter().rev().copied().collect();
            let want = causal_conv_direct(&h, &v);
            for t in 0..l {
                let got = causal_dot_step(&hrev, &v[..=t]);
                if scalar_active {
                    prop_assert!(got == want[t], "t={t}: {got} vs {}", want[t]);
                } else {
                    prop_assert!(
                        close(got, want[t], 1e-5),
                        "t={t}: {got} vs {}",
                        want[t]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn causal_dot_step_under_scalar_table_is_bitwise_direct() {
        // Whatever table is active, the scalar kernel itself must remain
        // bitwise-equal to the direct conv's accumulation (the pre-PR
        // `causal_dot_step` body) — the reference the SIMD dot is judged
        // against.
        let mut rng = Pcg::new(41);
        let l = 80usize;
        let h = random_signal(&mut rng, l);
        let v = random_signal(&mut rng, l);
        let hrev: Vec<f32> = h.iter().rev().copied().collect();
        let want = causal_conv_direct(&h, &v);
        for t in 0..l {
            let tail = &hrev[hrev.len() - (t + 1)..];
            let got = (kernels::SCALAR.dot)(tail, &v[..=t]);
            assert!(got == want[t], "t={t}: {got} vs {}", want[t]);
        }
    }

    #[test]
    fn causal_dot_step_agrees_with_fft_conv() {
        // And against the FFT plan (different rounding → f32 round-off).
        let mut rng = Pcg::new(23);
        let l = 200usize;
        let plan = CausalConv::new(l);
        let h = random_signal(&mut rng, l);
        let v = random_signal(&mut rng, l);
        let hrev: Vec<f32> = h.iter().rev().copied().collect();
        let fft = plan.conv(&h, &v);
        for t in 0..l {
            let got = causal_dot_step(&hrev, &v[..=t]);
            assert!(close(got, fft[t], 2e-3), "t={t}: {got} vs {}", fft[t]);
        }
    }

    #[test]
    fn plan_size_is_padded_power_of_two() {
        assert_eq!(CausalConv::new(1).fft_size(), 2);
        assert_eq!(CausalConv::new(16).fft_size(), 32);
        assert_eq!(CausalConv::new(17).fft_size(), 64);
        assert_eq!(CausalConv::new(1024).fft_size(), 2048);
        assert_eq!(CausalConv::new(1024).spec_len(), 1025);
    }

    #[test]
    fn plan_bank_ladder_and_routing() {
        let bank = PlanBank::new(256, 4);
        assert_eq!(bank.lens(), vec![32, 64, 128, 256]);
        assert_eq!(bank.full().len(), 256);
        // Smallest bucket that fits.
        assert_eq!(bank.bucket_index(1), Some(0));
        assert_eq!(bank.bucket_index(32), Some(0));
        assert_eq!(bank.bucket_index(33), Some(1));
        assert_eq!(bank.bucket_index(200), Some(3));
        assert_eq!(bank.bucket_index(256), Some(3));
        assert_eq!(bank.bucket_index(257), None);
        // Ladder stops at the minimum bucket length.
        assert_eq!(PlanBank::new(16, 4).lens(), vec![8, 16]);
        // One level = the unbucketed single plan.
        assert_eq!(PlanBank::new(256, 1).lens(), vec![256]);
        // Non-power-of-two full lengths still get a valid ladder.
        assert_eq!(PlanBank::new(48, 3).lens(), vec![12, 24, 48]);
    }

    #[test]
    fn bucket_plans_agree_with_full_plan_within_tolerance() {
        // A short signal convolved through its bucket plan must match the
        // full-pad plan mathematically (causality); the FFT sizes differ so
        // agreement is within f32 round-off, not bitwise (DESIGN §Serving).
        let mut rng = Pcg::new(17);
        let bank = PlanBank::new(128, 4);
        let p = 20usize; // prompt support → routes to the 32-length bucket
        let h_full = random_signal(&mut rng, 128);
        let mut v_full = vec![0.0f32; 128];
        for x in v_full[..p].iter_mut() {
            *x = rng.normal();
        }
        let want = bank.full().conv(&h_full, &v_full);
        let bi = bank.bucket_index(p).unwrap();
        let plan = bank.plan(bi);
        let lb = plan.len();
        let got = plan.conv(&h_full[..lb], &v_full[..lb]);
        for t in 0..lb {
            assert!(
                close(got[t], want[t], 1e-3),
                "bucket {lb} t={t}: {} vs {}",
                got[t],
                want[t]
            );
        }
    }

    #[test]
    fn longctx_overlap_save_matches_direct_and_monolithic_sweep() {
        // Satellite: chunked-vs-monolithic agreement across a sweep of
        // (L, chunk, filter) including ragged final chunks and chunk ==
        // filter. The direct O(L²) conv anchors correctness; the monolithic
        // FFT plan anchors the ≤1e-4 rel chunked-vs-monolithic contract.
        Prop::new("overlap-save == direct/monolithic").cases(64).check(|rng| {
            let f = 1 + rng.usize_below(16);
            let chunk = f + rng.usize_below(24);
            let l = 1 + rng.usize_below(200);
            let plan = ChunkedCausalConv::new(chunk, f);
            prop_assert!(plan.carry_len() == f - 1, "carry != filter-1");
            let h = random_signal(rng, f);
            let v = random_signal(rng, l);
            let got = plan.conv_streaming(&h, &v);

            let mut h_full = vec![0.0f32; l];
            let support = f.min(l);
            h_full[..support].copy_from_slice(&h[..support]);
            let direct = causal_conv_direct(&h_full, &v);
            let mono = CausalConv::new(l).conv(&h_full, &v);
            for t in 0..l {
                prop_assert!(
                    close(got[t], direct[t], 2e-3),
                    "direct L={l} c={chunk} f={f} t={t}: {} vs {}",
                    got[t],
                    direct[t]
                );
                prop_assert!(
                    close(got[t], mono[t], 1e-4),
                    "monolithic L={l} c={chunk} f={f} t={t}: {} vs {}",
                    got[t],
                    mono[t]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn longctx_overlap_save_rejects_chunk_smaller_than_filter() {
        assert!(ChunkedCausalConv::try_new(4, 5).is_none());
        assert!(ChunkedCausalConv::try_new(0, 1).is_none());
        assert!(ChunkedCausalConv::try_new(4, 0).is_none());
        // chunk == filter is the legal edge, not a rejection.
        assert!(ChunkedCausalConv::try_new(4, 4).is_some());
        assert!(ChunkedCausalConv::try_new(1, 1).is_some());
    }

    #[test]
    fn longctx_overlap_save_edge_geometries() {
        let mut rng = Pcg::new(7);
        // chunk == filter: every block past the first carries a full
        // chunk-minus-one overlap.
        for (l, c) in [(37usize, 8usize), (8, 8), (5, 8), (64, 8), (9, 8)] {
            let plan = ChunkedCausalConv::new(c, c);
            let h = random_signal(&mut rng, c);
            let v = random_signal(&mut rng, l);
            let got = plan.conv_streaming(&h, &v);
            let mut h_full = vec![0.0f32; l];
            let support = c.min(l);
            h_full[..support].copy_from_slice(&h[..support]);
            let want = causal_conv_direct(&h_full, &v);
            for t in 0..l {
                assert!(
                    close(got[t], want[t], 2e-3),
                    "L={l} c=f={c} t={t}: {} vs {}",
                    got[t],
                    want[t]
                );
            }
        }
        // filter == 1: no carry at all, blocks are independent.
        let plan = ChunkedCausalConv::new(6, 1);
        assert_eq!(plan.carry_len(), 0);
        let h = [1.5f32];
        let v = random_signal(&mut rng, 20);
        let got = plan.conv_streaming(&h, &v);
        for t in 0..20 {
            assert!(close(got[t], 1.5 * v[t], 1e-5), "t={t}");
        }
    }

    #[test]
    fn longctx_single_chunk_is_bitwise_monolithic_at_matched_fft_size() {
        // The exactness contract's strong half: when the chunked plan runs
        // at the monolithic plan's FFT size and the whole signal fits one
        // chunk (empty carry), the transform sequence is *identical* —
        // outputs must match bit for bit, not just within tolerance.
        let mut rng = Pcg::new(29);
        for l in [8usize, 16, 33, 100] {
            let mono = CausalConv::new(l);
            let plan = ChunkedCausalConv::with_fft_size(l, l, mono.fft_size());
            assert_eq!(plan.fft_size(), mono.fft_size());
            let h = random_signal(&mut rng, l);
            let v = random_signal(&mut rng, l);
            let want = mono.conv(&h, &v);
            let got = plan.conv_streaming(&h, &v);
            for t in 0..l {
                assert!(
                    got[t].to_bits() == want[t].to_bits(),
                    "L={l} t={t}: {} vs {} not bitwise",
                    got[t],
                    want[t]
                );
            }
        }
    }

    #[test]
    fn longctx_plan_bank_ext_ladder() {
        let bank = PlanBank::with_context(16, 2, 100);
        assert_eq!(bank.lens(), vec![8, 16], "base ladder must be untouched");
        assert_eq!(bank.ext_lens(), vec![32, 64, 128]);
        assert_eq!(bank.max_len(), 128);
        assert_eq!(bank.ext_plan(10).unwrap().len(), 16);
        assert_eq!(bank.ext_plan(16).unwrap().len(), 16);
        assert_eq!(bank.ext_plan(17).unwrap().len(), 32);
        assert_eq!(bank.ext_plan(40).unwrap().len(), 64);
        assert_eq!(bank.ext_plan(128).unwrap().len(), 128);
        assert!(bank.ext_plan(129).is_none());
        // Without a context extension the ladder stays empty and max_len is
        // the full bucket.
        let plain = PlanBank::new(16, 2);
        assert!(plain.ext_lens().is_empty());
        assert_eq!(plain.max_len(), 16);
        assert_eq!(PlanBank::with_context(16, 2, 16).ext_lens(), Vec::<usize>::new());
    }
}
