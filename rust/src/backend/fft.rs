//! Iterative radix-2 FFT and the causal FFT convolution it powers.
//!
//! This is the native backend's replacement for the XLA `Fft` op: the
//! O(L log L) "FFTConv" of the paper (Sec. 2, "Fast Methods for
//! Convolutions"). A causal aperiodic convolution of two length-L signals is
//! computed by zero-padding both to the next power of two ≥ 2L, multiplying
//! spectra, and truncating the circular result back to L.
//!
//! [`CausalConv`] is a small *plan*: it owns the twiddle table for one
//! transform size so repeated convolutions at a fixed sequence length (the
//! hot path of every Hyena block) pay the trigonometry once. Gradients reuse
//! the same plan: the adjoint of `conv(h, ·)` is correlation with `h`
//! ([`CausalConv::corr`]), i.e. multiplication by the conjugate spectrum.

use crate::util::rng::Pcg;

/// Radix-2 decimation-in-time FFT plan for one power-of-two size.
pub struct Fft {
    n: usize,
    /// Twiddles `w_k = exp(-2πik/n)` for `k < n/2`.
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl Fft {
    /// Build a plan for transform size `n` (must be a power of two ≥ 1).
    pub fn new(n: usize) -> Fft {
        assert!(n.is_power_of_two(), "FFT size {n} is not a power of two");
        let half = n / 2;
        let mut tw_re = Vec::with_capacity(half);
        let mut tw_im = Vec::with_capacity(half);
        for k in 0..half {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        Fft { n, tw_re, tw_im }
    }

    /// Transform size.
    pub fn size(&self) -> usize {
        self.n
    }

    /// In-place forward transform of `(re, im)`.
    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, false);
    }

    /// In-place inverse transform (includes the 1/n scale).
    pub fn inverse(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, true);
    }

    fn run(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        let n = self.n;
        assert_eq!(re.len(), n, "re buffer length != plan size");
        assert_eq!(im.len(), n, "im buffer length != plan size");
        if n == 1 {
            return;
        }

        // Bit-reversal permutation.
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }

        // Butterflies; at stage `len`, butterfly j uses twiddle w_{j·(n/len)}.
        let mut len = 2usize;
        while len <= n {
            let step = n / len;
            let half = len / 2;
            let mut start = 0usize;
            while start < n {
                for k in 0..half {
                    let wr = self.tw_re[k * step];
                    let wi = if inverse { -self.tw_im[k * step] } else { self.tw_im[k * step] };
                    let a = start + k;
                    let b = a + half;
                    let tr = re[b] * wr - im[b] * wi;
                    let ti = re[b] * wi + im[b] * wr;
                    re[b] = re[a] - tr;
                    im[b] = im[a] - ti;
                    re[a] += tr;
                    im[a] += ti;
                }
                start += len;
            }
            len <<= 1;
        }

        if inverse {
            let scale = 1.0 / n as f32;
            for x in re.iter_mut() {
                *x *= scale;
            }
            for x in im.iter_mut() {
                *x *= scale;
            }
        }
    }
}

/// Spectrum of a real signal: full complex FFT of the zero-padded input.
#[derive(Clone)]
pub struct Spectrum {
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

/// Causal-convolution plan for signals of length `l`.
pub struct CausalConv {
    l: usize,
    fft: Fft,
}

impl CausalConv {
    pub fn new(l: usize) -> CausalConv {
        assert!(l >= 1);
        let n = (2 * l).next_power_of_two();
        CausalConv { l, fft: Fft::new(n) }
    }

    /// Signal length the plan convolves.
    pub fn len(&self) -> usize {
        self.l
    }
    pub fn is_empty(&self) -> bool {
        self.l == 0
    }

    /// FFT size the plan transforms at (≥ 2·len, power of two).
    pub fn fft_size(&self) -> usize {
        self.fft.size()
    }

    /// Spectrum of a real length-`l` signal (zero-padded to the plan size).
    pub fn spectrum(&self, x: &[f32]) -> Spectrum {
        assert_eq!(x.len(), self.l);
        let n = self.fft.size();
        let mut re = vec![0.0f32; n];
        re[..self.l].copy_from_slice(x);
        let mut im = vec![0.0f32; n];
        self.fft.forward(&mut re, &mut im);
        Spectrum { re, im }
    }

    /// `irfft(A · B)[..l]` — causal convolution from two spectra.
    pub fn conv_spec(&self, a: &Spectrum, b: &Spectrum) -> Vec<f32> {
        let n = self.fft.size();
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        for k in 0..n {
            re[k] = a.re[k] * b.re[k] - a.im[k] * b.im[k];
            im[k] = a.re[k] * b.im[k] + a.im[k] * b.re[k];
        }
        self.fft.inverse(&mut re, &mut im);
        re.truncate(self.l);
        re
    }

    /// `irfft(conj(A) · B)[..l]` — causal correlation from two spectra.
    ///
    /// This is the adjoint of [`CausalConv::conv_spec`] in either argument:
    /// with `y = conv(h, v)` and upstream `dy`, `dv = corr(h, dy)` and
    /// `dh = corr(v, dy)`.
    pub fn corr_spec(&self, a: &Spectrum, b: &Spectrum) -> Vec<f32> {
        let n = self.fft.size();
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        for k in 0..n {
            re[k] = a.re[k] * b.re[k] + a.im[k] * b.im[k];
            im[k] = a.re[k] * b.im[k] - a.im[k] * b.re[k];
        }
        self.fft.inverse(&mut re, &mut im);
        re.truncate(self.l);
        re
    }

    /// Causal convolution `y[t] = Σ_{s≤t} h[t−s]·v[s]` in O(L log L).
    pub fn conv(&self, h: &[f32], v: &[f32]) -> Vec<f32> {
        self.conv_spec(&self.spectrum(h), &self.spectrum(v))
    }

    /// Causal correlation `y[s] = Σ_{t≥s} a[t−s]·g[t]` in O(L log L).
    pub fn corr(&self, a: &[f32], g: &[f32]) -> Vec<f32> {
        self.corr_spec(&self.spectrum(a), &self.spectrum(g))
    }
}

/// Reference O(L²) causal convolution (tests + the bench baseline).
pub fn causal_conv_direct(h: &[f32], v: &[f32]) -> Vec<f32> {
    let l = v.len();
    assert_eq!(h.len(), l);
    let mut y = vec![0.0f32; l];
    for t in 0..l {
        let mut acc = 0.0f32;
        for s in 0..=t {
            acc += h[t - s] * v[s];
        }
        y[t] = acc;
    }
    y
}

/// Reference O(L²) causal correlation (tests).
pub fn causal_corr_direct(a: &[f32], g: &[f32]) -> Vec<f32> {
    let l = g.len();
    assert_eq!(a.len(), l);
    let mut y = vec![0.0f32; l];
    for s in 0..l {
        let mut acc = 0.0f32;
        for t in s..l {
            acc += a[t - s] * g[t];
        }
        y[s] = acc;
    }
    y
}

/// Random signal helper shared by the property tests and the bench.
pub fn random_signal(rng: &mut Pcg, l: usize) -> Vec<f32> {
    (0..l).map(|_| rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn forward_matches_naive_dft() {
        let mut rng = Pcg::new(11);
        for n in [1usize, 2, 4, 8, 32] {
            let re_in = random_signal(&mut rng, n);
            let im_in = random_signal(&mut rng, n);
            let (mut re, mut im) = (re_in.clone(), im_in.clone());
            Fft::new(n).forward(&mut re, &mut im);
            for k in 0..n {
                let (mut wr, mut wi) = (0.0f64, 0.0f64);
                for t in 0..n {
                    let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                    let (c, s) = (ang.cos(), ang.sin());
                    wr += re_in[t] as f64 * c - im_in[t] as f64 * s;
                    wi += re_in[t] as f64 * s + im_in[t] as f64 * c;
                }
                assert!(close(re[k], wr as f32, 1e-4), "n={n} k={k}: {} vs {wr}", re[k]);
                assert!(close(im[k], wi as f32, 1e-4), "n={n} k={k}: {} vs {wi}", im[k]);
            }
        }
    }

    #[test]
    fn roundtrip_is_identity() {
        Prop::new("fft roundtrip").cases(64).check(|rng| {
            let n = 1usize << (1 + rng.usize_below(9)); // 2..=512
            let fft = Fft::new(n);
            let re0 = random_signal(rng, n);
            let im0 = random_signal(rng, n);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            fft.forward(&mut re, &mut im);
            fft.inverse(&mut re, &mut im);
            for t in 0..n {
                prop_assert!(close(re[t], re0[t], 1e-4), "re[{t}]: {} vs {}", re[t], re0[t]);
                prop_assert!(close(im[t], im0[t], 1e-4), "im[{t}]: {} vs {}", im[t], im0[t]);
            }
            Ok(())
        });
    }

    #[test]
    fn fft_conv_matches_direct() {
        Prop::new("fft conv == direct conv").cases(64).check(|rng| {
            let l = 1 + rng.usize_below(96);
            let plan = CausalConv::new(l);
            let h = random_signal(rng, l);
            let v = random_signal(rng, l);
            let fast = plan.conv(&h, &v);
            let slow = causal_conv_direct(&h, &v);
            for t in 0..l {
                prop_assert!(close(fast[t], slow[t], 2e-3), "t={t}: {} vs {}", fast[t], slow[t]);
            }
            Ok(())
        });
    }

    #[test]
    fn fft_corr_matches_direct() {
        Prop::new("fft corr == direct corr").cases(64).check(|rng| {
            let l = 1 + rng.usize_below(96);
            let plan = CausalConv::new(l);
            let a = random_signal(rng, l);
            let g = random_signal(rng, l);
            let fast = plan.corr(&a, &g);
            let slow = causal_corr_direct(&a, &g);
            for t in 0..l {
                prop_assert!(close(fast[t], slow[t], 2e-3), "t={t}: {} vs {}", fast[t], slow[t]);
            }
            Ok(())
        });
    }

    #[test]
    fn conv_is_causal() {
        // Changing v[t0..] must not change y[..t0].
        Prop::new("conv causality").cases(32).check(|rng| {
            let l = 2 + rng.usize_below(62);
            let plan = CausalConv::new(l);
            let h = random_signal(rng, l);
            let v = random_signal(rng, l);
            let t0 = 1 + rng.usize_below(l - 1);
            let mut v2 = v.clone();
            for x in v2[t0..].iter_mut() {
                *x += 1.0 + rng.f32();
            }
            let y1 = plan.conv(&h, &v);
            let y2 = plan.conv(&h, &v2);
            for t in 0..t0 {
                prop_assert!(close(y1[t], y2[t], 1e-4), "future leaked into t={t} (t0={t0})");
            }
            Ok(())
        });
    }

    #[test]
    fn spectra_reuse_matches_one_shot() {
        let mut rng = Pcg::new(5);
        let l = 40;
        let plan = CausalConv::new(l);
        let h = random_signal(&mut rng, l);
        let v = random_signal(&mut rng, l);
        let hs = plan.spectrum(&h);
        let vs = plan.spectrum(&v);
        let a = plan.conv_spec(&hs, &vs);
        let b = plan.conv(&h, &v);
        for t in 0..l {
            assert!(close(a[t], b[t], 1e-5));
        }
    }

    #[test]
    fn plan_size_is_padded_power_of_two() {
        assert_eq!(CausalConv::new(1).fft_size(), 2);
        assert_eq!(CausalConv::new(16).fft_size(), 32);
        assert_eq!(CausalConv::new(17).fft_size(), 64);
        assert_eq!(CausalConv::new(1024).fft_size(), 2048);
    }
}
