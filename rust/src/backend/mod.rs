//! Execution backends: one trait, two engines (DESIGN.md §2).
//!
//! The coordinator (trainer, server, decoding, few-shot harness) talks to a
//! model exclusively through the [`Backend`] trait:
//!
//! * [`crate::runtime::ModelState`] — the **pjrt** backend: executes HLO
//!   artifacts AOT-compiled from the JAX L2 / Pallas L1 stack. Fastest when
//!   the PJRT runtime and artifacts are present.
//! * [`native::NativeBackend`] — the **native** backend: a pure-Rust
//!   evaluation of the Hyena operator (FFT long conv + gating, implicit
//!   sine-FFN filters, AdamW training). Runs anywhere, zero dependencies.
//!
//! Selection: `--backend native|pjrt|auto` on the CLI, the `HYENA_BACKEND`
//! environment variable, or automatic detection (an artifact directory with
//! compiled HLO selects pjrt; anything else selects native).
//!
//! Threading: native backends capture the process-wide worker pool
//! ([`crate::util::pool`]) at construction, sized by `--threads N` /
//! `HYENA_THREADS` / available parallelism. The trainer and the batching
//! server therefore share one pool — size it once in `main`, before the
//! first backend loads.

pub mod fft;
pub mod native;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::{Manifest, ModelState, Tensor};

/// A model engine the coordinator can drive.
///
/// Implementations own parameters and optimizer state; the coordinator
/// exchanges only host [`Tensor`]s and reads shapes/hyperparameters from the
/// (real or synthesized) [`Manifest`].
pub trait Backend {
    /// The artifact manifest (pjrt) or its synthesized equivalent (native).
    fn manifest(&self) -> &Manifest;

    /// Optimizer steps taken so far.
    fn step(&self) -> u64;

    /// Overwrite the step counter (checkpoint restore).
    fn set_step(&mut self, step: u64);

    /// Re-initialize parameters from `seed` and reset the optimizer.
    fn reinit(&mut self, seed: i32) -> Result<()>;

    /// One optimizer step on a host batch (LM: `[tokens, targets, mask]`),
    /// returning the scalar loss.
    fn train_step(&mut self, batch: &[Tensor]) -> Result<f32>;

    /// Forward pass on data tensors, returning logits.
    fn forward(&self, inputs: &[Tensor]) -> Result<Tensor>;

    /// Materialize the block-0 implicit filters `(N, D, L)` (Fig. D.5).
    fn dump_filters(&self) -> Result<Tensor>;

    /// Copy parameters out to host tensors in manifest order.
    fn params_host(&self) -> Result<Vec<Tensor>>;

    /// Restore parameters from host tensors in manifest order.
    fn set_params(&mut self, tensors: &[Tensor]) -> Result<()>;
}

/// Which engine to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust evaluation (no artifacts, no PJRT needed).
    Native,
    /// PJRT execution of AOT-compiled HLO artifacts.
    Pjrt,
}

impl BackendKind {
    /// Spelling → kind; `None` for `auto`/empty (defer to detection).
    /// The single source of truth for backend names.
    fn from_name(s: &str) -> Result<Option<BackendKind>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "native" => Ok(Some(BackendKind::Native)),
            "pjrt" | "xla" => Ok(Some(BackendKind::Pjrt)),
            "auto" | "" => Ok(None),
            other => bail!("unknown backend {other:?} (expected native|pjrt|auto)"),
        }
    }

    /// Parse a CLI spelling. `auto`/empty defers to [`BackendKind::detect`].
    pub fn parse(s: &str, dir: &Path) -> Result<BackendKind> {
        match BackendKind::from_name(s)? {
            Some(kind) => Ok(kind),
            None => BackendKind::detect(dir),
        }
    }

    /// Resolve the backend for `dir`: `HYENA_BACKEND` wins when set;
    /// otherwise a directory containing compiled HLO selects pjrt and
    /// everything else selects native.
    pub fn detect(dir: &Path) -> Result<BackendKind> {
        if let Ok(v) = std::env::var("HYENA_BACKEND") {
            if let Some(kind) = BackendKind::from_name(&v)
                .map_err(|e| anyhow::anyhow!("HYENA_BACKEND: {e}"))?
            {
                return Ok(kind);
            }
        }
        if dir.join("init.hlo.txt").exists() {
            Ok(BackendKind::Pjrt)
        } else {
            Ok(BackendKind::Native)
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Construct a backend of `kind` for the artifact directory (or built-in
/// config name) `dir`, with parameters initialized from `seed`.
pub fn load(kind: BackendKind, dir: &Path, seed: i32) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(native::NativeBackend::load(dir, seed)?)),
        BackendKind::Pjrt => Ok(Box::new(ModelState::load(dir, seed)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn parse_spellings() {
        let d = PathBuf::from("artifacts/none");
        assert_eq!(BackendKind::parse("native", &d).unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("PJRT", &d).unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu", &d).is_err());
        // auto on a non-artifact dir resolves native (no env override set).
        if std::env::var_os("HYENA_BACKEND").is_none() {
            assert_eq!(BackendKind::parse("auto", &d).unwrap(), BackendKind::Native);
        }
    }

    #[test]
    fn load_native_builtin_via_trait_object() {
        let model = load(BackendKind::Native, &PathBuf::from("artifacts/golden_tiny"), 3).unwrap();
        assert_eq!(model.manifest().name, "golden_tiny");
        assert_eq!(model.step(), 0);
        let params = model.params_host().unwrap();
        assert_eq!(params.len(), model.manifest().params.len());
    }

    #[test]
    fn reinit_changes_parameters_deterministically() {
        let dir = PathBuf::from("artifacts/native_micro");
        let mut a = load(BackendKind::Native, &dir, 0).unwrap();
        let b = load(BackendKind::Native, &dir, 1).unwrap();
        let flat = |m: &dyn Backend| -> Vec<f32> {
            m.params_host()
                .unwrap()
                .iter()
                .flat_map(|t| t.as_f32().unwrap().to_vec())
                .collect()
        };
        assert_ne!(flat(a.as_ref()), flat(b.as_ref()));
        a.reinit(1).unwrap();
        assert_eq!(flat(a.as_ref()), flat(b.as_ref()));
    }
}
