//! Execution backends: one trait, two engines (DESIGN.md §2).
//!
//! The coordinator (trainer, server, decoding, few-shot harness) talks to a
//! model exclusively through the [`Backend`] trait:
//!
//! * [`crate::runtime::ModelState`] — the **pjrt** backend: executes HLO
//!   artifacts AOT-compiled from the JAX L2 / Pallas L1 stack. Fastest when
//!   the PJRT runtime and artifacts are present.
//! * [`native::NativeBackend`] — the **native** backend: a pure-Rust
//!   evaluation of the Hyena operator (FFT long conv + gating, implicit
//!   sine-FFN filters, AdamW training). Runs anywhere, zero dependencies.
//!
//! Selection: `--backend native|pjrt|auto` on the CLI, the `HYENA_BACKEND`
//! environment variable, or automatic detection (an artifact directory with
//! compiled HLO selects pjrt; anything else selects native).
//!
//! Threading: native backends capture the process-wide worker pool
//! ([`crate::util::pool`]) at construction, sized by `--threads N` /
//! `HYENA_THREADS` / available parallelism. The trainer and the batching
//! server therefore share one pool — size it once in `main`, before the
//! first backend loads.

pub mod fft;
pub mod native;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::{Manifest, ModelState, Tensor};

/// Arena/workspace accounting a backend can expose through the train and
/// serve reports (ROADMAP "per-step arena high-water metrics"). All fields
/// are zero for engines that do not track them.
#[derive(Debug, Clone, Default)]
pub struct MemReport {
    /// Training-scratch arena high-water mark, bytes.
    pub train_arena_hiwater_bytes: usize,
    /// Fresh/grown allocations the training arena has performed (steady
    /// state: stops increasing).
    pub train_arena_allocs: u64,
    /// Serving-workspace arena high-water mark, bytes.
    pub serve_arena_hiwater_bytes: usize,
    /// Fresh/grown allocations the serving arena has performed.
    pub serve_arena_allocs: u64,
    /// Bytes held by cached per-bucket filter spectra.
    pub serve_spec_bytes: usize,
    /// Inference forward passes executed (decoding runs one per round per
    /// batch, so this exceeds the request count by the mean decode length).
    pub serve_forwards: u64,
    /// Serving bucket lengths, ascending (last = full seqlen).
    pub bucket_lens: Vec<usize>,
    /// Inference forwards executed per bucket, aligned with `bucket_lens` —
    /// counted at the point of plan selection, so an all-full-bucket
    /// histogram is direct evidence of a full-pad fallback.
    pub bucket_hits: Vec<u64>,
}

/// A model engine the coordinator can drive.
///
/// Implementations own parameters and optimizer state; the coordinator
/// exchanges only host [`Tensor`]s and reads shapes/hyperparameters from the
/// (real or synthesized) [`Manifest`].
pub trait Backend {
    /// The artifact manifest (pjrt) or its synthesized equivalent (native).
    fn manifest(&self) -> &Manifest;

    /// Optimizer steps taken so far.
    fn step(&self) -> u64;

    /// Overwrite the step counter (checkpoint restore).
    fn set_step(&mut self, step: u64);

    /// Re-initialize parameters from `seed` and reset the optimizer.
    fn reinit(&mut self, seed: i32) -> Result<()>;

    /// One optimizer step on a host batch (LM: `[tokens, targets, mask]`),
    /// returning the scalar loss.
    fn train_step(&mut self, batch: &[Tensor]) -> Result<f32>;

    /// Forward pass on data tensors, returning logits.
    fn forward(&self, inputs: &[Tensor]) -> Result<Tensor>;

    /// Inference-only forward over `rows` token rows of length `l ≤ seqlen`,
    /// returning logits `(rows, l, vocab)`.
    ///
    /// The default pads every row to the compiled `(batch, seqlen)` shape
    /// and slices the result out of [`Backend::forward`] — correct for any
    /// engine, but it pays the full-length cost and rejects more rows than
    /// the compiled batch. The native backend overrides this with its
    /// shape-bucketed zero-alloc serving path, which has no static batch
    /// dimension and therefore accepts any nonzero row count — callers that
    /// must stay engine-portable (the server does, via the batcher's
    /// `batch_size`) should keep rows within `manifest().batch()`.
    fn infer(&self, tokens: &[i32], rows: usize, l: usize) -> Result<Tensor> {
        let man = self.manifest();
        let (bcomp, full, vocab) = (man.batch()?, man.seqlen()?, man.vocab()?);
        if l == 0 || l > full {
            bail!("infer length {l} out of range 1..={full}");
        }
        if rows > bcomp {
            bail!("{rows} rows > compiled batch {bcomp}");
        }
        if tokens.len() != rows * l {
            bail!("tokens length {} != rows {rows} × length {l}", tokens.len());
        }
        let mut toks = vec![0i32; bcomp * full];
        for r in 0..rows {
            toks[r * full..r * full + l].copy_from_slice(&tokens[r * l..(r + 1) * l]);
        }
        let logits = self.forward(&[Tensor::from_i32(&[bcomp, full], toks)?])?;
        let lf = logits.as_f32()?;
        let mut out = Vec::with_capacity(rows * l * vocab);
        for r in 0..rows {
            out.extend_from_slice(&lf[(r * full) * vocab..(r * full + l) * vocab]);
        }
        Tensor::from_f32(&[rows, l, vocab], out)
    }

    /// Serving bucket lengths, ascending. Engines without shape bucketing
    /// report the single compiled seqlen.
    fn serve_buckets(&self) -> Vec<usize> {
        self.manifest().seqlen().map(|l| vec![l]).unwrap_or_default()
    }

    /// Rebuild the serving bucket ladder with `levels` buckets (1 disables
    /// bucketing). No-op for engines without shape bucketing.
    fn set_serve_buckets(&mut self, _levels: usize) -> Result<()> {
        Ok(())
    }

    /// Arena/workspace accounting for the train/serve reports, when the
    /// engine tracks it.
    fn mem_report(&self) -> Option<MemReport> {
        None
    }

    /// Materialize the block-0 implicit filters `(N, D, L)` (Fig. D.5).
    fn dump_filters(&self) -> Result<Tensor>;

    /// Copy parameters out to host tensors in manifest order.
    fn params_host(&self) -> Result<Vec<Tensor>>;

    /// Restore parameters from host tensors in manifest order.
    fn set_params(&mut self, tensors: &[Tensor]) -> Result<()>;
}

/// Which engine to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust evaluation (no artifacts, no PJRT needed).
    Native,
    /// PJRT execution of AOT-compiled HLO artifacts.
    Pjrt,
}

impl BackendKind {
    /// Spelling → kind; `None` for `auto`/empty (defer to detection).
    /// The single source of truth for backend names.
    fn from_name(s: &str) -> Result<Option<BackendKind>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "native" => Ok(Some(BackendKind::Native)),
            "pjrt" | "xla" => Ok(Some(BackendKind::Pjrt)),
            "auto" | "" => Ok(None),
            other => bail!("unknown backend {other:?} (expected native|pjrt|auto)"),
        }
    }

    /// Parse a CLI spelling. `auto`/empty defers to [`BackendKind::detect`].
    pub fn parse(s: &str, dir: &Path) -> Result<BackendKind> {
        match BackendKind::from_name(s)? {
            Some(kind) => Ok(kind),
            None => BackendKind::detect(dir),
        }
    }

    /// Resolve the backend for `dir`: `HYENA_BACKEND` wins when set;
    /// otherwise a directory containing compiled HLO selects pjrt and
    /// everything else selects native.
    pub fn detect(dir: &Path) -> Result<BackendKind> {
        if let Ok(v) = std::env::var("HYENA_BACKEND") {
            if let Some(kind) = BackendKind::from_name(&v)
                .map_err(|e| anyhow::anyhow!("HYENA_BACKEND: {e}"))?
            {
                return Ok(kind);
            }
        }
        if dir.join("init.hlo.txt").exists() {
            Ok(BackendKind::Pjrt)
        } else {
            Ok(BackendKind::Native)
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Construct a backend of `kind` for the artifact directory (or built-in
/// config name) `dir`, with parameters initialized from `seed`.
pub fn load(kind: BackendKind, dir: &Path, seed: i32) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(native::NativeBackend::load(dir, seed)?)),
        BackendKind::Pjrt => Ok(Box::new(ModelState::load(dir, seed)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn parse_spellings() {
        let d = PathBuf::from("artifacts/none");
        assert_eq!(BackendKind::parse("native", &d).unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("PJRT", &d).unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu", &d).is_err());
        // auto on a non-artifact dir resolves native (no env override set).
        if std::env::var_os("HYENA_BACKEND").is_none() {
            assert_eq!(BackendKind::parse("auto", &d).unwrap(), BackendKind::Native);
        }
    }

    #[test]
    fn load_native_builtin_via_trait_object() {
        let model = load(BackendKind::Native, &PathBuf::from("artifacts/golden_tiny"), 3).unwrap();
        assert_eq!(model.manifest().name, "golden_tiny");
        assert_eq!(model.step(), 0);
        let params = model.params_host().unwrap();
        assert_eq!(params.len(), model.manifest().params.len());
    }

    #[test]
    fn default_infer_pads_to_the_compiled_shape() {
        // A wrapper that delegates everything but keeps the trait-default
        // `infer`, so the pad-and-slice fallback itself is covered.
        struct PadOnly(Box<dyn Backend>);
        impl Backend for PadOnly {
            fn manifest(&self) -> &Manifest {
                self.0.manifest()
            }
            fn step(&self) -> u64 {
                self.0.step()
            }
            fn set_step(&mut self, step: u64) {
                self.0.set_step(step)
            }
            fn reinit(&mut self, seed: i32) -> Result<()> {
                self.0.reinit(seed)
            }
            fn train_step(&mut self, batch: &[Tensor]) -> Result<f32> {
                self.0.train_step(batch)
            }
            fn forward(&self, inputs: &[Tensor]) -> Result<Tensor> {
                self.0.forward(inputs)
            }
            fn dump_filters(&self) -> Result<Tensor> {
                self.0.dump_filters()
            }
            fn params_host(&self) -> Result<Vec<Tensor>> {
                self.0.params_host()
            }
            fn set_params(&mut self, tensors: &[Tensor]) -> Result<()> {
                self.0.set_params(tensors)
            }
        }

        let dir = PathBuf::from("artifacts/golden_tiny");
        let native = load(BackendKind::Native, &dir, 0).unwrap();
        let fallback = PadOnly(load(BackendKind::Native, &dir, 0).unwrap());
        assert_eq!(fallback.serve_buckets(), vec![16]);
        assert!(fallback.mem_report().is_none());

        let (l, v) = (5usize, 32usize);
        let tokens: Vec<i32> = (0..l as i32).map(|i| i + 1).collect();
        let got = fallback.infer(&tokens, 1, l).unwrap();
        assert_eq!(got.shape(), &[1, l, v]);
        // The fallback must equal the full-pad forward's prefix exactly
        // (same engine, same full-length plan underneath).
        let mut padded = tokens.clone();
        padded.resize(16, 0);
        let mut full_batch = vec![0i32; 2 * 16];
        full_batch[..16].copy_from_slice(&padded);
        let full = native
            .forward(&[Tensor::from_i32(&[2, 16], full_batch).unwrap()])
            .unwrap();
        assert_eq!(got.as_f32().unwrap(), &full.as_f32().unwrap()[..l * v]);
        // Out-of-range lengths are rejected.
        assert!(fallback.infer(&tokens, 1, 0).is_err());
        assert!(fallback.infer(&tokens, 1, 99).is_err());
    }

    #[test]
    fn reinit_changes_parameters_deterministically() {
        let dir = PathBuf::from("artifacts/native_micro");
        let mut a = load(BackendKind::Native, &dir, 0).unwrap();
        let b = load(BackendKind::Native, &dir, 1).unwrap();
        let flat = |m: &dyn Backend| -> Vec<f32> {
            m.params_host()
                .unwrap()
                .iter()
                .flat_map(|t| t.as_f32().unwrap().to_vec())
                .collect()
        };
        assert_ne!(flat(a.as_ref()), flat(b.as_ref()));
        a.reinit(1).unwrap();
        assert_eq!(flat(a.as_ref()), flat(b.as_ref()));
    }
}
