//! Execution backends: one trait, two engines (DESIGN.md §2).
//!
//! The coordinator (trainer, server, decoding, few-shot harness) talks to a
//! model exclusively through the [`Backend`] trait:
//!
//! * [`crate::runtime::ModelState`] — the **pjrt** backend: executes HLO
//!   artifacts AOT-compiled from the JAX L2 / Pallas L1 stack. Fastest when
//!   the PJRT runtime and artifacts are present.
//! * [`native::NativeBackend`] — the **native** backend: a pure-Rust
//!   evaluation of the Hyena operator (FFT long conv + gating, implicit
//!   sine-FFN filters, AdamW training). Runs anywhere, zero dependencies.
//!
//! Selection: `--backend native|pjrt|auto` on the CLI, the `HYENA_BACKEND`
//! environment variable, or automatic detection (an artifact directory with
//! compiled HLO selects pjrt; anything else selects native).
//!
//! Threading: native backends capture the process-wide worker pool
//! ([`crate::util::pool`]) at construction, sized by `--threads N` /
//! `HYENA_THREADS` / available parallelism. The trainer and the batching
//! server therefore share one pool — size it once in `main`, before the
//! first backend loads.

pub mod fft;
pub mod native;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::{Manifest, ModelState, Tensor};

/// Arena/workspace accounting a backend can expose through the train and
/// serve reports (ROADMAP "per-step arena high-water metrics"). All fields
/// are zero for engines that do not track them.
#[derive(Debug, Clone, Default)]
pub struct MemReport {
    /// Training-scratch arena high-water mark, bytes.
    pub train_arena_hiwater_bytes: usize,
    /// Fresh/grown allocations the training arena has performed (steady
    /// state: stops increasing).
    pub train_arena_allocs: u64,
    /// Serving-workspace arena high-water mark, bytes.
    pub serve_arena_hiwater_bytes: usize,
    /// Fresh/grown allocations the serving arena has performed.
    pub serve_arena_allocs: u64,
    /// Bytes held by the input-independent filter caches: per-bucket
    /// spectra plus the decode path's reversed time-domain filters.
    pub serve_spec_bytes: usize,
    /// Inference forward passes executed (streaming decode runs one per
    /// prefill; the recompute fallback runs one per decode round).
    pub serve_forwards: u64,
    /// Serving bucket lengths, ascending (last = full seqlen).
    pub bucket_lens: Vec<usize>,
    /// Inference forwards executed per bucket, aligned with `bucket_lens` —
    /// counted at the point of plan selection, so an all-full-bucket
    /// histogram is direct evidence of a full-pad fallback.
    pub bucket_hits: Vec<u64>,
    /// Decode sessions currently holding streaming state.
    pub decode_sessions_live: u64,
    /// Engine-level decode sessions begun over the engine's lifetime.
    /// Counts every prefill that builds session state, so mid-session
    /// stale-state rebuilds (after a parameter update) and failed prefill
    /// attempts are included — this can exceed the caller-visible session
    /// count, never undercount it.
    pub decode_sessions_total: u64,
    /// Tokens served through the streaming `decode_step` path (recompute
    /// fallbacks do not count — zero here under decode traffic is direct
    /// evidence the engine is re-running prefixes).
    pub decode_steps: u64,
    /// Batched decode rounds served through the engine's
    /// `decode_step_batch` fast path (zero for engines that only loop the
    /// serial step).
    pub decode_step_batches: u64,
    /// Session-tokens served by those batched rounds (Σ rows per round).
    pub decode_step_batch_rows: u64,
    /// Bytes held by live per-session ring buffers / channel histories.
    pub decode_state_bytes: usize,
    /// Name of the engine's active compute-kernel dispatch table
    /// (`"scalar"` / `"simd"`; empty for engines without one). Benches and
    /// the `kernel-smoke` gate verify which path actually ran through this
    /// field rather than trusting `HYENA_KERNEL`.
    pub kernel: String,
    /// Longest prompt + generation the engine admits — the compiled seqlen
    /// unless the engine supports context extension (`--max-context`).
    pub max_context: usize,
    /// Extended monolithic plan lengths above the serving buckets,
    /// ascending (empty without a context extension). These back the
    /// exactness oracle, not the serving path.
    pub ext_bucket_lens: Vec<usize>,
    /// Prompts served through the chunked overlap-save prefill (zero for
    /// engines without one).
    pub prefill_chunked: u64,
    /// Total overlap-save chunks processed across those prefills.
    pub prefill_chunks: u64,
    /// Peak bytes one chunked prefill checked out of the serving workspace
    /// (carries + per-chunk activations + block buffers). O(chunk), not
    /// O(prompt): at a fixed model this gauge must match between a 4K and a
    /// 64K prompt — the ISSUE's long-context memory gate.
    pub prefill_chunk_bytes: usize,
    /// Parameter epoch the engine is serving (bumped by every
    /// `set_params`; invalidates cached serve state and live decode
    /// sessions). A replica fleet reports `max` across replicas — after a
    /// weight broadcast every replica must agree.
    pub params_epoch: u64,
}

impl MemReport {
    /// Fold another engine's report into this one — the replica fleet's
    /// aggregated `GET /mem`. Counters and byte gauges sum; bucket ladders
    /// and the kernel name must agree across a homogeneous fleet, so the
    /// first non-empty value wins; `params_epoch` takes the max (replicas
    /// lag only mid-broadcast, and admission is gated while they do).
    pub fn merge(&mut self, other: &MemReport) {
        self.train_arena_hiwater_bytes += other.train_arena_hiwater_bytes;
        self.train_arena_allocs += other.train_arena_allocs;
        self.serve_arena_hiwater_bytes += other.serve_arena_hiwater_bytes;
        self.serve_arena_allocs += other.serve_arena_allocs;
        self.serve_spec_bytes += other.serve_spec_bytes;
        self.serve_forwards += other.serve_forwards;
        if self.bucket_lens.is_empty() {
            self.bucket_lens = other.bucket_lens.clone();
            self.bucket_hits = other.bucket_hits.clone();
        } else if self.bucket_lens == other.bucket_lens
            && self.bucket_hits.len() == other.bucket_hits.len()
        {
            for (h, o) in self.bucket_hits.iter_mut().zip(&other.bucket_hits) {
                *h += *o;
            }
        }
        self.decode_sessions_live += other.decode_sessions_live;
        self.decode_sessions_total += other.decode_sessions_total;
        self.decode_steps += other.decode_steps;
        self.decode_step_batches += other.decode_step_batches;
        self.decode_step_batch_rows += other.decode_step_batch_rows;
        self.decode_state_bytes += other.decode_state_bytes;
        if self.kernel.is_empty() {
            self.kernel = other.kernel.clone();
        }
        self.max_context = self.max_context.max(other.max_context);
        if self.ext_bucket_lens.is_empty() {
            self.ext_bucket_lens = other.ext_bucket_lens.clone();
        }
        self.prefill_chunked += other.prefill_chunked;
        self.prefill_chunks += other.prefill_chunks;
        self.prefill_chunk_bytes = self.prefill_chunk_bytes.max(other.prefill_chunk_bytes);
        self.params_epoch = self.params_epoch.max(other.params_epoch);
    }
}

/// One autoregressive decode request in flight (DESIGN.md §Decode).
///
/// The portable state is the token sequence itself: the default trait
/// implementation re-runs the growing prefix through [`Backend::infer`]
/// every step, which is correct for any engine. Engines with a streaming
/// path (the native backend's per-request recurrence state) stash their
/// private state in `ext` and serve each step at O(L) instead of
/// O(L log L); if that state goes stale (a parameter update mid-session)
/// they rebuild it from `tokens`, so the session is always resumable.
pub struct DecodeSession {
    /// Prompt + generated tokens so far (grows by one per `decode_step`).
    tokens: Vec<i32>,
    /// Steps served through this session.
    steps: u64,
    /// Engine-private streaming state (`None` for recompute engines).
    ext: Option<Box<dyn std::any::Any + Send>>,
}

impl DecodeSession {
    /// Begin a session over `prompt` with no engine-private state (the
    /// recompute default). Engine overrides attach state via
    /// [`DecodeSession::set_ext`].
    pub fn new(prompt: &[i32]) -> DecodeSession {
        DecodeSession { tokens: prompt.to_vec(), steps: 0, ext: None }
    }

    /// Prompt + generated tokens so far.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Current sequence length (prompt + generated).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Steps served through this session.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Attach engine-private streaming state.
    pub fn set_ext(&mut self, ext: Box<dyn std::any::Any + Send>) {
        self.ext = Some(ext);
    }

    /// Borrow the engine-private state as `T` (None if absent or foreign).
    pub fn ext_mut<T: 'static>(&mut self) -> Option<&mut T> {
        self.ext.as_mut().and_then(|e| e.downcast_mut::<T>())
    }

    /// Detach the engine-private state as `T` (None if absent or foreign).
    pub fn take_ext<T: 'static>(&mut self) -> Option<Box<T>> {
        match self.ext.take() {
            Some(e) => match e.downcast::<T>() {
                Ok(t) => Some(t),
                Err(e) => {
                    self.ext = Some(e);
                    None
                }
            },
            None => None,
        }
    }
}

/// A model engine the coordinator can drive.
///
/// Implementations own parameters and optimizer state; the coordinator
/// exchanges only host [`Tensor`]s and reads shapes/hyperparameters from the
/// (real or synthesized) [`Manifest`].
pub trait Backend {
    /// The artifact manifest (pjrt) or its synthesized equivalent (native).
    fn manifest(&self) -> &Manifest;

    /// Optimizer steps taken so far.
    fn step(&self) -> u64;

    /// Overwrite the step counter (checkpoint restore).
    fn set_step(&mut self, step: u64);

    /// Re-initialize parameters from `seed` and reset the optimizer.
    fn reinit(&mut self, seed: i32) -> Result<()>;

    /// One optimizer step on a host batch (LM: `[tokens, targets, mask]`),
    /// returning the scalar loss.
    fn train_step(&mut self, batch: &[Tensor]) -> Result<f32>;

    /// Forward pass on data tensors, returning logits.
    fn forward(&self, inputs: &[Tensor]) -> Result<Tensor>;

    /// Inference-only forward over `rows` token rows of length `l ≤ seqlen`,
    /// returning logits `(rows, l, vocab)`.
    ///
    /// The default pads every row to the compiled `(batch, seqlen)` shape
    /// and slices the result out of [`Backend::forward`] — correct for any
    /// engine, but it pays the full-length cost and rejects more rows than
    /// the compiled batch. The native backend overrides this with its
    /// shape-bucketed zero-alloc serving path, which has no static batch
    /// dimension and therefore accepts any nonzero row count — callers that
    /// must stay engine-portable (the server does, via the batcher's
    /// `batch_size`) should keep rows within `manifest().batch()`.
    fn infer(&self, tokens: &[i32], rows: usize, l: usize) -> Result<Tensor> {
        let man = self.manifest();
        let (bcomp, full, vocab) = (man.batch()?, man.seqlen()?, man.vocab()?);
        if l == 0 || l > full {
            bail!("infer length {l} out of range 1..={full}");
        }
        if rows > bcomp {
            bail!("{rows} rows > compiled batch {bcomp}");
        }
        if tokens.len() != rows * l {
            bail!("tokens length {} != rows {rows} × length {l}", tokens.len());
        }
        let mut toks = vec![0i32; bcomp * full];
        for r in 0..rows {
            toks[r * full..r * full + l].copy_from_slice(&tokens[r * l..(r + 1) * l]);
        }
        let logits = self.forward(&[Tensor::from_i32(&[bcomp, full], toks)?])?;
        let lf = logits.as_f32()?;
        let mut out = Vec::with_capacity(rows * l * vocab);
        for r in 0..rows {
            out.extend_from_slice(&lf[(r * full) * vocab..(r * full + l) * vocab]);
        }
        Tensor::from_f32(&[rows, l, vocab], out)
    }

    /// Begin a streaming decode session over `prompt`: run the prefill and
    /// write the last position's `(V,)` logits row into `logits`.
    ///
    /// The default prefills through [`Backend::infer`] and keeps no engine
    /// state, so each subsequent [`Backend::decode_step`] re-runs the whole
    /// prefix — today's recompute decode, correct for any engine (PJRT is
    /// untouched). The native backend overrides the pair with per-session
    /// recurrence state and O(L)-per-token steps (DESIGN.md §Decode).
    fn decode_begin(&self, prompt: &[i32], logits: &mut Vec<f32>) -> Result<DecodeSession> {
        let full = self.manifest().seqlen()?;
        if prompt.is_empty() || prompt.len() >= full {
            bail!("prompt length {} out of range (1..{full})", prompt.len());
        }
        let sess = DecodeSession::new(prompt);
        let v = self.manifest().vocab()?;
        let l = sess.tokens.len();
        let t = self.infer(&sess.tokens, 1, l)?;
        logits.clear();
        logits.extend_from_slice(&t.as_f32()?[(l - 1) * v..l * v]);
        Ok(sess)
    }

    /// Advance a session by one token: append `token` to the sequence and
    /// write the `(V,)` logits row at its position into `logits`. Fails at
    /// the model's window edge (callers stop rows there).
    fn decode_step(
        &self,
        sess: &mut DecodeSession,
        token: i32,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let full = self.manifest().seqlen()?;
        if sess.tokens.len() >= full {
            bail!("decode session is at the window edge (length {full})");
        }
        sess.tokens.push(token);
        let l = sess.tokens.len();
        let res = self.infer(&sess.tokens, 1, l).and_then(|t| {
            let v = self.manifest().vocab()?;
            logits.clear();
            logits.extend_from_slice(&t.as_f32()?[(l - 1) * v..l * v]);
            Ok(())
        });
        match res {
            Ok(()) => {
                sess.steps += 1;
                Ok(())
            }
            Err(e) => {
                // Keep the session consistent on failure: the token was
                // not consumed, so it must not stay in the history.
                sess.tokens.pop();
                Err(e)
            }
        }
    }

    /// Advance several sessions by one token each — the server's token
    /// round as **one engine call**. `logits` receives the `rows` `(V,)`
    /// rows packed; the return value carries one outcome per session, in
    /// order (a failed row's logits slice is zeroed and its token is not
    /// consumed, exactly like [`Backend::decode_step`]).
    ///
    /// The default loops [`Backend::decode_step`] — correct for any engine
    /// (pjrt untouched). The native backend overrides it to stack all live
    /// sessions' current positions into one `(rows, D)` dense pass per
    /// block (histories stay per-session), recovering dense-kernel row
    /// blocking at high occupancy (DESIGN.md §Kernels).
    fn decode_step_batch(
        &self,
        sessions: &mut [&mut DecodeSession],
        tokens: &[i32],
        logits: &mut Vec<f32>,
    ) -> Vec<Result<()>> {
        assert_eq!(
            sessions.len(),
            tokens.len(),
            "decode_step_batch wants one token per session"
        );
        let v = match self.manifest().vocab() {
            Ok(v) => v,
            Err(e) => {
                logits.clear();
                return sessions.iter().map(|_| Err(anyhow::anyhow!("{e:#}"))).collect();
            }
        };
        logits.clear();
        logits.resize(sessions.len() * v, 0.0);
        let mut row = Vec::new();
        sessions
            .iter_mut()
            .zip(tokens.iter())
            .enumerate()
            .map(|(i, (sess, &tok))| {
                let res = self.decode_step(sess, tok, &mut row);
                if res.is_ok() {
                    logits[i * v..(i + 1) * v].copy_from_slice(&row);
                }
                res
            })
            .collect()
    }

    /// Finish a session, releasing any engine-private state back to the
    /// engine's workspaces. The recompute default has none to release.
    fn decode_end(&self, _sess: DecodeSession) {}

    /// Serving bucket lengths, ascending. Engines without shape bucketing
    /// report the single compiled seqlen.
    fn serve_buckets(&self) -> Vec<usize> {
        self.manifest().seqlen().map(|l| vec![l]).unwrap_or_default()
    }

    /// Rebuild the serving bucket ladder with `levels` buckets (1 disables
    /// bucketing). No-op for engines without shape bucketing.
    fn set_serve_buckets(&mut self, _levels: usize) -> Result<()> {
        Ok(())
    }

    /// Longest decode session (prompt + generation) the engine admits. The
    /// default is the compiled window; engines with chunked long-context
    /// prefill report their extended `--max-context` bound, and the
    /// coordinator's admission/retirement logic keys off this instead of
    /// the raw seqlen.
    /// (Manifests without a compiled seqlen report an unbounded window, as
    /// the serving loop always has.)
    fn decode_window(&self) -> usize {
        self.manifest().seqlen().unwrap_or(usize::MAX)
    }

    /// Extend the decode window to `n` positions (`--max-context`). Engines
    /// without a long-context path accept only the compiled window.
    fn set_max_context(&mut self, n: usize) -> Result<()> {
        let full = self.manifest().seqlen()?;
        if n != full {
            bail!("this backend cannot extend the context window past {full}");
        }
        Ok(())
    }

    /// Arena/workspace accounting for the train/serve reports, when the
    /// engine tracks it.
    fn mem_report(&self) -> Option<MemReport> {
        None
    }

    /// Materialize the block-0 implicit filters `(N, D, L)` (Fig. D.5).
    fn dump_filters(&self) -> Result<Tensor>;

    /// Copy parameters out to host tensors in manifest order.
    fn params_host(&self) -> Result<Vec<Tensor>>;

    /// Restore parameters from host tensors in manifest order.
    fn set_params(&mut self, tensors: &[Tensor]) -> Result<()>;
}

/// Which engine to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust evaluation (no artifacts, no PJRT needed).
    Native,
    /// PJRT execution of AOT-compiled HLO artifacts.
    Pjrt,
}

impl BackendKind {
    /// Spelling → kind; `None` for `auto`/empty (defer to detection).
    /// The single source of truth for backend names.
    fn from_name(s: &str) -> Result<Option<BackendKind>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "native" => Ok(Some(BackendKind::Native)),
            "pjrt" | "xla" => Ok(Some(BackendKind::Pjrt)),
            "auto" | "" => Ok(None),
            other => bail!("unknown backend {other:?} (expected native|pjrt|auto)"),
        }
    }

    /// Parse a CLI spelling. `auto`/empty defers to [`BackendKind::detect`].
    pub fn parse(s: &str, dir: &Path) -> Result<BackendKind> {
        match BackendKind::from_name(s)? {
            Some(kind) => Ok(kind),
            None => BackendKind::detect(dir),
        }
    }

    /// Resolve the backend for `dir`: `HYENA_BACKEND` wins when set;
    /// otherwise a directory containing compiled HLO selects pjrt and
    /// everything else selects native.
    pub fn detect(dir: &Path) -> Result<BackendKind> {
        if let Ok(v) = std::env::var("HYENA_BACKEND") {
            if let Some(kind) = BackendKind::from_name(&v)
                .map_err(|e| anyhow::anyhow!("HYENA_BACKEND: {e}"))?
            {
                return Ok(kind);
            }
        }
        if dir.join("init.hlo.txt").exists() {
            Ok(BackendKind::Pjrt)
        } else {
            Ok(BackendKind::Native)
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Construct a backend of `kind` for the artifact directory (or built-in
/// config name) `dir`, with parameters initialized from `seed`.
pub fn load(kind: BackendKind, dir: &Path, seed: i32) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(native::NativeBackend::load(dir, seed)?)),
        BackendKind::Pjrt => Ok(Box::new(ModelState::load(dir, seed)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn parse_spellings() {
        let d = PathBuf::from("artifacts/none");
        assert_eq!(BackendKind::parse("native", &d).unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("PJRT", &d).unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu", &d).is_err());
        // auto on a non-artifact dir resolves native (no env override set).
        if std::env::var_os("HYENA_BACKEND").is_none() {
            assert_eq!(BackendKind::parse("auto", &d).unwrap(), BackendKind::Native);
        }
    }

    #[test]
    fn load_native_builtin_via_trait_object() {
        let model = load(BackendKind::Native, &PathBuf::from("artifacts/golden_tiny"), 3).unwrap();
        assert_eq!(model.manifest().name, "golden_tiny");
        assert_eq!(model.step(), 0);
        let params = model.params_host().unwrap();
        assert_eq!(params.len(), model.manifest().params.len());
    }

    /// A wrapper that delegates the required methods but keeps every trait
    /// default (`infer`, `decode_begin/step/end`), so the pad-and-slice and
    /// recompute-decode fallbacks themselves are covered.
    struct PadOnly(Box<dyn Backend>);
    impl Backend for PadOnly {
        fn manifest(&self) -> &Manifest {
            self.0.manifest()
        }
        fn step(&self) -> u64 {
            self.0.step()
        }
        fn set_step(&mut self, step: u64) {
            self.0.set_step(step)
        }
        fn reinit(&mut self, seed: i32) -> Result<()> {
            self.0.reinit(seed)
        }
        fn train_step(&mut self, batch: &[Tensor]) -> Result<f32> {
            self.0.train_step(batch)
        }
        fn forward(&self, inputs: &[Tensor]) -> Result<Tensor> {
            self.0.forward(inputs)
        }
        fn dump_filters(&self) -> Result<Tensor> {
            self.0.dump_filters()
        }
        fn params_host(&self) -> Result<Vec<Tensor>> {
            self.0.params_host()
        }
        fn set_params(&mut self, tensors: &[Tensor]) -> Result<()> {
            self.0.set_params(tensors)
        }
    }

    #[test]
    fn default_infer_pads_to_the_compiled_shape() {
        let dir = PathBuf::from("artifacts/golden_tiny");
        let native = load(BackendKind::Native, &dir, 0).unwrap();
        let fallback = PadOnly(load(BackendKind::Native, &dir, 0).unwrap());
        assert_eq!(fallback.serve_buckets(), vec![16]);
        assert!(fallback.mem_report().is_none());

        let (l, v) = (5usize, 32usize);
        let tokens: Vec<i32> = (0..l as i32).map(|i| i + 1).collect();
        let got = fallback.infer(&tokens, 1, l).unwrap();
        assert_eq!(got.shape(), &[1, l, v]);
        // The fallback must equal the full-pad forward's prefix exactly
        // (same engine, same full-length plan underneath).
        let mut padded = tokens.clone();
        padded.resize(16, 0);
        let mut full_batch = vec![0i32; 2 * 16];
        full_batch[..16].copy_from_slice(&padded);
        let full = native
            .forward(&[Tensor::from_i32(&[2, 16], full_batch).unwrap()])
            .unwrap();
        assert_eq!(got.as_f32().unwrap(), &full.as_f32().unwrap()[..l * v]);
        // Out-of-range lengths are rejected.
        assert!(fallback.infer(&tokens, 1, 0).is_err());
        assert!(fallback.infer(&tokens, 1, 99).is_err());
    }

    #[test]
    fn default_decode_session_recomputes_via_infer() {
        // The trait-default decode session must reproduce, step by step,
        // what re-running the growing prefix through `infer` yields — the
        // contract that keeps recompute engines (pjrt) correct unchanged.
        let dir = PathBuf::from("artifacts/golden_tiny");
        let fallback = PadOnly(load(BackendKind::Native, &dir, 0).unwrap());
        let v = fallback.manifest().vocab().unwrap();
        let prompt = vec![1i32, 2, 3];

        let mut logits = Vec::new();
        let mut sess = fallback.decode_begin(&prompt, &mut logits).unwrap();
        assert_eq!(sess.tokens(), &prompt[..]);
        assert_eq!(sess.len(), 3);
        assert_eq!(logits.len(), v);
        let mut seq = prompt.clone();
        for step in 0..4 {
            let tok = crate::coordinator::generation::argmax(&logits);
            seq.push(tok);
            fallback.decode_step(&mut sess, tok, &mut logits).unwrap();
            assert_eq!(sess.tokens(), &seq[..], "session tokens diverged at step {step}");
            let want = fallback.infer(&seq, 1, seq.len()).unwrap();
            let wf = want.as_f32().unwrap();
            assert_eq!(
                &logits[..],
                &wf[(seq.len() - 1) * v..seq.len() * v],
                "recompute-default step {step} diverged from infer"
            );
        }
        assert_eq!(sess.steps(), 4);
        fallback.decode_end(sess);

        // Bounds: empty / over-long prompts are rejected, and a session at
        // the window edge refuses further steps.
        assert!(fallback.decode_begin(&[], &mut logits).is_err());
        assert!(fallback.decode_begin(&[0; 16], &mut logits).is_err());
        let mut edge = fallback.decode_begin(&[1; 15], &mut logits).unwrap();
        fallback.decode_step(&mut edge, 2, &mut logits).unwrap();
        assert!(fallback.decode_step(&mut edge, 2, &mut logits).is_err());
        fallback.decode_end(edge);
    }

    #[test]
    fn default_decode_step_batch_is_the_serial_loop() {
        // The trait-default batched round must behave exactly like looping
        // decode_step row by row — same logits, same token histories, and
        // per-row errors (window edge) that leave the other rows fine.
        let dir = PathBuf::from("artifacts/golden_tiny");
        let fallback = PadOnly(load(BackendKind::Native, &dir, 0).unwrap());
        let v = fallback.manifest().vocab().unwrap();
        let mut lg = Vec::new();
        let mut a = fallback.decode_begin(&[1, 2, 3], &mut lg).unwrap();
        let mut b = fallback.decode_begin(&[4, 5], &mut lg).unwrap();
        // Serial reference on identical twin sessions.
        let mut ra = fallback.decode_begin(&[1, 2, 3], &mut lg).unwrap();
        let mut rb = fallback.decode_begin(&[4, 5], &mut lg).unwrap();
        let mut packed = Vec::new();
        for round in 0..3 {
            let toks = [round as i32 + 6, round as i32 + 9];
            let mut want = Vec::new();
            fallback.decode_step(&mut ra, toks[0], &mut lg).unwrap();
            want.extend_from_slice(&lg);
            fallback.decode_step(&mut rb, toks[1], &mut lg).unwrap();
            want.extend_from_slice(&lg);
            let results = {
                let mut sessions = [&mut a, &mut b];
                fallback.decode_step_batch(&mut sessions, &toks, &mut packed)
            };
            assert_eq!(results.len(), 2);
            assert!(results.iter().all(Result::is_ok));
            assert_eq!(packed.len(), 2 * v);
            assert_eq!(packed, want, "default batch diverged at round {round}");
        }
        assert_eq!(a.tokens(), ra.tokens());
        assert_eq!(b.tokens(), rb.tokens());
        // Per-row failure: run one session to the window edge; its row
        // errors, the other still steps.
        let mut edge = fallback.decode_begin(&[1; 15], &mut lg).unwrap();
        fallback.decode_step(&mut edge, 2, &mut lg).unwrap(); // length 16 = L
        let results = {
            let mut sessions = [&mut edge, &mut a];
            fallback.decode_step_batch(&mut sessions, &[1, 2], &mut packed)
        };
        assert!(results[0].is_err(), "window-edge row should fail");
        assert!(results[1].is_ok(), "healthy row should step");
        assert!(packed[..v].iter().all(|&x| x == 0.0), "failed row logits not zeroed");
        fallback.decode_end(edge);
        for s in [a, b, ra, rb] {
            fallback.decode_end(s);
        }
    }

    #[test]
    fn reinit_changes_parameters_deterministically() {
        let dir = PathBuf::from("artifacts/native_micro");
        let mut a = load(BackendKind::Native, &dir, 0).unwrap();
        let b = load(BackendKind::Native, &dir, 1).unwrap();
        let flat = |m: &dyn Backend| -> Vec<f32> {
            m.params_host()
                .unwrap()
                .iter()
                .flat_map(|t| t.as_f32().unwrap().to_vec())
                .collect()
        };
        assert_ne!(flat(a.as_ref()), flat(b.as_ref()));
        a.reinit(1).unwrap();
        assert_eq!(flat(a.as_ref()), flat(b.as_ref()));
    }
}
