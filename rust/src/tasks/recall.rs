//! Associative recall (paper Sec. 4.1, App. A.1).
//!
//! Each sequence concatenates key→value pairs drawn from a *per-sequence*
//! random dictionary, ends with a query key that appeared earlier, and the
//! model must emit that key's value. On long sequences, pairs repeat
//! (App. A.1: with vocab 40 and 100k tokens multiple copies are inevitable);
//! the dictionary is consistent within a sequence so repeats reinforce.
//!
//! Token layout: ids `0..effective_vocab` are data tokens; the key/value
//! split is by parity of draw, not id range, matching the paper's setup
//! where keys and values share a vocabulary.

use crate::tasks::TaskBatch;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct RecallTask {
    pub seqlen: usize,
    /// Effective vocabulary (≤ the model's embedding slots).
    pub vocab: usize,
    pub batch: usize,
}

impl RecallTask {
    pub fn new(seqlen: usize, vocab: usize, batch: usize) -> Self {
        assert!(vocab >= 4, "recall needs ≥4 tokens");
        assert!(seqlen >= 4);
        RecallTask { seqlen, vocab, batch }
    }

    /// Generate one sequence: returns (tokens, answer).
    pub fn sample_seq(&self, rng: &mut Pcg) -> (Vec<i32>, i32) {
        let n_keys = (self.vocab / 2).max(1);
        // Per-sequence dictionary: key k → value dict[k].
        let dict: Vec<i32> = (0..n_keys)
            .map(|_| (n_keys + rng.usize_below(self.vocab - n_keys)) as i32)
            .collect();
        let pairs = (self.seqlen - 1) / 2;
        let mut toks = Vec::with_capacity(self.seqlen);
        let mut appeared: Vec<usize> = Vec::new();
        for _ in 0..pairs {
            let k = rng.usize_below(n_keys);
            appeared.push(k);
            toks.push(k as i32);
            toks.push(dict[k]);
        }
        // Query one key that appeared; its value is the answer.
        let q = appeared[rng.usize_below(appeared.len())];
        // Pad (with fresh pairs re-using the dict) so the query lands at the
        // final position.
        while toks.len() < self.seqlen - 1 {
            toks.push(0);
        }
        toks.truncate(self.seqlen - 1);
        toks.push(q as i32);
        (toks, dict[q])
    }

    /// Batch in train_step layout: mask is 1 only at the final position.
    pub fn sample_batch(&self, rng: &mut Pcg) -> TaskBatch {
        let (b, l) = (self.batch, self.seqlen);
        let mut tokens = Vec::with_capacity(b * l);
        let mut targets = vec![0i32; b * l];
        let mut mask = vec![0.0f32; b * l];
        for r in 0..b {
            let (toks, ans) = self.sample_seq(rng);
            tokens.extend_from_slice(&toks);
            targets[r * l + l - 1] = ans;
            mask[r * l + l - 1] = 1.0;
        }
        TaskBatch { tokens, targets, mask, batch: b, seqlen: l }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn answer_is_recoverable_from_context() {
        // The value for the query key must appear right after some earlier
        // occurrence of the key — i.e. the task is solvable from context.
        Prop::new("recall solvable").cases(200).check(|rng| {
            let vocab = 8 + rng.usize_below(32);
            let seqlen = 16 + 2 * rng.usize_below(64);
            let task = RecallTask::new(seqlen, vocab, 1);
            let (toks, ans) = task.sample_seq(rng);
            let q = *toks.last().unwrap();
            let mut found = false;
            for i in 0..toks.len() - 2 {
                if toks[i] == q && toks[i + 1] == ans {
                    found = true;
                    break;
                }
            }
            prop_assert!(found, "query {q} -> {ans} not in context {toks:?}");
            Ok(())
        });
    }

    #[test]
    fn dictionary_is_consistent_within_sequence() {
        // A key never maps to two different values in one sequence.
        Prop::new("recall consistent dict").cases(100).check(|rng| {
            let task = RecallTask::new(64, 20, 1);
            let (toks, _) = task.sample_seq(rng);
            let n_keys = 10;
            let mut seen = vec![None; n_keys];
            let mut i = 0;
            while i + 1 < toks.len() - 1 {
                let (k, v) = (toks[i] as usize, toks[i + 1]);
                if k < n_keys && v != 0 {
                    match seen[k] {
                        None => seen[k] = Some(v),
                        Some(prev) => prop_assert!(prev == v, "key {k}: {prev} vs {v}"),
                    }
                }
                i += 2;
            }
            Ok(())
        });
    }

    #[test]
    fn batch_layout() {
        let task = RecallTask::new(32, 10, 4);
        let mut rng = Pcg::new(0);
        let b = task.sample_batch(&mut rng);
        assert_eq!(b.tokens.len(), 4 * 32);
        assert_eq!(b.mask.iter().filter(|&&m| m > 0.0).count(), 4);
        // mask set exactly at the last position of each row
        for r in 0..4 {
            assert_eq!(b.mask[r * 32 + 31], 1.0);
        }
    }

    #[test]
    fn tokens_within_vocab() {
        let task = RecallTask::new(64, 30, 2);
        let mut rng = Pcg::new(1);
        let b = task.sample_batch(&mut rng);
        assert!(b.tokens.iter().all(|&t| (0..30).contains(&t)));
        assert!(b.targets.iter().all(|&t| (0..30).contains(&t)));
    }
}
