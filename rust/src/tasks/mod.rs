//! Synthetic mechanistic-design benchmarks (paper Tab. 4.1, App. A.1):
//! associative recall, majority, counting, ICL of (modular) linear
//! functions, and multi-digit arithmetic.

pub mod arithmetic;
pub mod counting;
pub mod icl;
pub mod majority;
pub mod recall;

use crate::runtime::Tensor;

/// A generated batch in the LM train_step layout.
#[derive(Debug, Clone)]
pub struct TaskBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seqlen: usize,
}

impl TaskBatch {
    /// Convert to the `[tokens, targets, mask]` tensor triple.
    pub fn to_tensors(&self) -> Vec<Tensor> {
        let shape = [self.batch, self.seqlen];
        vec![
            Tensor::from_i32(&shape, self.tokens.clone()).unwrap(),
            Tensor::from_i32(&shape, self.targets.clone()).unwrap(),
            Tensor::from_f32(&shape, self.mask.clone()).unwrap(),
        ]
    }
}
