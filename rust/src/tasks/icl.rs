//! In-context learning of (modular) linear functions (paper Tab. 4.1).
//!
//! The paper's version uses real-valued x, w·x pairs. Our models are
//! token-based, so we use the standard discrete analog: per sequence sample
//! a secret multiplier w; the prompt is x₁, w·x₁ mod p, …, xₙ and the target
//! is w·xₙ mod p. Solving it requires inferring w from the in-context pairs
//! — the same data-controlled mechanism the real-valued version probes
//! (documented substitution, DESIGN.md §3).

use crate::tasks::TaskBatch;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct IclTask {
    pub seqlen: usize,
    /// Modulus p (must be ≤ vocab and prime for invertibility; 31 default).
    pub modulus: usize,
    pub batch: usize,
}

impl IclTask {
    pub fn new(seqlen: usize, modulus: usize, batch: usize) -> Self {
        assert!(seqlen >= 4 && modulus >= 5);
        IclTask { seqlen, modulus, batch }
    }

    pub fn sample_seq(&self, rng: &mut Pcg) -> (Vec<i32>, i32) {
        let p = self.modulus;
        let w = 1 + rng.usize_below(p - 1); // non-zero multiplier
        let pairs = (self.seqlen - 1) / 2;
        let mut toks = Vec::with_capacity(self.seqlen);
        for _ in 0..pairs {
            let x = rng.usize_below(p);
            toks.push(x as i32);
            toks.push(((w * x) % p) as i32);
        }
        while toks.len() < self.seqlen - 1 {
            toks.push(0);
        }
        toks.truncate(self.seqlen - 1);
        let xq = 1 + rng.usize_below(p - 1);
        toks.push(xq as i32);
        (toks, ((w * xq) % p) as i32)
    }

    pub fn sample_batch(&self, rng: &mut Pcg) -> TaskBatch {
        let (b, l) = (self.batch, self.seqlen);
        let mut tokens = Vec::with_capacity(b * l);
        let mut targets = vec![0i32; b * l];
        let mut mask = vec![0.0f32; b * l];
        for r in 0..b {
            let (toks, ans) = self.sample_seq(rng);
            tokens.extend_from_slice(&toks);
            targets[r * l + l - 1] = ans;
            mask[r * l + l - 1] = 1.0;
        }
        TaskBatch { tokens, targets, mask, batch: b, seqlen: l }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn function_is_consistent_and_answer_correct() {
        Prop::new("icl consistent w").cases(200).check(|rng| {
            let task = IclTask::new(16 + 2 * rng.usize_below(32), 31, 1);
            let (toks, ans) = task.sample_seq(rng);
            // Recover w from the first pair with x != 0 and verify all pairs.
            let p = 31i64;
            let mut w: Option<i64> = None;
            let mut i = 0;
            while i + 1 < toks.len() - 1 {
                let (x, y) = (toks[i] as i64, toks[i + 1] as i64);
                if x != 0 && y != 0 {
                    // w = y * x^{-1} mod p
                    let xinv = mod_inv(x, p);
                    let cand = (y * xinv) % p;
                    match w {
                        None => w = Some(cand),
                        Some(prev) => prop_assert!(prev == cand, "inconsistent w"),
                    }
                }
                i += 2;
            }
            if let Some(w) = w {
                let xq = *toks.last().unwrap() as i64;
                prop_assert!((w * xq) % p == ans as i64, "bad answer");
            }
            Ok(())
        });
    }

    fn mod_inv(a: i64, p: i64) -> i64 {
        // Fermat: a^(p-2) mod p
        let mut result = 1i64;
        let mut base = a % p;
        let mut e = p - 2;
        while e > 0 {
            if e & 1 == 1 {
                result = result * base % p;
            }
            base = base * base % p;
            e >>= 1;
        }
        result
    }
}
