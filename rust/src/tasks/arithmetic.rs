//! Multi-digit addition (paper App. C.1, Fig. C.1).
//!
//! A D-digit sample is the token sequence ``a₁…a_D + b₁…b_D = s₁…s_{D+1}``
//! trained autoregressively; the loss mask covers only the result digits
//! (the paper masks the first 2D−1 prediction positions).
//!
//! Vocabulary: 0–9 digits, 10 = '+', 11 = '=', 12 = pad, 13 = bos.

use crate::tasks::TaskBatch;
use crate::util::rng::Pcg;

pub const PLUS: i32 = 10;
pub const EQUALS: i32 = 11;
pub const PAD: i32 = 12;
pub const BOS: i32 = 13;

#[derive(Debug, Clone)]
pub struct ArithmeticTask {
    pub digits: usize,
    pub seqlen: usize,
    pub batch: usize,
}

impl ArithmeticTask {
    pub fn new(digits: usize, seqlen: usize, batch: usize) -> Self {
        // bos + D + 1 + D + 1 + (D+1) tokens must fit
        assert!(seqlen >= 3 * digits + 4, "seqlen too short for {digits}-digit");
        ArithmeticTask { digits, seqlen, batch }
    }

    fn digits_of(mut n: u64, width: usize) -> Vec<i32> {
        let mut out = vec![0i32; width];
        for i in (0..width).rev() {
            out[i] = (n % 10) as i32;
            n /= 10;
        }
        out
    }

    /// One sample: (tokens, targets, mask) of length seqlen.
    pub fn sample_seq(&self, rng: &mut Pcg) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let d = self.digits as u32;
        let hi = 10u64.pow(d);
        let a = rng.next_u64() % hi;
        let b = rng.next_u64() % hi;
        let s = a + b;

        let mut seq = vec![BOS];
        seq.extend(Self::digits_of(a, self.digits));
        seq.push(PLUS);
        seq.extend(Self::digits_of(b, self.digits));
        seq.push(EQUALS);
        let result_start = seq.len(); // first result digit position in seq
        seq.extend(Self::digits_of(s, self.digits + 1));
        while seq.len() < self.seqlen + 1 {
            seq.push(PAD);
        }
        seq.truncate(self.seqlen + 1);

        // Autoregressive shift: input = seq[..L], target = seq[1..L+1].
        let tokens = seq[..self.seqlen].to_vec();
        let targets = seq[1..].to_vec();
        let mut mask = vec![0.0f32; self.seqlen];
        // Positions predicting the result digits: result_start-1 .. result_end-1.
        for pos in (result_start - 1)..(result_start + self.digits) {
            mask[pos] = 1.0;
        }
        (tokens, targets, mask)
    }

    pub fn sample_batch(&self, rng: &mut Pcg) -> TaskBatch {
        let (b, l) = (self.batch, self.seqlen);
        let mut tokens = Vec::with_capacity(b * l);
        let mut targets = Vec::with_capacity(b * l);
        let mut mask = Vec::with_capacity(b * l);
        for _ in 0..b {
            let (t, g, m) = self.sample_seq(rng);
            tokens.extend(t);
            targets.extend(g);
            mask.extend(m);
        }
        TaskBatch { tokens, targets, mask, batch: b, seqlen: l }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn sum_encoded_correctly() {
        Prop::new("arith sum digits").cases(200).check(|rng| {
            let d = 1 + rng.usize_below(4);
            let task = ArithmeticTask::new(d, 3 * d + 5, 1);
            let (tokens, targets, mask) = task.sample_seq(rng);
            // Decode a and b from the token stream.
            prop_assert!(tokens[0] == BOS, "no bos");
            let a: u64 = tokens[1..1 + d].iter().fold(0, |acc, &t| acc * 10 + t as u64);
            prop_assert!(tokens[1 + d] == PLUS, "no plus");
            let b: u64 = tokens[2 + d..2 + 2 * d]
                .iter()
                .fold(0, |acc, &t| acc * 10 + t as u64);
            prop_assert!(tokens[2 + 2 * d] == EQUALS, "no equals");
            // Result digits appear where mask predicts them: targets at the
            // masked positions spell a+b.
            let masked: Vec<i32> = mask
                .iter()
                .enumerate()
                .filter(|(_, &m)| m > 0.0)
                .map(|(i, _)| targets[i])
                .collect();
            prop_assert!(masked.len() == d + 1, "mask width {}", masked.len());
            let s: u64 = masked.iter().fold(0, |acc, &t| acc * 10 + t as u64);
            prop_assert!(s == a + b, "{a}+{b} != {s}");
            Ok(())
        });
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let task = ArithmeticTask::new(3, 16, 1);
        let mut rng = Pcg::new(0);
        let (tokens, targets, _) = task.sample_seq(&mut rng);
        // target[i] is the next input token wherever both are in range
        for i in 0..tokens.len() - 1 {
            assert_eq!(targets[i], tokens[i + 1]);
        }
    }

    #[test]
    #[should_panic(expected = "seqlen too short")]
    fn rejects_short_seqlen() {
        ArithmeticTask::new(4, 10, 1);
    }
}
