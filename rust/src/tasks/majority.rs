//! Majority voting (paper Tab. 4.1): the model must emit the most frequent
//! token of the sequence — a *densely* activated data-controlled matrix.

use crate::tasks::TaskBatch;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct MajorityTask {
    pub seqlen: usize,
    pub vocab: usize,
    pub batch: usize,
}

impl MajorityTask {
    pub fn new(seqlen: usize, vocab: usize, batch: usize) -> Self {
        assert!(vocab >= 3 && seqlen >= 4);
        MajorityTask { seqlen, vocab, batch }
    }

    /// One sequence: tokens biased toward a designated majority symbol so
    /// the answer is unique w.h.p.; we verify and fix uniqueness explicitly.
    pub fn sample_seq(&self, rng: &mut Pcg) -> (Vec<i32>, i32) {
        let body = self.seqlen - 1;
        let maj = rng.usize_below(self.vocab) as i32;
        let mut toks: Vec<i32> = (0..body)
            .map(|_| {
                if rng.f32() < 0.35 {
                    maj
                } else {
                    rng.usize_below(self.vocab) as i32
                }
            })
            .collect();
        // Recount and take the true mode (deterministic tie-break: smallest id),
        // then break ties by overwriting one position with the mode.
        let mut counts = vec![0usize; self.vocab];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        let best = counts.iter().enumerate().max_by_key(|(i, &c)| (c, self.vocab - i)).unwrap();
        let (mode, c0) = (best.0 as i32, *best.1);
        let runner_up = counts
            .iter()
            .enumerate()
            .filter(|(i, _)| *i as i32 != mode)
            .map(|(_, &c)| c)
            .max()
            .unwrap_or(0);
        if runner_up == c0 {
            // Force strict majority by flipping one non-mode token.
            if let Some(slot) = toks.iter().position(|&t| t != mode) {
                toks[slot] = mode;
            }
        }
        toks.push(0); // query marker position (token 0 acts as the cue)
        (toks, mode)
    }

    pub fn sample_batch(&self, rng: &mut Pcg) -> TaskBatch {
        let (b, l) = (self.batch, self.seqlen);
        let mut tokens = Vec::with_capacity(b * l);
        let mut targets = vec![0i32; b * l];
        let mut mask = vec![0.0f32; b * l];
        for r in 0..b {
            let (toks, ans) = self.sample_seq(rng);
            tokens.extend_from_slice(&toks);
            targets[r * l + l - 1] = ans;
            mask[r * l + l - 1] = 1.0;
        }
        TaskBatch { tokens, targets, mask, batch: b, seqlen: l }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn answer_is_strict_mode() {
        Prop::new("majority strict mode").cases(200).check(|rng| {
            let task = MajorityTask::new(32 + rng.usize_below(64), 3 + rng.usize_below(20), 1);
            let (toks, ans) = task.sample_seq(rng);
            let mut counts = std::collections::HashMap::new();
            for &t in &toks[..toks.len() - 1] {
                *counts.entry(t).or_insert(0usize) += 1;
            }
            let ans_count = counts[&ans];
            for (&t, &c) in &counts {
                if t != ans {
                    prop_assert!(c <= ans_count, "token {t} count {c} > mode {ans_count}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_mask_single_position() {
        let task = MajorityTask::new(16, 5, 3);
        let mut rng = Pcg::new(0);
        let b = task.sample_batch(&mut rng);
        assert_eq!(b.mask.iter().filter(|&&m| m > 0.0).count(), 3);
    }
}
