//! Counting (paper Tab. 4.1): emit the number of occurrences of a marker
//! token, capped at vocab−1 so the answer stays in-vocabulary.

use crate::tasks::TaskBatch;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct CountingTask {
    pub seqlen: usize,
    pub vocab: usize,
    pub batch: usize,
    /// The token to count (id 1; id 0 is the query cue).
    pub marker: i32,
}

impl CountingTask {
    pub fn new(seqlen: usize, vocab: usize, batch: usize) -> Self {
        assert!(vocab >= 4 && seqlen >= 4);
        CountingTask { seqlen, vocab, batch, marker: 1 }
    }

    pub fn sample_seq(&self, rng: &mut Pcg) -> (Vec<i32>, i32) {
        let body = self.seqlen - 1;
        let cap = (self.vocab - 1) as i32;
        // Choose a target count ≤ cap uniformly, then place that many markers.
        let want = rng.usize_below((cap as usize).min(body) + 1);
        let mut toks: Vec<i32> = (0..body)
            .map(|_| {
                // fill with non-marker tokens (≥ 2)
                let t = 2 + rng.usize_below(self.vocab - 2);
                t as i32
            })
            .collect();
        let mut slots: Vec<usize> = (0..body).collect();
        rng.shuffle(&mut slots);
        for &s in slots.iter().take(want) {
            toks[s] = self.marker;
        }
        toks.push(0); // query cue
        (toks, want as i32)
    }

    pub fn sample_batch(&self, rng: &mut Pcg) -> TaskBatch {
        let (b, l) = (self.batch, self.seqlen);
        let mut tokens = Vec::with_capacity(b * l);
        let mut targets = vec![0i32; b * l];
        let mut mask = vec![0.0f32; b * l];
        for r in 0..b {
            let (toks, ans) = self.sample_seq(rng);
            tokens.extend_from_slice(&toks);
            targets[r * l + l - 1] = ans;
            mask[r * l + l - 1] = 1.0;
        }
        TaskBatch { tokens, targets, mask, batch: b, seqlen: l }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn answer_equals_marker_count() {
        Prop::new("counting correct").cases(200).check(|rng| {
            let task = CountingTask::new(16 + rng.usize_below(64), 8 + rng.usize_below(24), 1);
            let (toks, ans) = task.sample_seq(rng);
            let count = toks[..toks.len() - 1].iter().filter(|&&t| t == 1).count();
            prop_assert!(count as i32 == ans, "count {count} != ans {ans}");
            prop_assert!(ans < task.vocab as i32, "answer out of vocab");
            Ok(())
        });
    }
}
