//! Training loop orchestrator: drives a [`Backend`]'s train step over a
//! batch source and tracks losses/throughput. Backend-agnostic — the same
//! loop trains PJRT artifacts and native models. Native train steps run
//! their row-parallel loops on the shared process pool (`util::pool`,
//! `--threads` / `HYENA_THREADS`), so the loop itself stays single-threaded.

use std::time::Instant;

use anyhow::Result;

use crate::backend::{Backend, MemReport};
use crate::metrics::perplexity;
use crate::runtime::Tensor;

/// Anything that can produce training batches (tasks, corpus, images).
pub trait BatchSource {
    /// Next batch in the model's train_step layout.
    fn next_batch(&mut self) -> Vec<Tensor>;
}

impl<F: FnMut() -> Vec<Tensor>> BatchSource for F {
    fn next_batch(&mut self) -> Vec<Tensor> {
        self()
    }
}

/// One recorded point on the loss curve.
#[derive(Debug, Clone)]
pub struct LogPoint {
    pub step: u64,
    pub loss: f32,
    pub ppl: f32,
    pub tokens_seen: u64,
    pub elapsed_s: f64,
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub curve: Vec<LogPoint>,
    pub final_loss: f32,
    pub steps: u64,
    pub tokens_seen: u64,
    pub wall_s: f64,
    pub steps_per_s: f64,
    pub tokens_per_s: f64,
    /// From the manifest's App. A.2 accounting: total training FLOPs.
    pub total_flops: Option<f64>,
    /// Arena/workspace high-water accounting (backends that track it), so
    /// memory regressions surface in the train report alongside throughput.
    pub mem: Option<MemReport>,
}

pub struct Trainer<'a, S: BatchSource> {
    pub model: &'a mut dyn Backend,
    pub source: S,
    pub log_every: u64,
    /// Exponential moving average window for reported losses.
    pub ema: f32,
    pub quiet: bool,
}

impl<'a, S: BatchSource> Trainer<'a, S> {
    pub fn new(model: &'a mut dyn Backend, source: S) -> Self {
        Trainer { model, source, log_every: 50, ema: 0.9, quiet: false }
    }

    /// Run `steps` optimizer steps; returns the loss curve and throughput.
    pub fn run(&mut self, steps: u64) -> Result<TrainReport> {
        let tokens_per_batch = (self.model.manifest().batch()?
            * self.model.manifest().seqlen().unwrap_or(1)) as u64;
        let flops_per_step = self.model.manifest().flops_per_step;
        let t0 = Instant::now();
        let mut curve = Vec::new();
        let mut ema_loss: Option<f32> = None;
        let mut last = 0.0f32;
        for i in 0..steps {
            let batch = self.source.next_batch();
            let loss = self.model.train_step(&batch)?;
            last = loss;
            ema_loss = Some(match ema_loss {
                None => loss,
                Some(e) => self.ema * e + (1.0 - self.ema) * loss,
            });
            if i % self.log_every == 0 || i + 1 == steps {
                let point = LogPoint {
                    step: self.model.step(),
                    loss: ema_loss.unwrap(),
                    ppl: perplexity(ema_loss.unwrap()),
                    tokens_seen: self.model.step() * tokens_per_batch,
                    elapsed_s: t0.elapsed().as_secs_f64(),
                };
                if !self.quiet {
                    println!(
                        "  step {:>6}  loss {:.4}  ppl {:>8.2}  tok {:>9}  {:.1}s",
                        point.step, point.loss, point.ppl, point.tokens_seen, point.elapsed_s
                    );
                }
                curve.push(point);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            final_loss: ema_loss.unwrap_or(last),
            steps,
            tokens_seen: steps * tokens_per_batch,
            wall_s: wall,
            steps_per_s: steps as f64 / wall.max(1e-9),
            tokens_per_s: (steps * tokens_per_batch) as f64 / wall.max(1e-9),
            total_flops: flops_per_step.map(|f| f * steps as f64),
            mem: self.model.mem_report(),
            curve,
        })
    }
}

/// Evaluate masked next-token accuracy of `model` on batches from `source`:
/// fraction of positions with mask > 0 where argmax(logits) == target.
/// This is the metric for all synthetic-task tables (Fig 4.1, Tab 4.2, ...).
pub fn eval_accuracy<S: BatchSource>(
    model: &dyn Backend,
    source: &mut S,
    batches: usize,
) -> Result<f64> {
    let mut correct = 0u64;
    let mut total = 0u64;
    for _ in 0..batches {
        let batch = source.next_batch();
        let (tokens, targets, mask) = (&batch[0], &batch[1], &batch[2]);
        let logits = model.forward(std::slice::from_ref(tokens))?;
        let v = *logits.shape().last().unwrap();
        let l = logits.shape()[1];
        let lf = logits.as_f32()?;
        let tg = targets.as_i32()?;
        let mk = mask.as_f32()?;
        for (pos, (&t, &m)) in tg.iter().zip(mk.iter()).enumerate() {
            if m <= 0.0 {
                continue;
            }
            let row = &lf[pos * v..(pos + 1) * v];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i as i32)
                .unwrap();
            total += 1;
            if argmax == t.clamp(0, v as i32 - 1) {
                correct += 1;
            }
        }
        debug_assert_eq!(lf.len() % (l * v), 0);
    }
    Ok(if total == 0 { 0.0 } else { correct as f64 / total as f64 })
}

/// Evaluate mean masked cross-entropy (→ perplexity) on held-out batches.
pub fn eval_loss<S: BatchSource>(
    model: &dyn Backend,
    source: &mut S,
    batches: usize,
) -> Result<f64> {
    let mut total_nll = 0.0f64;
    let mut total_cnt = 0.0f64;
    for _ in 0..batches {
        let batch = source.next_batch();
        let (tokens, targets, mask) = (&batch[0], &batch[1], &batch[2]);
        let logits = model.forward(std::slice::from_ref(tokens))?;
        let v = *logits.shape().last().unwrap();
        let lf = logits.as_f32()?;
        let tg = targets.as_i32()?;
        let mk = mask.as_f32()?;
        for (pos, (&t, &m)) in tg.iter().zip(mk.iter()).enumerate() {
            if m <= 0.0 {
                continue;
            }
            let row = &lf[pos * v..(pos + 1) * v];
            // log-softmax at the target index, numerically stable
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = mx + row.iter().map(|x| (x - mx).exp()).sum::<f32>().ln();
            let ti = (t.max(0) as usize).min(v - 1);
            total_nll += (lse - row[ti]) as f64;
            total_cnt += 1.0;
        }
    }
    Ok(total_nll / total_cnt.max(1.0))
}
