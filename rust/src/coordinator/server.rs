//! Threaded inference server with dynamic batching (serving-path L3).
//!
//! XLA handles are `!Send` (and backends in general need not be), so the
//! worker thread *constructs* its own [`Backend`] from the artifact path;
//! clients and worker exchange plain host data (`Vec<i32>` token ids) over
//! mpsc channels. The worker drains the queue through the `Batcher` policy
//! (full-batch or deadline) and decodes the whole batch together —
//! request-level continuous batching (iteration-level rebatching has no
//! payoff without a KV cache; the paper defers fast autoregressive
//! inference to future work).
//!
//! **Shape-bucketed routing.** Each request is keyed by the smallest plan
//! bucket (`Backend::serve_buckets`) covering its terminal length
//! (`prompt + max_new`), and a released batch contains only requests of the
//! oldest request's bucket. Decoding then runs through `Backend::infer` at
//! the live frontier length, so short prompts are served at a fraction of
//! the full-window FLOPs instead of being padded to the compiled L
//! (DESIGN.md §Serving). The response reports the routed bucket
//! (`bucket_len`) so callers — and `scripts/check.sh serve-smoke` — can
//! detect a full-pad fallback.
//!
//! The worker's native backend captures the process-wide worker pool
//! (`util::pool`) at construction, so the server's forward passes and any
//! concurrent training steps share one set of compute threads instead of
//! oversubscribing the machine (`--threads` / `HYENA_THREADS`).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{self, Backend, BackendKind, MemReport};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::generation::{decode_batch, Sampling};
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampling: Sampling,
}

#[derive(Debug)]
pub struct GenerateResponse {
    pub tokens: Vec<i32>,
    /// Time spent queued before entering a batch.
    pub queue_time: Duration,
    /// Wall time from submission to completion.
    pub total_time: Duration,
    /// How many requests shared the batch (observability).
    pub batch_occupancy: usize,
    /// Plan bucket the request was routed to (== compiled seqlen when the
    /// engine has no shape buckets — the full-pad fallback).
    pub bucket_len: usize,
}

struct Envelope {
    req: GenerateRequest,
    submitted: Instant,
    reply: Sender<Result<GenerateResponse>>,
}

/// Worker-bound messages: generation work or a serving-stats probe.
enum Msg {
    Gen(Envelope),
    Mem(Sender<Option<MemReport>>),
}

/// Handle used by clients to submit requests (cloneable, Send).
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
}

impl ServerHandle {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GenerateRequest) -> Receiver<Result<GenerateResponse>> {
        let (reply_tx, reply_rx) = channel();
        let env = Envelope { req, submitted: Instant::now(), reply: reply_tx };
        // If the worker is gone the reply channel closes and the caller
        // observes a RecvError.
        let _ = self.tx.send(Msg::Gen(env));
        reply_rx
    }

    /// Convenience blocking call.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server worker terminated"))?
    }

    /// Snapshot of the worker backend's arena/workspace accounting (the
    /// serve report; `None` when the engine does not track it).
    pub fn mem_report(&self) -> Option<MemReport> {
        let (tx, rx) = channel();
        if self.tx.send(Msg::Mem(tx)).is_err() {
            return None;
        }
        rx.recv().ok().flatten()
    }
}

pub struct Server {
    pub handle: ServerHandle,
    worker: Option<JoinHandle<()>>,
    shutdown: Sender<()>,
}

impl Server {
    /// Start the worker thread: it constructs its own backend for
    /// `artifact_dir` (backend state never crosses threads) and then serves
    /// until `stop()`. The engine follows [`BackendKind::detect`]
    /// (`HYENA_BACKEND`, else artifact autodetection). Blocks until ready.
    pub fn start(artifact_dir: PathBuf, seed: i32, max_wait: Duration) -> Result<Server> {
        Self::start_with_params(artifact_dir, seed, max_wait, None)
    }

    /// Like [`Server::start`], but install pretrained parameters (host
    /// tensors, manifest order) into the worker's model — the hand-off used
    /// by `examples/lm_pretrain.rs` after training.
    pub fn start_with_params(
        artifact_dir: PathBuf,
        seed: i32,
        max_wait: Duration,
        params: Option<Vec<Tensor>>,
    ) -> Result<Server> {
        let kind = BackendKind::detect(&artifact_dir)?;
        Self::start_kind(kind, artifact_dir, seed, max_wait, params, None)
    }

    /// Start with an explicitly chosen engine (the CLI's `--backend`) and,
    /// optionally, an explicit serving bucket-ladder depth (the CLI's
    /// `--buckets`; `None` keeps the engine default).
    pub fn start_kind(
        kind: BackendKind,
        artifact_dir: PathBuf,
        seed: i32,
        max_wait: Duration,
        params: Option<Vec<Tensor>>,
        buckets: Option<usize>,
    ) -> Result<Server> {
        let (tx, rx) = channel::<Msg>();
        let (sd_tx, sd_rx) = channel::<()>();
        let (ready_tx, ready_rx) = channel::<Result<usize>>();
        let worker = std::thread::Builder::new()
            .name("hyena-server".into())
            .spawn(move || {
                let model = match backend::load(kind, &artifact_dir, seed).and_then(|mut m| {
                    if let Some(p) = params {
                        m.set_params(&p)?;
                    }
                    if let Some(levels) = buckets {
                        m.set_serve_buckets(levels)?;
                    }
                    Ok(m)
                }) {
                    Ok(m) => {
                        let bs = m.manifest().batch().unwrap_or(1);
                        let _ = ready_tx.send(Ok(bs));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let batch_size = model.manifest().batch().unwrap_or(1);
                worker_loop(model, rx, sd_rx, batch_size, max_wait, seed as u64);
            })
            .expect("spawn server worker");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))??;
        Ok(Server { handle: ServerHandle { tx }, worker: Some(worker), shutdown: sd_tx })
    }

    pub fn stop(mut self) {
        let _ = self.shutdown.send(());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Smallest bucket covering a request's terminal length (prompt + budget),
/// clamped into the ladder — requests that will outgrow every bucket route
/// to the largest (the full compiled length).
fn bucket_for(env: &Envelope, buckets: &[usize]) -> usize {
    let terminal = env.req.prompt.len() + env.req.max_new;
    buckets
        .iter()
        .copied()
        .find(|&b| b >= terminal)
        .or_else(|| buckets.last().copied())
        .unwrap_or(terminal)
}

fn worker_loop(
    model: Box<dyn Backend>,
    rx: Receiver<Msg>,
    shutdown: Receiver<()>,
    batch_size: usize,
    max_wait: Duration,
    seed: u64,
) {
    let mut batcher: Batcher<Envelope> = Batcher::new(batch_size, max_wait);
    let mut rng = Pcg::with_stream(seed, 0x5e44);
    // The plan ladder is fixed for the worker's lifetime.
    let buckets = model.serve_buckets();
    let handle = |msg: Msg, batcher: &mut Batcher<Envelope>| match msg {
        Msg::Gen(env) => batcher.push(env),
        Msg::Mem(reply) => {
            let _ = reply.send(model.mem_report());
        }
    };
    loop {
        // Drain everything currently queued on the channel.
        loop {
            match rx.try_recv() {
                Ok(msg) => handle(msg, &mut batcher),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return,
            }
        }
        if shutdown.try_recv().is_ok() {
            return;
        }
        let now = Instant::now();
        if batcher.ready(now) {
            let envs = batcher.take_batch_by_key(|env| bucket_for(env, &buckets));
            serve_batch(model.as_ref(), envs, &buckets, &mut rng);
            continue;
        }
        // Sleep until the oldest deadline or a short poll tick.
        let wait = batcher
            .time_to_deadline(now)
            .unwrap_or(Duration::from_millis(2))
            .min(Duration::from_millis(2))
            .max(Duration::from_micros(200));
        if let Ok(msg) = rx.recv_timeout(wait) {
            handle(msg, &mut batcher);
        }
    }
}

fn serve_batch(model: &dyn Backend, envs: Vec<Envelope>, buckets: &[usize], rng: &mut Pcg) {
    let occupancy = envs.len();
    let entered = Instant::now();
    let bucket_len = envs.first().map(|e| bucket_for(e, buckets)).unwrap_or(0);
    let prompts: Vec<Vec<i32>> = envs.iter().map(|e| e.req.prompt.clone()).collect();
    let max_new: Vec<usize> = envs.iter().map(|e| e.req.max_new).collect();
    // All requests in a batch share one sampling config (first wins); the
    // executed graph is identical either way, this just simplifies the loop.
    let sampling = envs.first().map(|e| e.req.sampling).unwrap_or(Sampling::Greedy);

    match decode_batch(model, &prompts, &max_new, sampling, rng) {
        Ok(outputs) => {
            for (env, tokens) in envs.into_iter().zip(outputs) {
                let resp = GenerateResponse {
                    tokens,
                    queue_time: entered.duration_since(env.submitted),
                    total_time: env.submitted.elapsed(),
                    batch_occupancy: occupancy,
                    bucket_len,
                };
                let _ = env.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for env in envs {
                let _ = env.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
