//! Threaded inference server with resident decode sessions (serving L3).
//!
//! XLA handles are `!Send` (and backends in general need not be), so the
//! worker thread *constructs* its own [`Backend`] from the artifact path;
//! clients and worker exchange plain host data (`Vec<i32>` token ids) over
//! mpsc channels.
//!
//! **Session loop.** The worker keeps up to `batch_size` *live decode
//! sessions* ([`Backend::decode_begin`]): each is prefilled once at its own
//! prompt length (routed through the engine's smallest covering plan
//! bucket) and then advanced one token per round via
//! [`Backend::decode_step`] — on the native engine an O(L) time-domain dot
//! against per-session recurrence state, no prefix recompute (DESIGN.md
//! §Decode). Sessions persist across batching rounds: finished requests
//! retire and reply individually, and freed capacity is refilled from the
//! queue *between token rounds* (iteration-level continuous batching —
//! which now pays off precisely because sessions are stateful). Because
//! sessions are shape-independent, admission is FIFO (`take_up_to`); the
//! `Batcher` release policy (full batch or oldest-deadline) only decides
//! when the worker starts decoding from idle. Each request keeps its own
//! sampling policy.
//!
//! The response reports the prefill bucket (`bucket_len`) so callers — and
//! `scripts/check.sh decode-smoke` — can detect a full-pad prefill, and
//! `Backend::mem_report` exposes session counts / streamed-step counts so
//! `--stream-decode` can verify the engine is actually streaming.
//!
//! The worker's native backend captures the process-wide worker pool
//! (`util::pool`) at construction, so the server's forward passes and any
//! concurrent training steps share one set of compute threads instead of
//! oversubscribing the machine (`--threads` / `HYENA_THREADS`).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{self, Backend, BackendKind, DecodeSession, MemReport};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::generation::{sample_token, Sampling};
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampling: Sampling,
}

#[derive(Debug)]
pub struct GenerateResponse {
    pub tokens: Vec<i32>,
    /// Time spent queued before entering a batch.
    pub queue_time: Duration,
    /// Wall time from submission to completion.
    pub total_time: Duration,
    /// Highest number of co-resident sessions observed while this request
    /// was live (observability).
    pub batch_occupancy: usize,
    /// Plan bucket the request's *prefill* was routed to (== compiled
    /// seqlen when the engine has no shape buckets — the full-pad
    /// fallback). Decode steps after prefill are bucket-free: they run at
    /// a single position from the session state.
    pub bucket_len: usize,
}

struct Envelope {
    req: GenerateRequest,
    submitted: Instant,
    reply: Sender<Result<GenerateResponse>>,
}

/// Worker-bound messages: generation work or a serving-stats probe.
enum Msg {
    Gen(Envelope),
    Mem(Sender<Option<MemReport>>),
}

/// Handle used by clients to submit requests (cloneable, Send).
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
}

impl ServerHandle {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GenerateRequest) -> Receiver<Result<GenerateResponse>> {
        let (reply_tx, reply_rx) = channel();
        let env = Envelope { req, submitted: Instant::now(), reply: reply_tx };
        // If the worker is gone the reply channel closes and the caller
        // observes a RecvError.
        let _ = self.tx.send(Msg::Gen(env));
        reply_rx
    }

    /// Convenience blocking call.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server worker terminated"))?
    }

    /// Snapshot of the worker backend's arena/workspace accounting (the
    /// serve report; `None` when the engine does not track it).
    pub fn mem_report(&self) -> Option<MemReport> {
        let (tx, rx) = channel();
        if self.tx.send(Msg::Mem(tx)).is_err() {
            return None;
        }
        rx.recv().ok().flatten()
    }
}

pub struct Server {
    pub handle: ServerHandle,
    worker: Option<JoinHandle<()>>,
    shutdown: Sender<()>,
}

impl Server {
    /// Start the worker thread: it constructs its own backend for
    /// `artifact_dir` (backend state never crosses threads) and then serves
    /// until `stop()`. The engine follows [`BackendKind::detect`]
    /// (`HYENA_BACKEND`, else artifact autodetection). Blocks until ready.
    pub fn start(artifact_dir: PathBuf, seed: i32, max_wait: Duration) -> Result<Server> {
        Self::start_with_params(artifact_dir, seed, max_wait, None)
    }

    /// Like [`Server::start`], but install pretrained parameters (host
    /// tensors, manifest order) into the worker's model — the hand-off used
    /// by `examples/lm_pretrain.rs` after training.
    pub fn start_with_params(
        artifact_dir: PathBuf,
        seed: i32,
        max_wait: Duration,
        params: Option<Vec<Tensor>>,
    ) -> Result<Server> {
        let kind = BackendKind::detect(&artifact_dir)?;
        Self::start_kind(kind, artifact_dir, seed, max_wait, params, None, None)
    }

    /// Start with an explicitly chosen engine (the CLI's `--backend`) and,
    /// optionally, an explicit serving bucket-ladder depth (the CLI's
    /// `--buckets`; `None` keeps the engine default) and an extended
    /// context window (the CLI's `--max-context`; `None` keeps the
    /// compiled window — engines without chunked prefill reject other
    /// values at startup).
    pub fn start_kind(
        kind: BackendKind,
        artifact_dir: PathBuf,
        seed: i32,
        max_wait: Duration,
        params: Option<Vec<Tensor>>,
        buckets: Option<usize>,
        max_context: Option<usize>,
    ) -> Result<Server> {
        let (tx, rx) = channel::<Msg>();
        let (sd_tx, sd_rx) = channel::<()>();
        let (ready_tx, ready_rx) = channel::<Result<usize>>();
        let worker = std::thread::Builder::new()
            .name("hyena-server".into())
            .spawn(move || {
                let model = match backend::load(kind, &artifact_dir, seed).and_then(|mut m| {
                    if let Some(p) = params {
                        m.set_params(&p)?;
                    }
                    if let Some(levels) = buckets {
                        m.set_serve_buckets(levels)?;
                    }
                    if let Some(n) = max_context {
                        m.set_max_context(n)?;
                    }
                    Ok(m)
                }) {
                    Ok(m) => {
                        let bs = m.manifest().batch().unwrap_or(1);
                        let _ = ready_tx.send(Ok(bs));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let batch_size = model.manifest().batch().unwrap_or(1);
                worker_loop(model, rx, sd_rx, batch_size, max_wait, seed as u64);
            })
            .expect("spawn server worker");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))??;
        Ok(Server { handle: ServerHandle { tx }, worker: Some(worker), shutdown: sd_tx })
    }

    pub fn stop(mut self) {
        let _ = self.shutdown.send(());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Smallest bucket covering a prompt (the prefill's routing), clamped into
/// the ladder — prompts that outgrow every bucket route to the largest
/// (the full compiled length).
fn bucket_for_prompt(prompt_len: usize, buckets: &[usize]) -> usize {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= prompt_len)
        .or_else(|| buckets.last().copied())
        .unwrap_or(prompt_len)
}

/// One resident decode session inside the worker.
struct LiveSession {
    sess: DecodeSession,
    reply: Sender<Result<GenerateResponse>>,
    submitted: Instant,
    entered: Instant,
    sampling: Sampling,
    max_new: usize,
    prompt_len: usize,
    bucket_len: usize,
    /// Highest co-residency observed while live.
    occupancy: usize,
    /// Generated tokens; the last one is pending its decode step.
    out: Vec<i32>,
}

fn worker_loop(
    model: Box<dyn Backend>,
    rx: Receiver<Msg>,
    shutdown: Receiver<()>,
    capacity: usize,
    max_wait: Duration,
    seed: u64,
) {
    let mut batcher: Batcher<Envelope> = Batcher::new(capacity, max_wait);
    let mut rng = Pcg::with_stream(seed, 0x5e44);
    // The plan ladder and window are fixed for the worker's lifetime. The
    // window is the engine's decode window, which `--max-context` can
    // extend past the compiled shape (prompts beyond the largest plan
    // bucket prefill through the chunked overlap-save path).
    let buckets = model.serve_buckets();
    let l_full = model.decode_window();
    let mut live: Vec<LiveSession> = Vec::new();
    let mut logits: Vec<f32> = Vec::new();
    let handle = |msg: Msg, batcher: &mut Batcher<Envelope>| match msg {
        Msg::Gen(env) => batcher.push(env),
        Msg::Mem(reply) => {
            let _ = reply.send(model.mem_report());
        }
    };
    loop {
        // Drain everything currently queued on the channel — new arrivals
        // join between token rounds, not after whole batches.
        let mut disconnected = false;
        loop {
            match rx.try_recv() {
                Ok(msg) => handle(msg, &mut batcher),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if disconnected || shutdown.try_recv().is_ok() {
            // An admitted request always gets its reply (the old loop
            // finished any batch it had taken before observing shutdown);
            // queued-but-unadmitted requests are dropped, as before.
            while !live.is_empty() {
                step_round(model.as_ref(), &mut live, l_full, &mut rng, &mut logits);
            }
            return;
        }
        let now = Instant::now();
        // Admission: while sessions are in flight, freed capacity refills
        // immediately (sessions are shape-independent, so there is nothing
        // to co-schedule); from idle, the batching policy (full batch or
        // oldest-deadline) decides when decoding starts.
        if live.len() < capacity && (!live.is_empty() || batcher.ready(now)) {
            for env in batcher.take_up_to(capacity - live.len()) {
                admit(model.as_ref(), env, &buckets, l_full, &mut live, &mut rng, &mut logits);
            }
        }
        if !live.is_empty() {
            step_round(model.as_ref(), &mut live, l_full, &mut rng, &mut logits);
            continue;
        }
        // Idle: sleep until the oldest deadline or a short poll tick.
        let wait = batcher
            .time_to_deadline(now)
            .unwrap_or(Duration::from_millis(2))
            .min(Duration::from_millis(2))
            .max(Duration::from_micros(200));
        if let Ok(msg) = rx.recv_timeout(wait) {
            handle(msg, &mut batcher);
        }
    }
}

/// Prefill one request into a live session and sample its first token.
fn admit(
    model: &dyn Backend,
    env: Envelope,
    buckets: &[usize],
    l_full: usize,
    live: &mut Vec<LiveSession>,
    rng: &mut Pcg,
    logits: &mut Vec<f32>,
) {
    let entered = Instant::now();
    let Envelope { req, submitted, reply } = env;
    // Malformed prompts error out even on the zero-budget fast path (the
    // old whole-batch loop validated every request through decode_batch).
    if req.prompt.is_empty() || req.prompt.len() >= l_full {
        let _ = reply.send(Err(anyhow!(
            "prompt length {} out of range (1..{l_full})",
            req.prompt.len()
        )));
        return;
    }
    let bucket_len = bucket_for_prompt(req.prompt.len(), buckets);
    if req.max_new == 0 {
        let _ = reply.send(Ok(GenerateResponse {
            tokens: Vec::new(),
            queue_time: entered.duration_since(submitted),
            total_time: submitted.elapsed(),
            batch_occupancy: live.len() + 1,
            bucket_len,
        }));
        return;
    }
    match model.decode_begin(&req.prompt, logits) {
        Ok(sess) => {
            let first = sample_token(logits, req.sampling, rng);
            live.push(LiveSession {
                sess,
                reply,
                submitted,
                entered,
                sampling: req.sampling,
                max_new: req.max_new,
                prompt_len: req.prompt.len(),
                bucket_len,
                occupancy: 1,
                out: vec![first],
            });
        }
        Err(e) => {
            let _ = reply.send(Err(e));
        }
    }
}

/// Reply to and drop one finished/failed session.
fn retire(model: &dyn Backend, s: LiveSession, err: Option<anyhow::Error>) {
    let LiveSession { sess, reply, submitted, entered, bucket_len, occupancy, out, .. } = s;
    model.decode_end(sess);
    let _ = reply.send(match err {
        None => Ok(GenerateResponse {
            tokens: out,
            queue_time: entered.duration_since(submitted),
            total_time: submitted.elapsed(),
            batch_occupancy: occupancy,
            bucket_len,
        }),
        Some(e) => Err(anyhow!("{:#}", e)),
    });
}

/// Advance every live session by one token **in a single batched engine
/// call** (`Backend::decode_step_batch`): the native engine stacks every
/// session's current position into one `(rows, D)` dense pass per block,
/// recovering dense-kernel row blocking at high occupancy (DESIGN.md
/// §Kernels); engines without the override loop the serial step, which is
/// behaviour-identical. Finished sessions retire first and reply; failed
/// rows reply their error individually. The round is admission-shaped:
/// the engine sees the rows sorted by history length (ties by admission
/// order), so same-length sessions sit adjacent in the dense pass, but
/// sampling runs per row in *admission* order — the rng stream, and
/// therefore every token stream, is identical to the unshaped round.
fn step_round(
    model: &dyn Backend,
    live: &mut Vec<LiveSession>,
    l_full: usize,
    rng: &mut Pcg,
    logits: &mut Vec<f32>,
) {
    let occ = live.len();
    for s in live.iter_mut() {
        s.occupancy = s.occupancy.max(occ);
    }
    // Retire finished sessions before the round.
    let mut i = 0;
    while i < live.len() {
        let done = {
            let s = &live[i];
            s.out.len() >= s.max_new || s.prompt_len + s.out.len() >= l_full
        };
        if done {
            let s = live.remove(i);
            retire(model, s, None);
        } else {
            i += 1;
        }
    }
    if live.is_empty() {
        return;
    }
    // One batched step over everyone still live, shaped by history
    // length: (length, admission index) is a strict total order, so the
    // round composition is deterministic.
    let rows = live.len();
    let perm: Vec<usize>;
    let results = {
        let mut by_len: Vec<(usize, &mut LiveSession)> =
            live.iter_mut().enumerate().collect();
        by_len.sort_by_key(|(r, s)| (s.sess.len(), *r));
        perm = by_len.iter().map(|(r, _)| *r).collect();
        let tokens: Vec<i32> = by_len
            .iter()
            .map(|(_, s)| *s.out.last().expect("live session has a sampled token"))
            .collect();
        let mut sessions: Vec<&mut DecodeSession> =
            by_len.into_iter().map(|(_, s)| &mut s.sess).collect();
        model.decode_step_batch(&mut sessions, &tokens, logits)
    };
    debug_assert_eq!(results.len(), rows);
    let v = logits.len() / rows;
    // Engine row holding admission row `r`.
    let mut inv = vec![0usize; rows];
    for (j, &r) in perm.iter().enumerate() {
        inv[r] = j;
    }
    // Sample (or fail) per row in admission order; collect failures for
    // removal.
    let mut results: Vec<Option<anyhow::Result<()>>> =
        results.into_iter().map(Some).collect();
    let mut failed: Vec<(usize, anyhow::Error)> = Vec::new();
    for r in 0..rows {
        let j = inv[r];
        match results[j].take().expect("each engine row resolves one session") {
            Ok(()) => {
                let row = &logits[j * v..(j + 1) * v];
                let next = sample_token(row, live[r].sampling, rng);
                live[r].out.push(next);
            }
            Err(e) => failed.push((r, e)),
        }
    }
    for (r, e) in failed.into_iter().rev() {
        let s = live.remove(r);
        retire(model, s, Some(e));
    }
}
