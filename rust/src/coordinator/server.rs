//! Threaded inference server with resident decode sessions (serving L3).
//!
//! XLA handles are `!Send` (and backends in general need not be), so the
//! worker thread *constructs* its own [`Backend`] from the artifact path;
//! clients and worker exchange plain host data (`Vec<i32>` token ids) over
//! mpsc channels.
//!
//! **Session loop.** The worker keeps up to `batch_size` *live decode
//! sessions* ([`Backend::decode_begin`]): each is prefilled once at its own
//! prompt length (routed through the engine's smallest covering plan
//! bucket) and then advanced one token per round via
//! [`Backend::decode_step`] — on the native engine an O(L) time-domain dot
//! against per-session recurrence state, no prefix recompute (DESIGN.md
//! §Decode). Sessions persist across batching rounds: finished requests
//! retire and reply individually, and freed capacity is refilled from the
//! queue *between token rounds* (iteration-level continuous batching —
//! which now pays off precisely because sessions are stateful). Because
//! sessions are shape-independent, admission is FIFO (`take_up_to`); the
//! `Batcher` release policy (full batch or oldest-deadline) only decides
//! when the worker starts decoding from idle. Each request keeps its own
//! sampling policy.
//!
//! **Streaming + resilience (serving L4, DESIGN.md §Serving-Net).** A
//! request replies either once ([`Reply::Once`], the original blocking
//! path) or through a *bounded* [`StreamEvent`] channel
//! ([`ServerHandle::try_submit_stream`]) that emits every sampled token as
//! it exists — the network layer flushes each one as an SSE event. The
//! worker only ever `try_send`s: a full buffer means the client has
//! stalled past its allowance and the session is evicted; a disconnected
//! buffer means the client is gone and the session retires silently.
//! Either way one slow/dead socket can never wedge a decode round for the
//! other sessions. Per-request deadlines are enforced at three points
//! (queued, at admission, and swept *between token rounds* so an expired
//! request retires mid-decode). Admission control is a shared inflight
//! counter with a hard cap ([`ServerHandle::try_submit`] →
//! [`AdmitError::Busy`], which the HTTP front end maps to 429 +
//! Retry-After) — the queue can never grow without bound. Graceful drain
//! ([`ServerHandle::drain`]) stops admission, finishes live streams up to
//! a budget, force-retires the rest, and *keeps the worker alive* so
//! `mem_report` can prove zero leaked sessions afterwards.
//!
//! The response reports the prefill bucket (`bucket_len`) so callers — and
//! `scripts/check.sh decode-smoke` — can detect a full-pad prefill, and
//! `Backend::mem_report` exposes session counts / streamed-step counts so
//! `--stream-decode` can verify the engine is actually streaming.
//!
//! The worker's native backend captures the process-wide worker pool
//! (`util::pool`) at construction, so the server's forward passes and any
//! concurrent training steps share one set of compute threads instead of
//! oversubscribing the machine (`--threads` / `HYENA_THREADS`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{self, Backend, BackendKind, DecodeSession, MemReport};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::generation::{sample_token, Sampling};
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

#[derive(Clone)]
pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampling: Sampling,
    /// Wall-clock budget from submission. `None` = no deadline. Enforced
    /// while queued, at admission, and between token rounds; an expired
    /// streaming request terminates with a [`StreamEvent::Error`] after
    /// whatever tokens it already produced.
    pub deadline: Option<Duration>,
    /// Telemetry correlation id (`obs::trace`), minted by the front end and
    /// carried across the replica RPC so every layer's spans land on one
    /// trace. 0 = untraced (benches, direct engine drivers) — every trace
    /// call is then a no-op.
    pub trace_id: u64,
}

#[derive(Debug)]
pub struct GenerateResponse {
    pub tokens: Vec<i32>,
    /// Time spent queued before entering a batch.
    pub queue_time: Duration,
    /// Wall time from submission to completion.
    pub total_time: Duration,
    /// Highest number of co-resident sessions observed while this request
    /// was live (observability).
    pub batch_occupancy: usize,
    /// Plan bucket the request's *prefill* was routed to (== compiled
    /// seqlen when the engine has no shape buckets — the full-pad
    /// fallback). Decode steps after prefill are bucket-free: they run at
    /// a single position from the session state.
    pub bucket_len: usize,
}

/// One event on a streaming reply channel. The stream is a strict
/// grammar: `Token* (Done | Error)` — exactly one terminal, always last.
#[derive(Debug)]
pub enum StreamEvent {
    /// One sampled token, emitted the round it was produced.
    Token(i32),
    /// Normal completion; `tokens` repeats the full sequence so the
    /// terminal event is self-contained.
    Done(GenerateResponse),
    /// Abnormal termination (engine failure, deadline, slow-client
    /// eviction, drain abort). `partial` is how many tokens were produced
    /// before the stream died.
    Error { message: String, partial: usize },
}

/// Why a bounded submission was refused (never silently queued).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Inflight cap reached — back off for the hinted duration. The HTTP
    /// front end maps this to `429` + `Retry-After`.
    Busy { retry_after: Duration },
    /// Server is draining and admits nothing new (`503` on the wire).
    Draining,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Busy { retry_after } => {
                write!(f, "server busy: retry after {retry_after:?}")
            }
            AdmitError::Draining => write!(f, "server draining: not admitting new work"),
        }
    }
}

/// What a graceful drain did (`ServerHandle::drain`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DrainReport {
    /// Live sessions that ran to completion within the drain budget.
    pub finished: usize,
    /// Live sessions force-retired (error event) at the drain deadline.
    pub aborted: usize,
    /// Queued-but-unadmitted requests rejected at drain start.
    pub dropped_queued: usize,
}

/// Admission-accounting state shared between every handle clone and the
/// tickets riding on inflight requests.
struct ServerShared {
    /// Requests submitted through a bounded path and not yet replied.
    inflight: AtomicUsize,
    /// Hard admission cap: live capacity + allowed queue depth.
    admit_cap: AtomicUsize,
    /// Worker session capacity (manifest batch size), for observability.
    capacity: AtomicUsize,
    draining: AtomicBool,
}

/// RAII inflight slot: reserved at submission, released when the request's
/// reply has been sent and the worker drops its state — every exit path
/// (reply, retire, eviction, drain drop) releases exactly once, because
/// release *is* drop. Legacy unbounded submissions carry an empty ticket.
struct Ticket {
    shared: Option<Arc<ServerShared>>,
}

impl Drop for Ticket {
    fn drop(&mut self) {
        if let Some(s) = self.shared.take() {
            s.inflight.fetch_sub(1, Ordering::SeqCst);
            crate::obs::serving().inflight.add(-1);
        }
    }
}

/// How a request gets its answer back.
enum Reply {
    /// Single blocking reply (the original in-process path).
    Once(Sender<Result<GenerateResponse>>),
    /// Bounded per-token stream. The worker never blocks on it.
    Stream(SyncSender<StreamEvent>),
}

impl Reply {
    fn send_ok(self, resp: GenerateResponse) {
        match self {
            Reply::Once(tx) => {
                let _ = tx.send(Ok(resp));
            }
            Reply::Stream(tx) => {
                let _ = tx.try_send(StreamEvent::Done(resp));
            }
        }
    }

    fn send_err(self, e: anyhow::Error, partial: usize) {
        match self {
            Reply::Once(tx) => {
                let _ = tx.send(Err(anyhow!("{:#}", e)));
            }
            Reply::Stream(tx) => {
                let _ = tx.try_send(StreamEvent::Error {
                    message: format!("{:#}", e),
                    partial,
                });
            }
        }
    }
}

struct Envelope {
    req: GenerateRequest,
    submitted: Instant,
    /// Absolute deadline (submission + request budget).
    deadline: Option<Instant>,
    reply: Reply,
    ticket: Ticket,
}

/// Worker-bound messages: generation work, a serving-stats probe, a
/// parameter swap, or a drain order.
enum Msg {
    Gen(Envelope),
    Mem(Sender<Option<MemReport>>),
    /// Install new parameters (manifest order). The engine bumps its param
    /// epoch, which invalidates every live `ServeState`/decode session —
    /// the replica-fleet weight broadcast rides on this.
    SetParams(Vec<Tensor>, Sender<Result<()>>),
    Drain(Duration, Sender<DrainReport>),
}

/// Handle used by clients to submit requests (cloneable, Send).
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
    shared: Arc<ServerShared>,
}

impl ServerHandle {
    fn envelope(&self, req: GenerateRequest, reply: Reply, ticket: Ticket) -> Envelope {
        let submitted = Instant::now();
        let deadline = req.deadline.map(|d| submitted + d);
        Envelope { req, submitted, deadline, reply, ticket }
    }

    /// Reserve an inflight slot or say exactly why not.
    fn reserve(&self) -> std::result::Result<Ticket, AdmitError> {
        if self.shared.draining.load(Ordering::SeqCst) {
            return Err(AdmitError::Draining);
        }
        let cap = self.shared.admit_cap.load(Ordering::SeqCst);
        let prev = self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        if prev >= cap {
            self.shared.inflight.fetch_sub(1, Ordering::SeqCst);
            return Err(AdmitError::Busy { retry_after: Duration::from_secs(1) });
        }
        crate::obs::serving().inflight.add(1);
        Ok(Ticket { shared: Some(Arc::clone(&self.shared)) })
    }

    /// Submit a request; returns a receiver for the response. Unbounded
    /// (no admission control) — the in-process/benchmark path.
    pub fn submit(&self, req: GenerateRequest) -> Receiver<Result<GenerateResponse>> {
        let (reply_tx, reply_rx) = channel();
        let env = self.envelope(req, Reply::Once(reply_tx), Ticket { shared: None });
        // If the worker is gone the reply channel closes and the caller
        // observes a RecvError.
        let _ = self.tx.send(Msg::Gen(env));
        reply_rx
    }

    /// Bounded submission: refused with [`AdmitError`] when the inflight
    /// cap is reached or the server is draining. Never queues unboundedly.
    pub fn try_submit(
        &self,
        req: GenerateRequest,
    ) -> std::result::Result<Receiver<Result<GenerateResponse>>, AdmitError> {
        let ticket = self.reserve()?;
        let (reply_tx, reply_rx) = channel();
        let env = self.envelope(req, Reply::Once(reply_tx), ticket);
        let _ = self.tx.send(Msg::Gen(env));
        Ok(reply_rx)
    }

    /// Bounded *streaming* submission: each sampled token arrives as a
    /// [`StreamEvent::Token`] on a channel buffered to `token_buf` events.
    /// If the consumer falls `token_buf` tokens behind the engine it is
    /// evicted (its session retires with an error event it may never
    /// read); if it hangs up, the session retires silently. The terminal
    /// `Done`/`Error` event needs a buffer slot too, so `token_buf >= 2`.
    pub fn try_submit_stream(
        &self,
        req: GenerateRequest,
        token_buf: usize,
    ) -> std::result::Result<Receiver<StreamEvent>, AdmitError> {
        let ticket = self.reserve()?;
        Ok(self.submit_stream_with(req, token_buf, ticket))
    }

    /// Streaming submission without admission control (in-process use).
    pub fn submit_stream(
        &self,
        req: GenerateRequest,
        token_buf: usize,
    ) -> Receiver<StreamEvent> {
        self.submit_stream_with(req, token_buf, Ticket { shared: None })
    }

    fn submit_stream_with(
        &self,
        req: GenerateRequest,
        token_buf: usize,
        ticket: Ticket,
    ) -> Receiver<StreamEvent> {
        let (reply_tx, reply_rx) = sync_channel(token_buf.max(2));
        let env = self.envelope(req, Reply::Stream(reply_tx), ticket);
        let _ = self.tx.send(Msg::Gen(env));
        reply_rx
    }

    /// Convenience blocking call.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server worker terminated"))?
    }

    /// Swap the worker's parameters (host tensors, manifest order) between
    /// requests. The engine's param-epoch bump invalidates every cached
    /// `ServeState` and live decode session, so no request ever sees
    /// mixed-epoch tokens. Blocks until the worker has installed them.
    pub fn set_params(&self, params: Vec<Tensor>) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::SetParams(params, tx))
            .map_err(|_| anyhow!("server worker terminated"))?;
        rx.recv().map_err(|_| anyhow!("server worker terminated"))?
    }

    /// Snapshot of the worker backend's arena/workspace accounting (the
    /// serve report; `None` when the engine does not track it). Still
    /// answered after a drain — that is how the front end proves zero
    /// leaked sessions.
    pub fn mem_report(&self) -> Option<MemReport> {
        let (tx, rx) = channel();
        if self.tx.send(Msg::Mem(tx)).is_err() {
            return None;
        }
        rx.recv().ok().flatten()
    }

    /// Worker session capacity (manifest batch size).
    pub fn capacity(&self) -> usize {
        self.shared.capacity.load(Ordering::SeqCst)
    }

    /// Requests currently holding an inflight slot (bounded paths only).
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Set the admission cap to `capacity + queue_cap` (default queue_cap
    /// is one extra capacity's worth).
    pub fn set_queue_cap(&self, queue_cap: usize) {
        let cap = self.shared.capacity.load(Ordering::SeqCst);
        self.shared.admit_cap.store(cap + queue_cap, Ordering::SeqCst);
    }

    /// Stop admitting bounded submissions (the drain's first step; also
    /// flips new `try_submit*` calls to [`AdmitError::Draining`]).
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Graceful drain: stop admission, let live streams finish for up to
    /// `budget`, force-retire the rest with error events, and report.
    /// The worker stays alive afterwards (answering `mem_report`, refusing
    /// generation) until [`Server::stop`].
    pub fn drain(&self, budget: Duration) -> Option<DrainReport> {
        self.begin_drain();
        let (tx, rx) = channel();
        if self.tx.send(Msg::Drain(budget, tx)).is_err() {
            return None;
        }
        rx.recv().ok()
    }
}

/// A stream admitted through [`Engine::try_submit_stream`]: the bounded
/// event channel plus, when the engine is a replica fleet, which replica
/// the request landed on (surfaced in access logs and `done` events).
pub struct StreamSubmission {
    pub rx: Receiver<StreamEvent>,
    pub replica: Option<usize>,
}

/// What the network front end needs from whatever serves tokens — the
/// single in-process worker ([`ServerHandle`]) or a replica fleet behind
/// the router (`net::router::FleetHandle`). Everything the HTTP layer
/// does (admission, streaming, health, mem, drain) goes through this
/// seam, so `serve --listen` and `serve --listen --replicas N` share one
/// front end.
pub trait Engine: Send + Sync {
    /// Bounded streaming admission. `session` is an optional client
    /// affinity key: a fleet pins every request carrying the same key to
    /// one replica (decode state is replica-resident); the in-process
    /// engine ignores it (there is only one place state can live).
    fn try_submit_stream(
        &self,
        req: GenerateRequest,
        token_buf: usize,
        session: Option<&str>,
    ) -> std::result::Result<StreamSubmission, AdmitError>;

    /// Aggregated serving-stats snapshot (summed across a fleet).
    fn mem_report(&self) -> Option<MemReport>;

    /// Total live-session capacity (summed across a fleet).
    fn capacity(&self) -> usize;

    /// Requests currently holding inflight slots (summed across a fleet).
    fn inflight(&self) -> usize;

    /// Admission queue depth on top of capacity. Fleets ignore this: each
    /// replica's cap is fixed at replica startup.
    fn set_queue_cap(&self, _queue_cap: usize) {}

    fn begin_drain(&self);
    fn is_draining(&self) -> bool;

    /// Graceful drain (fleet-wide when there are replicas).
    fn drain(&self, budget: Duration) -> Option<DrainReport>;

    /// Worker processes behind this engine (1 for the in-process worker).
    fn replicas(&self) -> usize {
        1
    }

    /// Telemetry snapshot for `GET /metrics`. The in-process engine shares
    /// the front end's registry, so the default — this process's snapshot —
    /// is exact; a fleet overrides it to merge replica snapshots
    /// (aggregate sums plus per-replica labeled series).
    fn metrics(&self) -> crate::obs::Snapshot {
        crate::obs::snapshot()
    }
}

impl Engine for ServerHandle {
    fn try_submit_stream(
        &self,
        req: GenerateRequest,
        token_buf: usize,
        _session: Option<&str>,
    ) -> std::result::Result<StreamSubmission, AdmitError> {
        ServerHandle::try_submit_stream(self, req, token_buf)
            .map(|rx| StreamSubmission { rx, replica: None })
    }

    fn mem_report(&self) -> Option<MemReport> {
        ServerHandle::mem_report(self)
    }

    fn capacity(&self) -> usize {
        ServerHandle::capacity(self)
    }

    fn inflight(&self) -> usize {
        ServerHandle::inflight(self)
    }

    fn set_queue_cap(&self, queue_cap: usize) {
        ServerHandle::set_queue_cap(self, queue_cap)
    }

    fn begin_drain(&self) {
        ServerHandle::begin_drain(self)
    }

    fn is_draining(&self) -> bool {
        ServerHandle::is_draining(self)
    }

    fn drain(&self, budget: Duration) -> Option<DrainReport> {
        ServerHandle::drain(self, budget)
    }
}

pub struct Server {
    pub handle: ServerHandle,
    worker: Option<JoinHandle<()>>,
    shutdown: Sender<()>,
}

impl Server {
    /// Start the worker thread: it constructs its own backend for
    /// `artifact_dir` (backend state never crosses threads) and then serves
    /// until `stop()`. The engine follows [`BackendKind::detect`]
    /// (`HYENA_BACKEND`, else artifact autodetection). Blocks until ready.
    pub fn start(artifact_dir: PathBuf, seed: i32, max_wait: Duration) -> Result<Server> {
        Self::start_with_params(artifact_dir, seed, max_wait, None)
    }

    /// Like [`Server::start`], but install pretrained parameters (host
    /// tensors, manifest order) into the worker's model — the hand-off used
    /// by `examples/lm_pretrain.rs` after training.
    pub fn start_with_params(
        artifact_dir: PathBuf,
        seed: i32,
        max_wait: Duration,
        params: Option<Vec<Tensor>>,
    ) -> Result<Server> {
        let kind = BackendKind::detect(&artifact_dir)?;
        Self::start_kind(kind, artifact_dir, seed, max_wait, params, None, None)
    }

    /// Start with an explicitly chosen engine (the CLI's `--backend`) and,
    /// optionally, an explicit serving bucket-ladder depth (the CLI's
    /// `--buckets`; `None` keeps the engine default) and an extended
    /// context window (the CLI's `--max-context`; `None` keeps the
    /// compiled window — engines without chunked prefill reject other
    /// values at startup).
    pub fn start_kind(
        kind: BackendKind,
        artifact_dir: PathBuf,
        seed: i32,
        max_wait: Duration,
        params: Option<Vec<Tensor>>,
        buckets: Option<usize>,
        max_context: Option<usize>,
    ) -> Result<Server> {
        let (tx, rx) = channel::<Msg>();
        let (sd_tx, sd_rx) = channel::<()>();
        let (ready_tx, ready_rx) = channel::<Result<usize>>();
        let worker = std::thread::Builder::new()
            .name("hyena-server".into())
            .spawn(move || {
                let model = match backend::load(kind, &artifact_dir, seed).and_then(|mut m| {
                    if let Some(p) = params {
                        m.set_params(&p)?;
                    }
                    if let Some(levels) = buckets {
                        m.set_serve_buckets(levels)?;
                    }
                    if let Some(n) = max_context {
                        m.set_max_context(n)?;
                    }
                    Ok(m)
                }) {
                    Ok(m) => {
                        let bs = m.manifest().batch().unwrap_or(1);
                        let _ = ready_tx.send(Ok(bs));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let batch_size = model.manifest().batch().unwrap_or(1);
                worker_loop(model, rx, sd_rx, batch_size, max_wait, seed as u64);
            })
            .expect("spawn server worker");
        let capacity = ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))??;
        let shared = Arc::new(ServerShared {
            inflight: AtomicUsize::new(0),
            // Default queue depth: one extra capacity's worth of waiters.
            admit_cap: AtomicUsize::new(capacity * 2),
            capacity: AtomicUsize::new(capacity),
            draining: AtomicBool::new(false),
        });
        Ok(Server {
            handle: ServerHandle { tx, shared },
            worker: Some(worker),
            shutdown: sd_tx,
        })
    }

    pub fn stop(mut self) {
        let _ = self.shutdown.send(());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Smallest bucket covering a prompt (the prefill's routing), clamped into
/// the ladder — prompts that outgrow every bucket route to the largest
/// (the full compiled length).
fn bucket_for_prompt(prompt_len: usize, buckets: &[usize]) -> usize {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= prompt_len)
        .or_else(|| buckets.last().copied())
        .unwrap_or(prompt_len)
}

/// One resident decode session inside the worker.
struct LiveSession {
    sess: DecodeSession,
    reply: Reply,
    ticket: Ticket,
    submitted: Instant,
    entered: Instant,
    deadline: Option<Instant>,
    sampling: Sampling,
    max_new: usize,
    prompt_len: usize,
    bucket_len: usize,
    /// Telemetry id riding with the request (`obs::trace`; 0 = untraced).
    trace_id: u64,
    /// Highest co-residency observed while live.
    occupancy: usize,
    /// Generated tokens; the last one is pending its decode step.
    out: Vec<i32>,
}

fn worker_loop(
    mut model: Box<dyn Backend>,
    rx: Receiver<Msg>,
    shutdown: Receiver<()>,
    capacity: usize,
    max_wait: Duration,
    seed: u64,
) {
    let mut batcher: Batcher<Envelope> = Batcher::new(capacity, max_wait);
    let mut rng = Pcg::with_stream(seed, 0x5e44);
    // The plan ladder and window are fixed for the worker's lifetime. The
    // window is the engine's decode window, which `--max-context` can
    // extend past the compiled shape (prompts beyond the largest plan
    // bucket prefill through the chunked overlap-save path).
    let buckets = model.serve_buckets();
    let l_full = model.decode_window();
    let mut live: Vec<LiveSession> = Vec::new();
    let mut logits: Vec<f32> = Vec::new();
    // Post-drain the worker refuses generation but keeps answering Mem.
    let mut drained = false;
    let mut drain_req: Option<(Duration, Sender<DrainReport>)> = None;
    loop {
        // Drain everything currently queued on the channel — new arrivals
        // join between token rounds, not after whole batches.
        let mut disconnected = false;
        loop {
            match rx.try_recv() {
                Ok(msg) => handle_msg(msg, model.as_mut(), &mut batcher, drained, &mut drain_req),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if disconnected || shutdown.try_recv().is_ok() {
            // An admitted request always gets its reply (the old loop
            // finished any batch it had taken before observing shutdown);
            // queued-but-unadmitted requests are dropped, as before.
            while !live.is_empty() {
                step_round(model.as_ref(), &mut live, l_full, &mut rng, &mut logits);
            }
            return;
        }
        if let Some((budget, report_tx)) = drain_req.take() {
            let deadline = Instant::now() + budget;
            let mut report = DrainReport::default();
            // Queued-but-unadmitted work is rejected immediately — a drain
            // only owes completion to sessions that already hold state.
            let n = batcher.len();
            for env in batcher.take_up_to(n) {
                env.reply
                    .send_err(anyhow!("server draining: request dropped before admission"), 0);
                report.dropped_queued += 1;
            }
            // Let live streams run to completion inside the budget.
            while !live.is_empty() && Instant::now() < deadline {
                let before = live.len();
                step_round(model.as_ref(), &mut live, l_full, &mut rng, &mut logits);
                report.finished += before - live.len();
            }
            // Whatever is still live gets a terminal error event, not a
            // silent disappearance — and its session state is freed.
            report.aborted = live.len();
            for s in live.drain(..) {
                let partial = s.out.len();
                retire_with(
                    model.as_ref(),
                    s,
                    Some((anyhow!("server draining: stream aborted at drain deadline"), partial)),
                );
            }
            drained = true;
            let _ = report_tx.send(report);
            continue;
        }
        let now = Instant::now();
        // Deadline sweep over the queue: a request that expired while
        // waiting replies its error without ever touching the engine.
        for env in batcher.take_expired(now, |e: &Envelope| e.deadline) {
            let waited = now.duration_since(env.submitted);
            env.reply
                .send_err(anyhow!("deadline exceeded after {waited:?} in queue"), 0);
        }
        // Admission: while sessions are in flight, freed capacity refills
        // immediately (sessions are shape-independent, so there is nothing
        // to co-schedule); from idle, the batching policy (full batch or
        // oldest-deadline) decides when decoding starts.
        if live.len() < capacity && (!live.is_empty() || batcher.ready(now)) {
            for env in batcher.take_up_to(capacity - live.len()) {
                admit(model.as_ref(), env, &buckets, l_full, &mut live, &mut rng, &mut logits);
            }
        }
        if !live.is_empty() {
            step_round(model.as_ref(), &mut live, l_full, &mut rng, &mut logits);
            continue;
        }
        // Idle: sleep until the oldest deadline or a short poll tick.
        let wait = batcher
            .time_to_deadline(now)
            .unwrap_or(Duration::from_millis(2))
            .min(Duration::from_millis(2))
            .max(Duration::from_micros(200));
        if let Ok(msg) = rx.recv_timeout(wait) {
            handle_msg(msg, model.as_mut(), &mut batcher, drained, &mut drain_req);
        }
    }
}

fn handle_msg(
    msg: Msg,
    model: &mut dyn Backend,
    batcher: &mut Batcher<Envelope>,
    drained: bool,
    drain_req: &mut Option<(Duration, Sender<DrainReport>)>,
) {
    match msg {
        Msg::Gen(env) => {
            if drained {
                env.reply
                    .send_err(anyhow!("server draining: not admitting new work"), 0);
            } else {
                batcher.push(env);
            }
        }
        Msg::Mem(reply) => {
            let _ = reply.send(model.mem_report());
        }
        Msg::SetParams(params, tx) => {
            // Installed between token rounds: sessions admitted before the
            // swap keep stepping against the *old* epoch's state and are
            // refused by the engine (`decode_state_stale`), surfacing a
            // clean per-session error instead of mixed-epoch tokens.
            let _ = tx.send(model.set_params(&params));
        }
        Msg::Drain(budget, tx) => {
            if drained {
                // Idempotent: a second drain finds nothing to do.
                let _ = tx.send(DrainReport::default());
            } else {
                *drain_req = Some((budget, tx));
            }
        }
    }
}

/// Prefill one request into a live session and sample its first token.
fn admit(
    model: &dyn Backend,
    env: Envelope,
    buckets: &[usize],
    l_full: usize,
    live: &mut Vec<LiveSession>,
    rng: &mut Pcg,
    logits: &mut Vec<f32>,
) {
    let entered = Instant::now();
    let Envelope { req, submitted, deadline, reply, ticket } = env;
    // Queue-wait telemetry: observed for every request that reached
    // admission, even one about to be rejected below.
    let queued = entered.duration_since(submitted);
    crate::obs::serving().queue_wait_us.observe_us(queued);
    let q_us = queued.as_micros() as u64;
    crate::obs::trace::span(
        req.trace_id,
        "queue_wait",
        crate::obs::clock::now_us().saturating_sub(q_us),
        q_us,
        0,
    );
    // A request that expired in the queue gap never touches the engine.
    if deadline.is_some_and(|d| entered >= d) {
        let waited = entered.duration_since(submitted);
        reply.send_err(anyhow!("deadline exceeded after {waited:?} in queue"), 0);
        return;
    }
    // Malformed prompts error out even on the zero-budget fast path (the
    // old whole-batch loop validated every request through decode_batch).
    if req.prompt.is_empty() || req.prompt.len() >= l_full {
        reply.send_err(
            anyhow!("prompt length {} out of range (1..{l_full})", req.prompt.len()),
            0,
        );
        return;
    }
    let bucket_len = bucket_for_prompt(req.prompt.len(), buckets);
    if req.max_new == 0 {
        reply.send_ok(GenerateResponse {
            tokens: Vec::new(),
            queue_time: entered.duration_since(submitted),
            total_time: submitted.elapsed(),
            batch_occupancy: live.len() + 1,
            bucket_len,
        });
        return;
    }
    // Prefill, timed; the ambient trace id lets the engine attach
    // per-chunk spans from inside the overlap-save loop.
    let t0 = crate::obs::clock::now_us();
    crate::obs::trace::set_current(req.trace_id);
    let begun = model.decode_begin(&req.prompt, logits);
    crate::obs::trace::set_current(0);
    let prefill_us = crate::obs::clock::now_us().saturating_sub(t0);
    crate::obs::serving().prefill_us.observe(prefill_us);
    crate::obs::trace::span(req.trace_id, "prefill", t0, prefill_us, req.prompt.len() as u64);
    match begun {
        Ok(sess) => {
            let first = sample_token(logits, req.sampling, rng);
            if let Reply::Stream(tx) = &reply {
                if tx.try_send(StreamEvent::Token(first)).is_err() {
                    // Client hung up (or stalled) before its first token:
                    // free the session state immediately.
                    model.decode_end(sess);
                    return;
                }
            }
            live.push(LiveSession {
                sess,
                reply,
                ticket,
                submitted,
                entered,
                deadline,
                sampling: req.sampling,
                max_new: req.max_new,
                prompt_len: req.prompt.len(),
                bucket_len,
                trace_id: req.trace_id,
                occupancy: 1,
                out: vec![first],
            });
        }
        Err(e) => {
            reply.send_err(e, 0);
        }
    }
}

/// Reply to and drop one finished/failed session. `err` carries the token
/// count already produced (the stream's `partial`).
fn retire_with(
    model: &dyn Backend,
    s: LiveSession,
    err: Option<(anyhow::Error, usize)>,
) {
    let LiveSession { sess, reply, submitted, entered, bucket_len, occupancy, out, .. } = s;
    model.decode_end(sess);
    match err {
        None => reply.send_ok(GenerateResponse {
            tokens: out,
            queue_time: entered.duration_since(submitted),
            total_time: submitted.elapsed(),
            batch_occupancy: occupancy,
            bucket_len,
        }),
        Some((e, partial)) => reply.send_err(e, partial),
    }
}

/// Advance every live session by one token **in a single batched engine
/// call** (`Backend::decode_step_batch`): the native engine stacks every
/// session's current position into one `(rows, D)` dense pass per block,
/// recovering dense-kernel row blocking at high occupancy (DESIGN.md
/// §Kernels); engines without the override loop the serial step, which is
/// behaviour-identical. Finished and deadline-expired sessions retire
/// first and reply; failed rows reply their error individually; streaming
/// rows whose consumer stalled (buffer full) or hung up retire after the
/// round without wedging anyone else. The round is admission-shaped:
/// the engine sees the rows sorted by history length (ties by admission
/// order), so same-length sessions sit adjacent in the dense pass, but
/// sampling runs per row in *admission* order — the rng stream, and
/// therefore every token stream, is identical to the unshaped round.
fn step_round(
    model: &dyn Backend,
    live: &mut Vec<LiveSession>,
    l_full: usize,
    rng: &mut Pcg,
    logits: &mut Vec<f32>,
) {
    let occ = live.len();
    for s in live.iter_mut() {
        s.occupancy = s.occupancy.max(occ);
    }
    // Retire finished and deadline-expired sessions before the round (the
    // mid-decode deadline sweep: an expired request never costs another
    // engine step).
    let now = Instant::now();
    let mut i = 0;
    while i < live.len() {
        let done = {
            let s = &live[i];
            s.out.len() >= s.max_new || s.prompt_len + s.out.len() >= l_full
        };
        let expired = !done && live[i].deadline.is_some_and(|d| now >= d);
        if done {
            let s = live.remove(i);
            retire_with(model, s, None);
        } else if expired {
            let s = live.remove(i);
            let partial = s.out.len();
            retire_with(
                model,
                s,
                Some((anyhow!("deadline exceeded after {partial} generated tokens"), partial)),
            );
        } else {
            i += 1;
        }
    }
    if live.is_empty() {
        return;
    }
    // One batched step over everyone still live, shaped by history
    // length: (length, admission index) is a strict total order, so the
    // round composition is deterministic.
    let rows = live.len();
    let perm: Vec<usize>;
    let round_t0 = crate::obs::clock::now_us();
    let results = {
        let mut by_len: Vec<(usize, &mut LiveSession)> =
            live.iter_mut().enumerate().collect();
        by_len.sort_by_key(|(r, s)| (s.sess.len(), *r));
        perm = by_len.iter().map(|(r, _)| *r).collect();
        let tokens: Vec<i32> = by_len
            .iter()
            .map(|(_, s)| *s.out.last().expect("live session has a sampled token"))
            .collect();
        let mut sessions: Vec<&mut DecodeSession> =
            by_len.into_iter().map(|(_, s)| &mut s.sess).collect();
        model.decode_step_batch(&mut sessions, &tokens, logits)
    };
    let round_us = crate::obs::clock::now_us().saturating_sub(round_t0);
    crate::obs::serving().decode_round_us.observe(round_us);
    // One span per live trace per round (coarse: never per token-byte, so
    // the hub mutex stays off the inner sampling loop).
    for s in live.iter() {
        crate::obs::trace::span(s.trace_id, "decode_round", round_t0, round_us, rows as u64);
    }
    debug_assert_eq!(results.len(), rows);
    let v = logits.len() / rows;
    // Engine row holding admission row `r`.
    let mut inv = vec![0usize; rows];
    for (j, &r) in perm.iter().enumerate() {
        inv[r] = j;
    }
    // Sample (or fail) per row in admission order; collect failures for
    // removal. Sampling happens for every healthy row *before* any
    // eviction, so stream pushes can never perturb the rng order.
    let mut results: Vec<Option<anyhow::Result<()>>> =
        results.into_iter().map(Some).collect();
    let mut failed: Vec<(usize, anyhow::Error)> = Vec::new();
    for r in 0..rows {
        let j = inv[r];
        match results[j].take().expect("each engine row resolves one session") {
            Ok(()) => {
                let row = &logits[j * v..(j + 1) * v];
                let next = sample_token(row, live[r].sampling, rng);
                live[r].out.push(next);
                if let Reply::Stream(tx) = &live[r].reply {
                    match tx.try_send(StreamEvent::Token(next)) {
                        Ok(()) => {}
                        Err(TrySendError::Full(_)) => failed.push((
                            r,
                            anyhow!(
                                "slow client: stalled past {} buffered tokens, stream evicted",
                                // Capacity == buffer len when full.
                                live[r].out.len()
                            ),
                        )),
                        // Consumer hung up (client disconnect): retire
                        // silently — sends to a dead channel are no-ops.
                        Err(TrySendError::Disconnected(_)) => {
                            failed.push((r, anyhow!("client disconnected mid-stream")))
                        }
                    }
                }
            }
            Err(e) => failed.push((r, e)),
        }
    }
    for (r, e) in failed.into_iter().rev() {
        let s = live.remove(r);
        let partial = s.out.len();
        retire_with(model, s, Some((e, partial)));
    }
}
