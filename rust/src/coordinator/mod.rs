//! L3 coordination: training orchestration, dynamic-batching inference
//! server, autoregressive decoding, few-shot evaluation harness.
pub mod batcher;
pub mod experiment;
pub mod fewshot;
pub mod generation;
pub mod server;
pub mod trainer;
