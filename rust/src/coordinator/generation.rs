//! Autoregressive decoding + token sampling.
//!
//! Hyena has no KV cache (it is convolutional; the paper defers fast
//! autoregressive inference to future work), so decoding recomputes the
//! forward pass per generated token over the compiled fixed-length window.

use anyhow::{bail, Result};

use crate::backend::Backend;
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

/// Sampling policy for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// Temperature softmax sampling; optional top-k truncation.
    Temperature { t: f32, top_k: usize },
}

/// Pick the next token from a logits row.
pub fn sample_token(row: &[f32], s: Sampling, rng: &mut Pcg) -> i32 {
    match s {
        Sampling::Greedy => argmax(row),
        Sampling::Temperature { t, top_k } => {
            let t = t.max(1e-4);
            let mut idx: Vec<usize> = (0..row.len()).collect();
            if top_k > 0 && top_k < row.len() {
                idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
                idx.truncate(top_k);
            }
            let mx = idx.iter().map(|&i| row[i]).fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f32> = idx.iter().map(|&i| ((row[i] - mx) / t).exp()).collect();
            idx[rng.weighted(&weights)] as i32
        }
    }
}

pub fn argmax(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Decode a *batch* of prompts together through the compiled forward pass.
///
/// `prompts` are token id vectors (each < seqlen). Rows are padded with 0;
/// causality guarantees pad positions after a row's frontier cannot affect
/// its next-token logits. Each row stops after its own `max_new` tokens or
/// at the model's window edge. Returns the generated suffixes.
pub fn decode_batch(
    model: &dyn Backend,
    prompts: &[Vec<i32>],
    max_new: &[usize],
    sampling: Sampling,
    rng: &mut Pcg,
) -> Result<Vec<Vec<i32>>> {
    let b = model.manifest().batch()?;
    let l = model.manifest().seqlen()?;
    let v = model.manifest().vocab()?;
    if prompts.len() > b {
        bail!("{} prompts > compiled batch {}", prompts.len(), b);
    }
    let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
    for s in &seqs {
        if s.is_empty() || s.len() >= l {
            bail!("prompt length {} out of range (1..{})", s.len(), l);
        }
    }
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
    let max_rounds = max_new.iter().copied().max().unwrap_or(0);

    for _ in 0..max_rounds {
        // Assemble the padded token matrix.
        let mut toks = vec![0i32; b * l];
        for (r, s) in seqs.iter().enumerate() {
            toks[r * l..r * l + s.len()].copy_from_slice(s);
        }
        let logits = model.forward(&[Tensor::from_i32(&[b, l], toks)?])?;
        let lf = logits.as_f32()?;
        let mut progressed = false;
        for (r, s) in seqs.iter_mut().enumerate() {
            if out[r].len() >= max_new[r] || s.len() >= l {
                continue;
            }
            let pos = s.len() - 1;
            let row = &lf[(r * l + pos) * v..(r * l + pos + 1) * v];
            let tok = sample_token(row, sampling, rng);
            s.push(tok);
            out[r].push(tok);
            progressed = true;
        }
        if !progressed {
            break;
        }
    }
    Ok(out)
}

/// Per-position logits row accessor used by few-shot scoring: returns the
/// log-softmax score of `target` at position `pos` of row `r`.
pub fn logprob_at(logits: &Tensor, r: usize, pos: usize, target: i32) -> Result<f32> {
    let shape = logits.shape();
    let (l, v) = (shape[1], shape[2]);
    let lf = logits.as_f32()?;
    let row = &lf[(r * l + pos) * v..(r * l + pos + 1) * v];
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = mx + row.iter().map(|x| (x - mx).exp()).sum::<f32>().ln();
    Ok(row[target as usize] - lse)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let row = [0.1, 2.0, -1.0, 1.9];
        let mut rng = Pcg::new(0);
        assert_eq!(sample_token(&row, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let row = [0.0, 5.0, 0.0];
        let mut rng = Pcg::new(1);
        for _ in 0..50 {
            let t = sample_token(
                &row,
                Sampling::Temperature { t: 0.01, top_k: 0 },
                &mut rng,
            );
            assert_eq!(t, 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let row = [10.0, 9.0, -50.0, -50.0];
        let mut rng = Pcg::new(2);
        for _ in 0..100 {
            let t = sample_token(
                &row,
                Sampling::Temperature { t: 5.0, top_k: 2 },
                &mut rng,
            );
            assert!(t == 0 || t == 1, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let row = [1.0, 0.9, 0.8, 0.7];
        let mut rng = Pcg::new(3);
        let mut seen = [false; 4];
        for _ in 0..300 {
            let t = sample_token(
                &row,
                Sampling::Temperature { t: 10.0, top_k: 0 },
                &mut rng,
            );
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
