//! Autoregressive decoding + token sampling.
//!
//! Hyena has no KV cache (it is convolutional; the paper defers fast
//! autoregressive inference to future work), but its long convolutions
//! admit **stateful streaming decode**: keep the per-block conv-input
//! histories resident and each new token costs one O(L) time-domain dot
//! per channel instead of an O(L log L) re-transform of the whole prefix
//! (DESIGN.md §Decode). [`decode_batch`] therefore runs a *session loop*
//! over [`Backend::decode_begin`]/[`Backend::decode_step`]: one prefill
//! per request (through the engine's bucketed plans), then one step per
//! token, with finished rows dropping out as they stop. Engines without a
//! streaming path fall back to the trait default — recompute the growing
//! prefix through [`Backend::infer`] each step — which is exactly the
//! behaviour [`decode_batch_recompute`] preserves as the reference
//! implementation (equivalence is pinned by tests and gated by
//! `benches/native_decode.rs`).

use anyhow::{bail, Result};

use crate::backend::{Backend, DecodeSession};
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

/// Sampling policy for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// Temperature softmax sampling; optional top-k truncation.
    Temperature { t: f32, top_k: usize },
}

/// Pick the next token from a logits row.
///
/// Robust against non-finite logits: NaNs lose every comparison
/// (`f32::total_cmp` under a NaN filter, never `partial_cmp().unwrap()` —
/// one NaN logit used to panic the whole serving worker) and are excluded
/// from the temperature-sampling support. `-inf` entries stay in the
/// support with zero weight; a `+inf` (or all-`-inf`) support degenerates
/// the softmax, so it falls back to the greedy argmax — keeping greedy and
/// temperature sampling consistent about which token dominates.
pub fn sample_token(row: &[f32], s: Sampling, rng: &mut Pcg) -> i32 {
    match s {
        Sampling::Greedy => argmax(row),
        Sampling::Temperature { t, top_k } => {
            let t = t.max(1e-4);
            let mut idx: Vec<usize> = (0..row.len()).filter(|&i| !row[i].is_nan()).collect();
            if idx.is_empty() {
                // Degenerate row (all NaN): deterministic fallback.
                return argmax(row);
            }
            if top_k > 0 && top_k < idx.len() {
                // O(V) selection instead of a full O(V log V) vocab sort
                // per token. The comparator is a strict total order (logit
                // descending, index ascending on ties), so the selected set
                // — and, after the O(k log k) sort of the survivors, the
                // exact ordering — is identical to the old full-sort path
                // (pinned by `top_k_selection_matches_full_sort`).
                let by_logit_desc =
                    |a: &usize, b: &usize| row[*b].total_cmp(&row[*a]).then(a.cmp(b));
                idx.select_nth_unstable_by(top_k - 1, by_logit_desc);
                idx.truncate(top_k);
                idx.sort_unstable_by(by_logit_desc);
            }
            let mx = idx.iter().map(|&i| row[i]).fold(f32::NEG_INFINITY, f32::max);
            if !mx.is_finite() {
                // +inf in the support (or nothing above -inf): softmax
                // weights are NaN; the dominating token is the argmax.
                return argmax(row);
            }
            let weights: Vec<f32> = idx.iter().map(|&i| ((row[i] - mx) / t).exp()).collect();
            idx[rng.weighted(&weights)] as i32
        }
    }
}

/// Index of the largest non-NaN logit (0 for an all-NaN row).
pub fn argmax(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .filter(|(_, x)| !x.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Validate a decode request against a context window of `l` tokens;
/// returns `V`. Streaming decode passes [`Backend::decode_window`] (which
/// the native engine extends past the compiled shape via `--max-context`);
/// the recompute path passes the compiled `seqlen`, since it replays whole
/// prefixes through the bucketed [`Backend::infer`].
fn check_decode_shapes(
    model: &dyn Backend,
    prompts: &[Vec<i32>],
    max_new: &[usize],
    l: usize,
) -> Result<usize> {
    let b = model.manifest().batch()?;
    let v = model.manifest().vocab()?;
    if prompts.len() > b {
        bail!("{} prompts > compiled batch {}", prompts.len(), b);
    }
    if prompts.len() != max_new.len() {
        bail!("{} prompts but {} max_new budgets", prompts.len(), max_new.len());
    }
    for s in prompts {
        if s.is_empty() || s.len() >= l {
            bail!("prompt length {} out of range (1..{})", s.len(), l);
        }
    }
    Ok(v)
}

/// Decode a *batch* of prompts as resident streaming sessions.
///
/// One [`Backend::decode_begin`] prefill per request (the engine routes it
/// through its smallest covering plan bucket), then rounds of
/// [`Backend::decode_step_batch`] over the still-live rows — one engine
/// call per token round, stepping every row at once — where a row retires
/// after its own `max_new` tokens or at the model's window edge, and
/// retired rows stop costing anything (session-level row compaction).
/// Rounds are admission-shaped: the engine call sees live rows sorted by
/// history length, while sampling consumes the rng in original row order,
/// so every token stream is invariant under the shaping permutation.
/// The window is [`Backend::decode_window`], which the native engine can
/// extend past the compiled shape (`--max-context`); prompts beyond the
/// largest plan bucket prefill through the chunked overlap-save path.
/// The native engine serves each step at O(L) from its per-session
/// recurrence state; engines without a streaming path inherit the trait
/// default, which recomputes the prefix through [`Backend::infer`] —
/// functionally today's [`decode_batch_recompute`]. Returns the generated
/// suffixes.
pub fn decode_batch(
    model: &dyn Backend,
    prompts: &[Vec<i32>],
    max_new: &[usize],
    sampling: Sampling,
    rng: &mut Pcg,
) -> Result<Vec<Vec<i32>>> {
    let l = model.decode_window();
    let vocab = check_decode_shapes(model, prompts, max_new, l)?;
    let rows = prompts.len();
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); rows];
    let mut sessions: Vec<Option<DecodeSession>> = Vec::with_capacity(rows);
    let mut logits = Vec::new();

    // Prefill round: one session per request; sample its first token.
    // Row order matches the step rounds so the rng stream is identical to
    // the recompute loop's round-major order.
    let mut result = Ok(());
    for r in 0..rows {
        if max_new[r] == 0 {
            sessions.push(None);
            continue;
        }
        match model.decode_begin(&prompts[r], &mut logits) {
            Ok(sess) => {
                out[r].push(sample_token(&logits, sampling, rng));
                sessions.push(Some(sess));
            }
            Err(e) => {
                result = Err(e);
                break;
            }
        }
    }

    // Step rounds over the live rows — every round is one batched engine
    // call (`Backend::decode_step_batch`; the native engine stacks all
    // rows into one dense pass per block, other engines loop the serial
    // step). Sampling stays per row in row order, so the rng stream — and
    // therefore every token stream — is identical to the serial loop.
    let mut packed = Vec::new();
    while result.is_ok() {
        // Retire: budget exhausted or (prompt + generated) at the window
        // edge. The last sampled token needs no step.
        for r in 0..rows {
            if sessions[r].is_some()
                && (out[r].len() >= max_new[r] || prompts[r].len() + out[r].len() >= l)
            {
                model.decode_end(sessions[r].take().expect("session checked live"));
            }
        }
        // Gather the still-live rows, then shape the round: the engine
        // sees the rows sorted by history length (shortest first), so
        // same-length sessions sit adjacent in the batched pass and the
        // per-row O(t) dot work ramps monotonically across the round.
        // The sort key is (length, row), a strict total order — round
        // composition is deterministic regardless of arrival order.
        let mut live: Vec<(usize, &mut DecodeSession)> = Vec::new();
        for (r, slot) in sessions.iter_mut().enumerate() {
            if let Some(sess) = slot.as_mut() {
                live.push((r, sess));
            }
        }
        if live.is_empty() {
            break;
        }
        live.sort_by_key(|(r, sess)| (sess.len(), *r));
        let ix: Vec<usize> = live.iter().map(|(r, _)| *r).collect();
        let toks: Vec<i32> = ix
            .iter()
            .map(|&r| *out[r].last().expect("live row has a sampled token"))
            .collect();
        let results = {
            let mut refs: Vec<&mut DecodeSession> =
                live.into_iter().map(|(_, sess)| sess).collect();
            model.decode_step_batch(&mut refs, &toks, &mut packed)
        };
        // Sample in ascending *original* row order, not engine-row order:
        // the rng stream — and therefore every token stream — must be
        // identical whatever permutation the round shaping picked
        // (`sorted_rounds_keep_token_streams_identical` pins this).
        let mut order: Vec<usize> = (0..ix.len()).collect();
        order.sort_unstable_by_key(|&j| ix[j]);
        let mut results: Vec<Option<Result<()>>> =
            results.into_iter().map(Some).collect();
        for &j in &order {
            match results[j].take().expect("each engine row visited once") {
                Ok(()) => {
                    let row = &packed[j * vocab..(j + 1) * vocab];
                    out[ix[j]].push(sample_token(row, sampling, rng));
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
    }
    for sess in sessions.into_iter().flatten() {
        model.decode_end(sess);
    }
    result.map(|_| out)
}

/// Decode a *batch* of prompts by full-prefix recompute — the pre-streaming
/// reference path, kept for engines/tests/benches that want it explicitly.
///
/// Each round assembles the live rows at the current frontier length (the
/// longest sequence so far) and runs [`Backend::infer`], which rounds the
/// length up to the engine's smallest covering plan bucket — short prompts
/// are served at a fraction of the full-window cost and buckets grow as
/// the sequences lengthen. Rows shorter than the frontier are padded with
/// 0 inside the engine; causality guarantees pad positions after a row's
/// frontier cannot affect its next-token logits. Each row stops after its
/// own `max_new` tokens or at the model's window edge. Returns the
/// generated suffixes.
pub fn decode_batch_recompute(
    model: &dyn Backend,
    prompts: &[Vec<i32>],
    max_new: &[usize],
    sampling: Sampling,
    rng: &mut Pcg,
) -> Result<Vec<Vec<i32>>> {
    let l = model.manifest().seqlen()?;
    let v = check_decode_shapes(model, prompts, max_new, l)?;
    let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
    let rows = seqs.len();
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); rows];
    let max_rounds = max_new.iter().copied().max().unwrap_or(0);
    let mut toks: Vec<i32> = Vec::new();

    for _ in 0..max_rounds {
        // Compact to the live rows: finished sequences stop paying for
        // forward passes (infer takes an arbitrary row count).
        let live: Vec<usize> =
            (0..rows).filter(|&r| out[r].len() < max_new[r] && seqs[r].len() < l).collect();
        if live.is_empty() {
            break;
        }
        // Frontier length this round; the engine rounds it up to a bucket.
        let lcur = live.iter().map(|&r| seqs[r].len()).max().unwrap_or(0).min(l);
        toks.clear();
        toks.resize(live.len() * lcur, 0);
        for (i, &r) in live.iter().enumerate() {
            let n = seqs[r].len().min(lcur);
            toks[i * lcur..i * lcur + n].copy_from_slice(&seqs[r][..n]);
        }
        let logits = model.infer(&toks, live.len(), lcur)?;
        let lf = logits.as_f32()?;
        for (i, &r) in live.iter().enumerate() {
            let pos = seqs[r].len() - 1;
            let row = &lf[(i * lcur + pos) * v..(i * lcur + pos + 1) * v];
            let tok = sample_token(row, sampling, rng);
            seqs[r].push(tok);
            out[r].push(tok);
        }
    }
    Ok(out)
}

/// Per-position logits row accessor used by few-shot scoring: returns the
/// log-softmax score of `target` at position `pos` of row `r`. The exp sum
/// accumulates in f64 (f64-accumulation audit, DESIGN.md §Decode).
pub fn logprob_at(logits: &Tensor, r: usize, pos: usize, target: i32) -> Result<f32> {
    let shape = logits.shape();
    let (l, v) = (shape[1], shape[2]);
    let lf = logits.as_f32()?;
    let row = &lf[(r * l + pos) * v..(r * l + pos + 1) * v];
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = (mx as f64 + row.iter().map(|x| ((x - mx) as f64).exp()).sum::<f64>().ln()) as f32;
    Ok(row[target as usize] - lse)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let row = [0.1, 2.0, -1.0, 1.9];
        let mut rng = Pcg::new(0);
        assert_eq!(sample_token(&row, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let row = [0.0, 5.0, 0.0];
        let mut rng = Pcg::new(1);
        for _ in 0..50 {
            let t = sample_token(
                &row,
                Sampling::Temperature { t: 0.01, top_k: 0 },
                &mut rng,
            );
            assert_eq!(t, 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let row = [10.0, 9.0, -50.0, -50.0];
        let mut rng = Pcg::new(2);
        for _ in 0..100 {
            let t = sample_token(
                &row,
                Sampling::Temperature { t: 5.0, top_k: 2 },
                &mut rng,
            );
            assert!(t == 0 || t == 1, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn nan_logits_do_not_panic_and_never_win() {
        // Regression: the top-k path used `partial_cmp().unwrap()`, so a
        // single NaN logit panicked the serving worker.
        let row = [0.1, f32::NAN, 2.0, f32::NAN, -1.0];
        let mut rng = Pcg::new(7);
        assert_eq!(sample_token(&row, Sampling::Greedy, &mut rng), 2);
        for _ in 0..100 {
            let t = sample_token(&row, Sampling::Temperature { t: 1.0, top_k: 2 }, &mut rng);
            assert!(t == 0 || t == 2, "sampled a NaN slot: {t}");
        }
        // Non-finite-only rows fall back deterministically instead of
        // panicking in the weighted sampler.
        let bad = [f32::NAN, f32::NAN];
        assert_eq!(sample_token(&bad, Sampling::Greedy, &mut rng), 0);
        let _ = sample_token(&bad, Sampling::Temperature { t: 0.5, top_k: 1 }, &mut rng);
        // -inf stays sampleable territory for greedy (total_cmp orders it).
        let inf = [f32::NEG_INFINITY, 1.0];
        assert_eq!(sample_token(&inf, Sampling::Greedy, &mut rng), 1);
        // Greedy and temperature agree on a +inf-dominated row (temperature
        // degenerates to argmax instead of excluding the +inf slot).
        let pinf = [f32::INFINITY, 0.0];
        assert_eq!(sample_token(&pinf, Sampling::Greedy, &mut rng), 0);
        for _ in 0..20 {
            assert_eq!(
                sample_token(&pinf, Sampling::Temperature { t: 1.0, top_k: 0 }, &mut rng),
                0
            );
        }
        // All--inf rows degenerate deterministically too.
        let ninf = [f32::NEG_INFINITY, f32::NEG_INFINITY];
        let _ = sample_token(&ninf, Sampling::Temperature { t: 1.0, top_k: 0 }, &mut rng);
    }

    #[test]
    fn top_k_selection_matches_full_sort() {
        // The O(V) select_nth path must reproduce the old full-sort
        // truncation exactly — same survivors, same order — including on
        // rows with repeated logit values (ties break by ascending index
        // under the strict total order, matching the stable sort).
        let mut rng = Pcg::new(17);
        for case in 0..200 {
            let v = 2 + rng.usize_below(64);
            let row: Vec<f32> = (0..v)
                .map(|_| if rng.f32() < 0.3 { 1.0 } else { rng.normal() })
                .collect();
            let top_k = 1 + rng.usize_below(v);
            // Reference: the pre-PR-4 implementation (stable full sort by
            // logit descending, then truncate).
            let mut want: Vec<usize> = (0..v).filter(|&i| !row[i].is_nan()).collect();
            want.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
            want.truncate(top_k);
            // The shipped path, reproduced on the same support.
            let mut got: Vec<usize> = (0..v).filter(|&i| !row[i].is_nan()).collect();
            if top_k > 0 && top_k < got.len() {
                let by_logit_desc =
                    |a: &usize, b: &usize| row[*b].total_cmp(&row[*a]).then(a.cmp(b));
                got.select_nth_unstable_by(top_k - 1, by_logit_desc);
                got.truncate(top_k);
                got.sort_unstable_by(by_logit_desc);
            }
            assert_eq!(got, want, "case {case}: selection diverged (top_k={top_k})");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let row = [1.0, 0.9, 0.8, 0.7];
        let mut rng = Pcg::new(3);
        let mut seen = [false; 4];
        for _ in 0..300 {
            let t = sample_token(
                &row,
                Sampling::Temperature { t: 10.0, top_k: 0 },
                &mut rng,
            );
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
