//! Shared experiment-driver plumbing for the `examples/` binaries: train an
//! artifact on a batch source, evaluate, and time forward/train passes.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::trainer::{eval_accuracy, BatchSource, TrainReport, Trainer};
use crate::runtime::{ModelState, Tensor};
use crate::util::stats::Summary;

/// Train `artifact` for `steps` on `source`; returns the model + report.
pub fn train_artifact<S: BatchSource>(
    dir: &Path,
    seed: i32,
    mut source: S,
    steps: u64,
    quiet: bool,
) -> Result<(ModelState, TrainReport)> {
    let mut model = ModelState::load(dir, seed)?;
    let report = {
        let mut tr = Trainer::new(&mut model, || source.next_batch());
        tr.quiet = quiet;
        tr.run(steps)?
    };
    Ok((model, report))
}

/// Train then measure masked-position accuracy on fresh batches.
pub fn train_and_eval<S: BatchSource>(
    dir: &Path,
    seed: i32,
    mut source: S,
    steps: u64,
    eval_batches: usize,
    quiet: bool,
) -> Result<(f64, TrainReport)> {
    let (model, report) = train_artifact(dir, seed, || source.next_batch(), steps, quiet)?;
    let acc = eval_accuracy(&model, &mut || source.next_batch(), eval_batches)?;
    Ok((acc, report))
}

/// Wall-time a forward pass `iters` times after `warmup` runs.
pub fn bench_forward(
    model: &ModelState,
    inputs: &[Tensor],
    warmup: usize,
    iters: usize,
) -> Result<Summary> {
    for _ in 0..warmup {
        model.forward(inputs)?;
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        model.forward(inputs)?;
        s.push(t0.elapsed().as_secs_f64());
    }
    Ok(s)
}

/// Wall-time train steps.
pub fn bench_train_step<S: BatchSource>(
    model: &mut ModelState,
    source: &mut S,
    warmup: usize,
    iters: usize,
) -> Result<Summary> {
    for _ in 0..warmup {
        let b = source.next_batch();
        model.train_step(&b)?;
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let b = source.next_batch();
        let t0 = Instant::now();
        model.train_step(&b)?;
        s.push(t0.elapsed().as_secs_f64());
    }
    Ok(s)
}
