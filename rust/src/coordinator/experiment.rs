//! Shared experiment-driver plumbing for the `examples/` binaries: train a
//! model on a batch source, evaluate, and time forward/train passes.
//!
//! Models are addressed by artifact directory and constructed through
//! [`crate::backend`], so every driver honors `HYENA_BACKEND` and runs on
//! either engine (artifact dirs with compiled HLO select pjrt, everything
//! else the native backend).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::backend::{self, Backend, BackendKind};
use crate::coordinator::trainer::{eval_accuracy, BatchSource, TrainReport, Trainer};
use crate::runtime::Tensor;
use crate::util::stats::Summary;

/// Train the model at `dir` for `steps` on `source`; returns model + report.
pub fn train_artifact<S: BatchSource>(
    dir: &Path,
    seed: i32,
    mut source: S,
    steps: u64,
    quiet: bool,
) -> Result<(Box<dyn Backend>, TrainReport)> {
    let kind = BackendKind::detect(dir)?;
    let mut model = backend::load(kind, dir, seed)?;
    let report = {
        let mut tr = Trainer::new(model.as_mut(), || source.next_batch());
        tr.quiet = quiet;
        tr.run(steps)?
    };
    Ok((model, report))
}

/// Train then measure masked-position accuracy on fresh batches.
pub fn train_and_eval<S: BatchSource>(
    dir: &Path,
    seed: i32,
    mut source: S,
    steps: u64,
    eval_batches: usize,
    quiet: bool,
) -> Result<(f64, TrainReport)> {
    let (model, report) = train_artifact(dir, seed, || source.next_batch(), steps, quiet)?;
    let acc = eval_accuracy(model.as_ref(), &mut || source.next_batch(), eval_batches)?;
    Ok((acc, report))
}

/// Wall-time a forward pass `iters` times after `warmup` runs.
pub fn bench_forward(
    model: &dyn Backend,
    inputs: &[Tensor],
    warmup: usize,
    iters: usize,
) -> Result<Summary> {
    for _ in 0..warmup {
        model.forward(inputs)?;
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        model.forward(inputs)?;
        s.push(t0.elapsed().as_secs_f64());
    }
    Ok(s)
}

/// Wall-time train steps.
pub fn bench_train_step<S: BatchSource>(
    model: &mut dyn Backend,
    source: &mut S,
    warmup: usize,
    iters: usize,
) -> Result<Summary> {
    for _ in 0..warmup {
        let b = source.next_batch();
        model.train_step(&b)?;
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let b = source.next_batch();
        let t0 = Instant::now();
        model.train_step(&b)?;
        s.push(t0.elapsed().as_secs_f64());
    }
    Ok(s)
}
