//! Zero/few-shot evaluation harness (SuperGLUE stand-in, Tab. 4.5/4.6 —
//! substitution documented in DESIGN.md §3).
//!
//! Protocol mirrors the paper's: score each answer option by its total
//! log-probability given the prompt (logit scoring, as the paper uses for
//! WIC/CB/BoolQ), optionally prepending k solved demonstrations. Tasks are
//! built from the synthetic suite so pretrained TinyPile models can be
//! probed for in-context ability without external datasets.

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::generation::logprob_at;
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

/// One multiple-choice episode: prompt tokens + candidate answer tokens.
#[derive(Debug, Clone)]
pub struct Episode {
    pub prompt: Vec<i32>,
    pub options: Vec<Vec<i32>>,
    pub correct: usize,
}

/// Score one episode: pick the option with the highest mean token logprob.
/// Returns (chosen index, was_correct).
pub fn score_episode(model: &dyn Backend, ep: &Episode) -> Result<(usize, bool)> {
    let b = model.manifest().batch()?;
    let l = model.manifest().seqlen()?;
    let mut best = (f32::NEG_INFINITY, 0usize);
    for (oi, opt) in ep.options.iter().enumerate() {
        let mut seq = ep.prompt.clone();
        seq.extend_from_slice(opt);
        assert!(seq.len() <= l, "episode longer than model window");
        let mut toks = vec![0i32; b * l];
        toks[..seq.len()].copy_from_slice(&seq);
        let logits = model.forward(&[Tensor::from_i32(&[b, l], toks)?])?;
        let mut lp = 0.0f32;
        for (k, &tok) in opt.iter().enumerate() {
            let pos = ep.prompt.len() + k - 1; // logits at pos predict pos+1
            lp += logprob_at(&logits, 0, pos, tok)?;
        }
        let mean_lp = lp / opt.len() as f32;
        if mean_lp > best.0 {
            best = (mean_lp, oi);
        }
    }
    Ok((best.1, best.1 == ep.correct))
}

/// Build a k-shot episode by prepending `k` solved demonstrations of the
/// same task (episodes share the generator, not the instance).
pub fn with_shots(mut make: impl FnMut(&mut Pcg) -> Episode, k: usize, rng: &mut Pcg) -> Episode {
    let target = make(rng);
    let mut prompt = Vec::new();
    for _ in 0..k {
        let demo = make(rng);
        prompt.extend_from_slice(&demo.prompt);
        prompt.extend_from_slice(&demo.options[demo.correct]);
    }
    prompt.extend_from_slice(&target.prompt);
    Episode { prompt, options: target.options, correct: target.correct }
}

/// Evaluate accuracy over n episodes.
pub fn eval_episodes(
    model: &dyn Backend,
    mut make: impl FnMut(&mut Pcg) -> Episode,
    shots: usize,
    n: usize,
    rng: &mut Pcg,
) -> Result<f64> {
    let mut correct = 0usize;
    for _ in 0..n {
        let ep = with_shots(&mut make, shots, rng);
        if score_episode(model, &ep)?.1 {
            correct += 1;
        }
    }
    Ok(correct as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_shots_prepends_demos() {
        let mut rng = Pcg::new(0);
        let make = |_: &mut Pcg| Episode {
            prompt: vec![1, 2],
            options: vec![vec![3], vec![4]],
            correct: 1,
        };
        let ep = with_shots(make, 2, &mut rng);
        // two demos of (prompt + correct option) then the target prompt
        assert_eq!(ep.prompt, vec![1, 2, 4, 1, 2, 4, 1, 2]);
        assert_eq!(ep.correct, 1);
    }

    #[test]
    fn zero_shots_is_plain_episode() {
        let mut rng = Pcg::new(1);
        let make = |_: &mut Pcg| Episode {
            prompt: vec![9],
            options: vec![vec![1]],
            correct: 0,
        };
        let ep = with_shots(make, 0, &mut rng);
        assert_eq!(ep.prompt, vec![9]);
    }
}
