//! Dynamic batching policy for the inference server.
//!
//! Requests accumulate in a queue; a batch is released when either (a) the
//! batch is full (the compiled executable's static batch dimension), or
//! (b) the oldest queued request has waited `max_wait`. This is the standard
//! serving trade-off between padding waste and queueing latency; the policy
//! sweep is benchmarked in `benches/server.rs`.
//!
//! The **session-based** server (streaming decode, DESIGN.md §Decode) has
//! no shape coupling between co-resident requests — each session prefills
//! at its own prompt length and then steps one position at a time — so it
//! admits FIFO via `Batcher::take_up_to`: the release policy above decides
//! *when* the worker starts decoding from idle, and free capacity is
//! refilled continuously while sessions are in flight.
//!
//! `Batcher::take_batch_by_key` remains for whole-batch consumers (the
//! recompute decode path, `decode_batch_recompute`-style serving, or any
//! engine whose released batch must share one *shape bucket*): the oldest
//! request picks the bucket and the batch is filled with the queued
//! requests of that bucket in FIFO order, so a short prompt is never
//! padded to the full compiled length just because a long prompt was
//! queued beside it.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Decision state independent of I/O so the policy is unit/property-testable.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<(Instant, T)>,
    pub batch_size: usize,
    pub max_wait: Duration,
}

impl<T> Batcher<T> {
    pub fn new(batch_size: usize, max_wait: Duration) -> Self {
        assert!(batch_size > 0);
        Batcher { queue: VecDeque::new(), batch_size, max_wait }
    }

    pub fn push(&mut self, item: T) {
        self.queue.push_back((Instant::now(), item));
    }

    pub fn push_at(&mut self, at: Instant, item: T) {
        self.queue.push_back((at, item));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be released right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.batch_size {
            return true;
        }
        match self.queue.front() {
            Some((t, _)) => now.duration_since(*t) >= self.max_wait,
            None => false,
        }
    }

    /// Time until the deadline of the oldest request (for worker sleeps).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|(t, _)| {
            let elapsed = now.duration_since(*t);
            self.max_wait.saturating_sub(elapsed)
        })
    }

    /// Pop up to `n` requests, FIFO — the session server's admission path
    /// (capacity refill is `capacity − live_sessions`, not `batch_size`).
    pub fn take_up_to(&mut self, n: usize) -> Vec<T> {
        let k = self.queue.len().min(n);
        self.queue.drain(..k).map(|(_, x)| x).collect()
    }

    /// Pop up to `batch_size` requests, FIFO.
    pub fn take_batch(&mut self) -> Vec<T> {
        self.take_up_to(self.batch_size)
    }

    /// Pop every queued request whose *own* deadline (as exposed by
    /// `deadline_of`; `None` = never expires) has passed, preserving FIFO
    /// order among survivors and their original timestamps. The serving
    /// worker sweeps this between token rounds so a request that expired
    /// while waiting replies its deadline error immediately instead of
    /// being admitted to the engine (or worse, sitting behind a long
    /// decode until `max_wait` releases it).
    pub fn take_expired(
        &mut self,
        now: Instant,
        deadline_of: impl Fn(&T) -> Option<Instant>,
    ) -> Vec<T> {
        if self.queue.is_empty() {
            return Vec::new();
        }
        let mut expired = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(entry) = self.queue.pop_front() {
            match deadline_of(&entry.1) {
                Some(d) if now >= d => expired.push(entry.1),
                _ => rest.push_back(entry),
            }
        }
        self.queue = rest;
        expired
    }

    /// Pop up to `batch_size` requests that share the *oldest* request's
    /// key (its shape bucket), preserving FIFO order within the key.
    /// Requests with other keys keep their queue positions and timestamps,
    /// so `ready`'s deadline logic serves every bucket eventually.
    pub fn take_batch_by_key<K: Eq, F: Fn(&T) -> K>(&mut self, key: F) -> Vec<T> {
        let Some((_, front)) = self.queue.front() else {
            return Vec::new();
        };
        let k0 = key(front);
        let mut taken = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(entry) = self.queue.pop_front() {
            if taken.len() < self.batch_size && key(&entry.1) == k0 {
                taken.push(entry.1);
            } else {
                rest.push_back(entry);
            }
        }
        self.queue = rest;
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn releases_on_full_batch() {
        let mut b = Batcher::new(4, Duration::from_secs(60));
        let now = Instant::now();
        for i in 0..4 {
            b.push_at(now, i);
        }
        assert!(b.ready(now));
        assert_eq!(b.take_batch(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push_at(t0, 1u32);
        assert!(!b.ready(t0));
        assert!(b.ready(t0 + Duration::from_millis(11)));
        assert_eq!(b.take_batch(), vec![1]);
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<u32> = Batcher::new(2, Duration::from_millis(0));
        assert!(!b.ready(Instant::now()));
        assert_eq!(b.time_to_deadline(Instant::now()), None);
    }

    #[test]
    fn take_up_to_respects_the_cap_and_fifo() {
        let mut b = Batcher::new(8, Duration::from_secs(1));
        let now = Instant::now();
        for i in 0..5 {
            b.push_at(now, i);
        }
        assert_eq!(b.take_up_to(2), vec![0, 1]);
        assert_eq!(b.take_up_to(0), Vec::<i32>::new());
        assert_eq!(b.take_up_to(99), vec![2, 3, 4]);
        assert!(b.is_empty());
    }

    #[test]
    fn take_batch_caps_at_batch_size() {
        let mut b = Batcher::new(3, Duration::from_secs(1));
        let now = Instant::now();
        for i in 0..7 {
            b.push_at(now, i);
        }
        assert_eq!(b.take_batch(), vec![0, 1, 2]);
        assert_eq!(b.len(), 4);
    }

    /// Property: across any push/take interleavings, no request is lost or
    /// duplicated, and batches preserve FIFO order.
    #[test]
    fn prop_no_loss_no_duplication_fifo() {
        Prop::new("batcher conservation").cases(200).check(|rng| {
            let bs = 1 + rng.usize_below(6);
            let mut b = Batcher::new(bs, Duration::from_secs(60));
            let now = Instant::now();
            let total = 1 + rng.usize_below(40);
            let mut pushed = 0u32;
            let mut popped: Vec<u32> = Vec::new();
            while popped.len() < total {
                if pushed < total as u32 && rng.f32() < 0.6 {
                    b.push_at(now, pushed);
                    pushed += 1;
                } else if !b.is_empty() {
                    let batch = b.take_batch();
                    prop_assert!(batch.len() <= bs, "batch over size");
                    popped.extend(batch);
                } else if pushed >= total as u32 {
                    break;
                }
            }
            popped.extend(b.take_batch());
            while !b.is_empty() {
                popped.extend(b.take_batch());
            }
            let want: Vec<u32> = (0..pushed).collect();
            prop_assert!(popped == want, "lost/dup/reorder: {popped:?}");
            Ok(())
        });
    }

    #[test]
    fn take_by_key_groups_the_oldest_bucket() {
        let mut b = Batcher::new(3, Duration::from_secs(1));
        let now = Instant::now();
        // (id, bucket): oldest is bucket 8.
        for item in [(0u32, 8usize), (1, 16), (2, 8), (3, 8), (4, 16), (5, 8)] {
            b.push_at(now, item);
        }
        let batch = b.take_batch_by_key(|x| x.1);
        assert_eq!(batch, vec![(0, 8), (2, 8), (3, 8)], "bucket-8 FIFO, capped at 3");
        // The other bucket (and the bucket-8 overflow) kept its order.
        let batch = b.take_batch_by_key(|x| x.1);
        assert_eq!(batch, vec![(1, 16), (4, 16)]);
        let batch = b.take_batch_by_key(|x| x.1);
        assert_eq!(batch, vec![(5, 8)]);
        assert!(b.is_empty());
        assert!(b.take_batch_by_key(|x| x.1).is_empty());
    }

    /// Property: bucketed draining loses/duplicates nothing, every released
    /// batch is single-bucket, and order within a bucket is FIFO.
    #[test]
    fn prop_take_by_key_conserves_and_is_homogeneous() {
        Prop::new("bucketed batcher conservation").cases(200).check(|rng| {
            let bs = 1 + rng.usize_below(5);
            let mut b = Batcher::new(bs, Duration::from_secs(60));
            let now = Instant::now();
            let total = 1 + rng.usize_below(40);
            let items: Vec<(u32, usize)> =
                (0..total).map(|i| (i as u32, [8usize, 16, 32][rng.usize_below(3)])).collect();
            for &it in &items {
                b.push_at(now, it);
            }
            let mut popped: Vec<(u32, usize)> = Vec::new();
            while !b.is_empty() {
                let batch = b.take_batch_by_key(|x| x.1);
                prop_assert!(!batch.is_empty(), "ready queue released nothing");
                prop_assert!(batch.len() <= bs, "batch over size");
                prop_assert!(
                    batch.iter().all(|x| x.1 == batch[0].1),
                    "mixed buckets in one batch: {batch:?}"
                );
                popped.extend(batch);
            }
            prop_assert!(popped.len() == items.len(), "lost/duplicated requests");
            for bucket in [8usize, 16, 32] {
                let want: Vec<u32> =
                    items.iter().filter(|x| x.1 == bucket).map(|x| x.0).collect();
                let got: Vec<u32> =
                    popped.iter().filter(|x| x.1 == bucket).map(|x| x.0).collect();
                prop_assert!(got == want, "bucket {bucket} reordered: {got:?} vs {want:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn take_expired_sweeps_only_the_dead() {
        let mut b = Batcher::new(8, Duration::from_secs(60));
        let now = Instant::now();
        // (id, deadline): 1 and 3 are expired, 2 has no deadline.
        let items = [
            (0u32, Some(now + Duration::from_secs(5))),
            (1, Some(now - Duration::from_millis(1))),
            (2, None),
            (3, Some(now)),
        ];
        for &it in &items {
            b.push_at(now, it);
        }
        let dead = b.take_expired(now, |x: &(u32, Option<Instant>)| x.1);
        assert_eq!(dead.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 3]);
        // Survivors keep FIFO order.
        assert_eq!(b.take_up_to(9).iter().map(|x| x.0).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn take_expired_on_empty_is_a_noop() {
        let mut b: Batcher<(u32, Option<Instant>)> = Batcher::new(2, Duration::from_secs(1));
        assert!(b.take_expired(Instant::now(), |x| x.1).is_empty());
    }

    /// Property: expired + survivors == pushed (conservation), every swept
    /// item really was expired, no survivor was, and survivor order is
    /// FIFO — across arbitrary deadline assignments.
    #[test]
    fn prop_take_expired_conserves_and_partitions() {
        Prop::new("take_expired partition").cases(200).check(|rng| {
            let mut b = Batcher::new(4, Duration::from_secs(60));
            let now = Instant::now();
            let total = 1 + rng.usize_below(40);
            let items: Vec<(u32, Option<Instant>)> = (0..total)
                .map(|i| {
                    let dl = match rng.usize_below(3) {
                        0 => None,
                        1 => Some(now - Duration::from_millis(1 + rng.below(50) as u64)),
                        _ => Some(now + Duration::from_millis(1 + rng.below(50) as u64)),
                    };
                    (i as u32, dl)
                })
                .collect();
            for &it in &items {
                b.push_at(now, it);
            }
            let dead = b.take_expired(now, |x: &(u32, Option<Instant>)| x.1);
            let alive = b.take_up_to(total);
            prop_assert!(dead.len() + alive.len() == total, "lost/duplicated");
            prop_assert!(
                dead.iter().all(|x| x.1.is_some_and(|d| now >= d)),
                "swept a live request"
            );
            prop_assert!(
                alive.iter().all(|x| x.1.map_or(true, |d| now < d)),
                "kept an expired request"
            );
            let want: Vec<u32> = items
                .iter()
                .filter(|x| x.1.map_or(true, |d| now < d))
                .map(|x| x.0)
                .collect();
            let got: Vec<u32> = alive.iter().map(|x| x.0).collect();
            prop_assert!(got == want, "survivors reordered: {got:?} vs {want:?}");
            Ok(())
        });
    }

    /// Property: `ready` is monotone in time — once ready (with no queue
    /// change), it stays ready.
    #[test]
    fn prop_ready_monotone() {
        Prop::new("ready monotone").cases(100).check(|rng| {
            let mut b = Batcher::new(4, Duration::from_millis(rng.u64_wait()));
            let t0 = Instant::now();
            b.push_at(t0, 0u8);
            let d1 = Duration::from_millis(rng.below(100) as u64);
            let d2 = d1 + Duration::from_millis(rng.below(100) as u64);
            let r1 = b.ready(t0 + d1);
            let r2 = b.ready(t0 + d2);
            prop_assert!(!r1 || r2, "ready regressed");
            Ok(())
        });
    }
}

#[cfg(test)]
impl crate::util::rng::Pcg {
    fn u64_wait(&mut self) -> u64 {
        self.below(50) as u64
    }
}
