//! Synthetic-10 image dataset (ImageNet/CIFAR stand-in for Tab. 4.7 —
//! substitution table in DESIGN.md §3).
//!
//! Ten parametric pattern classes over single-channel images with additive
//! noise, random phase/offsets and per-image gain so the task needs shape
//! (not trivial pixel statistics): 0–3 oriented gratings at four angles,
//! 4 checkerboard, 5 radial rings, 6 center blob, 7 corner gradient,
//! 8 horizontal ramp + stripes, 9 noise-only texture.

use crate::runtime::Tensor;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct ImageTask {
    pub size: usize,
    pub batch: usize,
    pub noise: f32,
}

impl ImageTask {
    pub fn new(size: usize, batch: usize) -> Self {
        ImageTask { size, batch, noise: 0.25 }
    }

    pub fn render(&self, class: usize, rng: &mut Pcg) -> Vec<f32> {
        let n = self.size;
        let phase = rng.f32() * std::f32::consts::TAU;
        let freq = 0.5 + rng.f32() * 0.5;
        let gain = 0.7 + rng.f32() * 0.6;
        let mut img = vec![0.0f32; n * n];
        for y in 0..n {
            for x in 0..n {
                let (xf, yf) = (x as f32 / n as f32, y as f32 / n as f32);
                let v = match class {
                    0..=3 => {
                        // gratings at 0°, 45°, 90°, 135°
                        let ang = class as f32 * std::f32::consts::PI / 4.0;
                        let proj = xf * ang.cos() + yf * ang.sin();
                        (proj * freq * 14.0 + phase).sin()
                    }
                    4 => {
                        let k = (2.0 + freq * 4.0) as usize + 2;
                        if ((x * k / n) + (y * k / n)) % 2 == 0 {
                            1.0
                        } else {
                            -1.0
                        }
                    }
                    5 => {
                        let r = ((xf - 0.5).powi(2) + (yf - 0.5).powi(2)).sqrt();
                        (r * freq * 40.0 + phase).sin()
                    }
                    6 => {
                        let r2 = (xf - 0.5).powi(2) + (yf - 0.5).powi(2);
                        (-(r2) * (8.0 + 8.0 * freq)).exp() * 2.0 - 1.0
                    }
                    7 => (xf + yf) - 1.0,
                    8 => (xf * 2.0 - 1.0) + 0.5 * (yf * freq * 25.0 + phase).sin(),
                    _ => 0.0,
                };
                img[y * n + x] = gain * v + self.noise * rng.normal();
            }
        }
        img
    }

    /// Batch in img train_step layout: `[images (B,H,W) f32, labels (B) i32]`.
    pub fn sample_batch(&self, rng: &mut Pcg) -> Vec<Tensor> {
        let n = self.size;
        let mut images = Vec::with_capacity(self.batch * n * n);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let class = rng.usize_below(10);
            images.extend(self.render(class, rng));
            labels.push(class as i32);
        }
        vec![
            Tensor::from_f32(&[self.batch, n, n], images).unwrap(),
            Tensor::from_i32(&[self.batch], labels).unwrap(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let t = ImageTask::new(16, 4);
        let mut rng = Pcg::new(0);
        let b = t.sample_batch(&mut rng);
        assert_eq!(b[0].shape(), &[4, 16, 16]);
        assert_eq!(b[1].shape(), &[4]);
        assert!(b[1].as_i32().unwrap().iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean inter-class L2 distance should exceed intra-class distance.
        let t = ImageTask { size: 16, batch: 1, noise: 0.1 };
        let mut rng = Pcg::new(1);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n_intra = 0;
        let mut n_inter = 0;
        let renders: Vec<Vec<Vec<f32>>> = (0..5)
            .map(|c| (0..4).map(|_| t.render(c, &mut rng)).collect())
            .collect();
        for c1 in 0..5 {
            for i in 0..4 {
                for c2 in 0..5 {
                    for j in 0..4 {
                        if c1 == c2 && i < j {
                            intra += dist(&renders[c1][i], &renders[c2][j]);
                            n_intra += 1;
                        } else if c1 < c2 {
                            inter += dist(&renders[c1][i], &renders[c2][j]);
                            n_inter += 1;
                        }
                    }
                }
            }
        }
        assert!(inter / n_inter as f32 > 0.8 * intra / n_intra as f32);
    }

    #[test]
    fn finite_pixels() {
        let t = ImageTask::new(8, 2);
        let mut rng = Pcg::new(2);
        let b = t.sample_batch(&mut rng);
        assert!(b[0].as_f32().unwrap().iter().all(|p| p.is_finite()));
    }
}
