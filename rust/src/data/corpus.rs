//! TinyPile: a deterministic synthetic text corpus (The Pile / WikiText
//! stand-in — substitution table in DESIGN.md §3).
//!
//! Built so the statistics that separate architectures in the paper are
//! present at laptop scale:
//!  * Zipfian unigram distribution over a generated lexicon (natural-language
//!    word statistics),
//!  * Markov bigram structure (local predictability → baseline compressible),
//!  * **induction structure**: each document introduces named entities that
//!    recur throughout it, so models with working recall (induction heads /
//!    data-controlled gating) achieve strictly lower loss — the mechanism
//!    App. C links to Pile perplexity rank.

use crate::tokenizer::CharTokenizer;
use crate::util::rng::{Pcg, Zipf};

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub lexicon_size: usize,
    pub zipf_exponent: f32,
    pub doc_len_words: usize,
    pub entities_per_doc: usize,
    /// Probability that the next word is a recurring entity mention.
    pub entity_rate: f32,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            lexicon_size: 2000,
            zipf_exponent: 1.05,
            doc_len_words: 180,
            entities_per_doc: 3,
            entity_rate: 0.12,
            seed: 0,
        }
    }
}

/// A generated corpus: token ids (char-level) split into train/val streams.
pub struct Corpus {
    pub train: Vec<i32>,
    pub val: Vec<i32>,
}

/// Deterministic pseudo-word: CV syllables keyed by lexicon index.
fn make_word(idx: usize) -> String {
    const C: &[u8] = b"bcdfghjklmnprstvwz";
    const V: &[u8] = b"aeiou";
    let mut x = idx as u64 * 2654435761 + 12345;
    let syllables = 1 + (idx % 3);
    let mut w = String::new();
    for _ in 0..=syllables {
        w.push(C[(x % C.len() as u64) as usize] as char);
        x /= C.len() as u64;
        w.push(V[(x % V.len() as u64) as usize] as char);
        x = x / V.len() as u64 + 17 + idx as u64;
    }
    w
}

/// Entity names are capitalized rare words — visually distinct, and their
/// repetitions inside a document are the recall signal.
fn make_entity(idx: usize) -> String {
    let mut w = make_word(5000 + idx * 7);
    w.get_mut(0..1).map(|s| s.make_ascii_uppercase());
    let mut chars: Vec<char> = w.chars().collect();
    chars[0] = chars[0].to_ascii_uppercase();
    chars.into_iter().collect()
}

pub fn generate(cfg: &CorpusConfig, total_docs: usize) -> Corpus {
    let tok = CharTokenizer::new();
    let zipf = Zipf::new(cfg.lexicon_size, cfg.zipf_exponent);
    let mut rng = Pcg::with_stream(cfg.seed, 0x71e_ba5e);
    // First-order Markov chain over lexicon "topics": each word biases the
    // next toward a deterministic successor set.
    let mut text = String::new();
    let mut docs: Vec<String> = Vec::with_capacity(total_docs);
    for _ in 0..total_docs {
        let mut doc = String::new();
        let entities: Vec<String> = (0..cfg.entities_per_doc)
            .map(|_| make_entity(rng.usize_below(300)))
            .collect();
        // Topic chain state.
        let mut prev = zipf.sample(&mut rng);
        let mut sentence_len = 0usize;
        for _ in 0..cfg.doc_len_words {
            let word = if rng.f32() < cfg.entity_rate {
                entities[rng.usize_below(entities.len())].clone()
            } else {
                // 50%: Markov successor of prev; 50%: fresh Zipf draw.
                let idx = if rng.f32() < 0.5 {
                    (prev.wrapping_mul(31).wrapping_add(7)) % cfg.lexicon_size
                } else {
                    zipf.sample(&mut rng)
                };
                prev = idx;
                make_word(idx)
            };
            doc.push_str(&word);
            sentence_len += 1;
            if sentence_len >= 8 + rng.usize_below(9) {
                doc.push_str(". ");
                sentence_len = 0;
            } else {
                doc.push(' ');
            }
        }
        doc.push('\n');
        docs.push(doc);
    }
    for d in &docs {
        text.push_str(d);
    }
    let ids = tok.encode(&text);
    // 95/5 train/val split on document boundary-ish offsets.
    let split = ids.len() * 95 / 100;
    Corpus { train: ids[..split].to_vec(), val: ids[split..].to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let cfg = CorpusConfig { seed: 3, ..Default::default() };
        let a = generate(&cfg, 5);
        let b = generate(&cfg, 5);
        assert_eq!(a.train, b.train);
        let c = generate(&CorpusConfig { seed: 4, ..Default::default() }, 5);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn tokens_in_char_vocab() {
        let corpus = generate(&CorpusConfig::default(), 3);
        assert!(corpus.train.iter().all(|&t| (0..96).contains(&t)));
        assert!(!corpus.val.is_empty());
    }

    #[test]
    fn has_zipfian_skew() {
        // The most common word should be much more frequent than the median.
        let corpus = generate(&CorpusConfig::default(), 20);
        let tok = CharTokenizer::new();
        let text = tok.decode(&corpus.train);
        let mut counts = std::collections::HashMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w.trim_matches('.')).or_insert(0usize) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().cloned().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] >= 8 * freqs[freqs.len() / 2], "no skew: {:?}", &freqs[..5]);
    }

    #[test]
    fn entities_recur_within_docs() {
        let cfg = CorpusConfig::default();
        let corpus = generate(&cfg, 4);
        let tok = CharTokenizer::new();
        let text = tok.decode(&corpus.train);
        // Capitalized pseudo-words should appear multiple times.
        let mut caps = std::collections::HashMap::new();
        for w in text.split_whitespace() {
            let w = w.trim_matches('.');
            if w.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                *caps.entry(w.to_string()).or_insert(0usize) += 1;
            }
        }
        assert!(caps.values().any(|&c| c >= 4), "entities never recur");
    }
}
