//! Batch assembly over a token stream (LM pretraining data pipeline).

use crate::runtime::Tensor;
use crate::util::rng::Pcg;

/// Samples random windows from a token stream and assembles LM batches
/// in train_step layout `[tokens, targets, mask]` with mask ≡ 1.
pub struct LmBatches<'a> {
    stream: &'a [i32],
    batch: usize,
    seqlen: usize,
    /// Tokens are folded into [0, vocab) (models with fewer embedding slots
    /// than the tokenizer's 96 still train on a well-formed stream).
    vocab: i32,
    rng: Pcg,
}

impl<'a> LmBatches<'a> {
    pub fn new(stream: &'a [i32], batch: usize, seqlen: usize, seed: u64) -> Self {
        assert!(
            stream.len() > seqlen + 1,
            "stream too short: {} <= {}",
            stream.len(),
            seqlen + 1
        );
        LmBatches { stream, batch, seqlen, vocab: i32::MAX, rng: Pcg::with_stream(seed, 0xda7a) }
    }

    /// Restrict emitted token ids to [0, vocab).
    pub fn with_vocab(mut self, vocab: usize) -> Self {
        self.vocab = vocab as i32;
        self
    }

    pub fn next_batch(&mut self) -> Vec<Tensor> {
        let (b, l) = (self.batch, self.seqlen);
        let mut tokens = Vec::with_capacity(b * l);
        let mut targets = Vec::with_capacity(b * l);
        for _ in 0..b {
            let start = self.rng.usize_below(self.stream.len() - l - 1);
            tokens.extend(self.stream[start..start + l].iter().map(|&t| t % self.vocab));
            targets.extend(
                self.stream[start + 1..start + l + 1].iter().map(|&t| t % self.vocab),
            );
        }
        vec![
            Tensor::from_i32(&[b, l], tokens).unwrap(),
            Tensor::from_i32(&[b, l], targets).unwrap(),
            Tensor::from_f32(&[b, l], vec![1.0; b * l]).unwrap(),
        ]
    }

    /// Deterministic sequential batches for eval (fixed coverage, no overlap).
    pub fn eval_batches(stream: &'a [i32], batch: usize, seqlen: usize) -> Vec<Vec<Tensor>> {
        Self::eval_batches_vocab(stream, batch, seqlen, usize::MAX)
    }

    /// Deterministic sequential eval batches with vocabulary folding.
    pub fn eval_batches_vocab(
        stream: &'a [i32],
        batch: usize,
        seqlen: usize,
        vocab: usize,
    ) -> Vec<Vec<Tensor>> {
        let vm = vocab.min(i32::MAX as usize) as i32;
        let mut out = Vec::new();
        let mut offset = 0;
        loop {
            let need = batch * (seqlen + 1);
            if offset + need > stream.len() {
                break;
            }
            let mut tokens = Vec::with_capacity(batch * seqlen);
            let mut targets = Vec::with_capacity(batch * seqlen);
            for r in 0..batch {
                let s = offset + r * (seqlen + 1);
                tokens.extend(stream[s..s + seqlen].iter().map(|&t| t % vm));
                targets.extend(stream[s + 1..s + seqlen + 1].iter().map(|&t| t % vm));
            }
            out.push(vec![
                Tensor::from_i32(&[batch, seqlen], tokens).unwrap(),
                Tensor::from_i32(&[batch, seqlen], targets).unwrap(),
                Tensor::from_f32(&[batch, seqlen], vec![1.0; batch * seqlen]).unwrap(),
            ]);
            offset += need;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn targets_shift_tokens_by_one() {
        let s = stream(1000);
        let mut b = LmBatches::new(&s, 2, 16, 0);
        let batch = b.next_batch();
        let toks = batch[0].as_i32().unwrap();
        let tgts = batch[1].as_i32().unwrap();
        for r in 0..2 {
            for i in 0..15 {
                assert_eq!(tgts[r * 16 + i], toks[r * 16 + i + 1]);
            }
            assert_eq!(tgts[r * 16 + 15], toks[r * 16 + 15] + 1);
        }
    }

    #[test]
    fn mask_all_ones() {
        let s = stream(100);
        let mut b = LmBatches::new(&s, 1, 8, 1);
        let batch = b.next_batch();
        assert!(batch[2].as_f32().unwrap().iter().all(|&m| m == 1.0));
    }

    #[test]
    fn deterministic_in_seed() {
        let s = stream(500);
        let a = LmBatches::new(&s, 2, 8, 7).next_batch();
        let b = LmBatches::new(&s, 2, 8, 7).next_batch();
        assert_eq!(a[0].as_i32().unwrap(), b[0].as_i32().unwrap());
    }

    #[test]
    fn eval_batches_cover_disjoint_windows() {
        let s = stream(100);
        let evs = LmBatches::eval_batches(&s, 2, 10);
        assert!(!evs.is_empty());
        // sequential, non-overlapping coverage
        let first = evs[0][0].as_i32().unwrap()[0];
        assert_eq!(first, 0);
        if evs.len() > 1 {
            let second_start = evs[1][0].as_i32().unwrap()[0];
            assert_eq!(second_start, 22); // 2 rows × (10+1)
        }
    }

    #[test]
    #[should_panic(expected = "stream too short")]
    fn rejects_short_stream() {
        let s = stream(5);
        LmBatches::new(&s, 1, 8, 0);
    }
}
