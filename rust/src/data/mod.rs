//! Data substrates: TinyPile corpus, LM batch pipeline, synthetic images.
pub mod corpus;
pub mod dataset;
pub mod images;
