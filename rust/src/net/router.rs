//! Replica-parallel serving: a multi-process worker fleet behind a
//! least-loaded, session-affine router.
//!
//! Two halves, one wire protocol:
//!
//! * [`ReplicaServer`] — the worker side. Wraps one in-process session
//!   engine ([`ServerHandle`]) in a lean framed-RPC loop over local TCP:
//!   newline-delimited JSON frames, parsed with the same incremental
//!   [`JsonReader`] the HTTP front end uses. One connection carries one
//!   request at a time (the router opens a connection per admitted
//!   stream), so a dropped connection maps 1:1 to a retired session.
//!
//! * [`FleetHandle`] — the router side. Implements [`Engine`], so the
//!   HTTP front end (`net::server`) serves a fleet exactly as it serves
//!   one in-process worker. Fresh prompts go to the live replica with
//!   the fewest inflight requests (ties break by id); requests carrying
//!   a `session` key pin to the replica already holding that session's
//!   decode state — affinity beats balancing, because decode state is
//!   replica-resident and cannot be moved.
//!
//! Weight updates are epoch-synchronized: [`FleetHandle::broadcast_params`]
//! gates admission to each replica, pushes the new tensors, and ungates
//! only on an epoch ack. A replica that misses the broadcast reports a
//! stale `params_epoch` on its next health probe and is kept out of the
//! candidate set until re-broadcast — a stale replica never serves
//! mixed-epoch tokens. Within a replica, the existing serve-state epoch
//! invalidation refuses stale decode sessions, so both layers agree.
//!
//! Failure handling: health probes mark replicas down after consecutive
//! probe failures and back up when they recover; a replica that dies
//! before its stream produced any token is retried on a peer (prompt
//! re-prefill — cheap, nothing was delivered); one that dies mid-stream
//! surfaces a clean [`StreamEvent::Error`] (tokens already sent cannot
//! be unsent, and decode state died with the replica).

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::MemReport;
use crate::coordinator::server::{
    AdmitError, DrainReport, Engine, GenerateRequest, GenerateResponse, ServerHandle, StreamEvent,
    StreamSubmission,
};
use crate::coordinator::generation::Sampling;
use crate::net::jsonrd::{Frame, JsonReader};
use crate::obs::{self, trace};
use crate::runtime::Tensor;
use crate::util::json::Json;

/// Frame-size cap on replica connections. Parameter broadcasts ship full
/// model tensors as JSON, so this is far above the HTTP body cap.
const FRAME_CAP: usize = 64 << 20;

/// Consecutive probe failures before a live replica is marked down.
const MARK_DOWN_FAILS: usize = 2;

// ---------------------------------------------------------------------------
// Frame I/O
// ---------------------------------------------------------------------------

/// Write one newline-delimited JSON frame.
fn write_frame(stream: &mut TcpStream, v: &Json) -> io::Result<()> {
    let mut s = v.to_string();
    s.push('\n');
    stream.write_all(s.as_bytes())
}

/// Read one JSON frame, first draining any bytes the reader retained
/// past the previous frame, then pulling from the socket.
fn read_frame(stream: &mut TcpStream, rd: &mut JsonReader) -> io::Result<Json> {
    match rd.feed(&[]) {
        Ok(Frame::Complete(v)) => return Ok(v),
        Ok(Frame::Incomplete) => {}
        Err(e) => return Err(io::Error::new(io::ErrorKind::InvalidData, e.to_string())),
    }
    let mut buf = [0u8; 8192];
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "peer closed mid-frame",
            ));
        }
        match rd.feed(&buf[..n]) {
            Ok(Frame::Complete(v)) => return Ok(v),
            Ok(Frame::Incomplete) => {}
            Err(e) => return Err(io::Error::new(io::ErrorKind::InvalidData, e.to_string())),
        }
    }
}

/// Terminal error frame on a `gen` stream.
fn ev_err(message: &str, partial: usize) -> Json {
    Json::obj(vec![
        ("ev", Json::str("err")),
        ("message", Json::str(message)),
        ("partial", Json::num(partial as f64)),
    ])
}

// ---------------------------------------------------------------------------
// MemReport <-> JSON (every field — the fleet aggregates real reports)
// ---------------------------------------------------------------------------

fn usizes_to_json(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn u64s_to_json(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn mem_to_json(m: &MemReport) -> Json {
    Json::obj(vec![
        ("train_arena_hiwater_bytes", Json::num(m.train_arena_hiwater_bytes as f64)),
        ("train_arena_allocs", Json::num(m.train_arena_allocs as f64)),
        ("serve_arena_hiwater_bytes", Json::num(m.serve_arena_hiwater_bytes as f64)),
        ("serve_arena_allocs", Json::num(m.serve_arena_allocs as f64)),
        ("serve_spec_bytes", Json::num(m.serve_spec_bytes as f64)),
        ("serve_forwards", Json::num(m.serve_forwards as f64)),
        ("bucket_lens", usizes_to_json(&m.bucket_lens)),
        ("bucket_hits", u64s_to_json(&m.bucket_hits)),
        ("decode_sessions_live", Json::num(m.decode_sessions_live as f64)),
        ("decode_sessions_total", Json::num(m.decode_sessions_total as f64)),
        ("decode_steps", Json::num(m.decode_steps as f64)),
        ("decode_step_batches", Json::num(m.decode_step_batches as f64)),
        ("decode_step_batch_rows", Json::num(m.decode_step_batch_rows as f64)),
        ("decode_state_bytes", Json::num(m.decode_state_bytes as f64)),
        ("kernel", Json::str(&m.kernel)),
        ("max_context", Json::num(m.max_context as f64)),
        ("ext_bucket_lens", usizes_to_json(&m.ext_bucket_lens)),
        ("prefill_chunked", Json::num(m.prefill_chunked as f64)),
        ("prefill_chunks", Json::num(m.prefill_chunks as f64)),
        ("prefill_chunk_bytes", Json::num(m.prefill_chunk_bytes as f64)),
        ("params_epoch", Json::num(m.params_epoch as f64)),
    ])
}

fn mem_from_json(v: &Json) -> MemReport {
    let n = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
    let us = |k: &str| -> Vec<usize> {
        v.get(k)
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|e| e.as_f64()).map(|f| f as usize).collect())
            .unwrap_or_default()
    };
    let u64s = |k: &str| -> Vec<u64> {
        v.get(k)
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|e| e.as_f64()).map(|f| f as u64).collect())
            .unwrap_or_default()
    };
    MemReport {
        train_arena_hiwater_bytes: n("train_arena_hiwater_bytes") as usize,
        train_arena_allocs: n("train_arena_allocs") as u64,
        serve_arena_hiwater_bytes: n("serve_arena_hiwater_bytes") as usize,
        serve_arena_allocs: n("serve_arena_allocs") as u64,
        serve_spec_bytes: n("serve_spec_bytes") as usize,
        serve_forwards: n("serve_forwards") as u64,
        bucket_lens: us("bucket_lens"),
        bucket_hits: u64s("bucket_hits"),
        decode_sessions_live: n("decode_sessions_live") as u64,
        decode_sessions_total: n("decode_sessions_total") as u64,
        decode_steps: n("decode_steps") as u64,
        decode_step_batches: n("decode_step_batches") as u64,
        decode_step_batch_rows: n("decode_step_batch_rows") as u64,
        decode_state_bytes: n("decode_state_bytes") as usize,
        kernel: v.get("kernel").and_then(|x| x.as_str()).unwrap_or("").to_string(),
        max_context: n("max_context") as usize,
        ext_bucket_lens: us("ext_bucket_lens"),
        prefill_chunked: n("prefill_chunked") as u64,
        prefill_chunks: n("prefill_chunks") as u64,
        prefill_chunk_bytes: n("prefill_chunk_bytes") as usize,
        params_epoch: n("params_epoch") as u64,
    }
}

// ---------------------------------------------------------------------------
// Parameter tensors <-> JSON (f32 -> f64 -> f32 is bitwise-exact, so a
// broadcast replica serves the same weights the router holds)
// ---------------------------------------------------------------------------

fn params_to_json(params: &[Tensor]) -> Result<Json> {
    let mut arr = Vec::with_capacity(params.len());
    for t in params {
        let data = t.as_f32().context("parameter tensor is not f32")?;
        arr.push(Json::obj(vec![
            ("shape", usizes_to_json(t.shape())),
            ("data", Json::Arr(data.iter().map(|&x| Json::num(x as f64)).collect())),
        ]));
    }
    Ok(Json::Arr(arr))
}

fn parse_params(req: &Json) -> Result<Vec<Tensor>> {
    let arr = req
        .get("params")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| anyhow!("set_params frame missing `params` array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let shape: Vec<usize> = t
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("param {i}: missing `shape`"))?
            .iter()
            .map(|e| e.as_f64().map(|f| f as usize).ok_or_else(|| anyhow!("param {i}: bad shape")))
            .collect::<Result<_>>()?;
        let data: Vec<f32> = t
            .get("data")
            .and_then(|d| d.as_arr())
            .ok_or_else(|| anyhow!("param {i}: missing `data`"))?
            .iter()
            .map(|e| e.as_f64().map(|f| f as f32).ok_or_else(|| anyhow!("param {i}: bad data")))
            .collect::<Result<_>>()?;
        out.push(Tensor::from_f32(&shape, data)?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Replica side: framed RPC over one engine
// ---------------------------------------------------------------------------

/// One worker's RPC endpoint: accepts router connections and serves the
/// frame ops (`gen`, `health`, `mem`, `metrics`, `set_params`, `drain`)
/// against a single in-process engine.
pub struct ReplicaServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<(u64, TcpStream)>>>,
    accept: Option<JoinHandle<()>>,
}

impl ReplicaServer {
    /// Bind `bind` (port 0 picks a free port) and start accepting.
    pub fn start(handle: ServerHandle, bind: &str) -> Result<ReplicaServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(u64, TcpStream)>>> = Arc::new(Mutex::new(Vec::new()));
        // Cache the param epoch at the RPC layer: `gen`/`done` frames stamp
        // it so the router can assert no mixed-epoch tokens ever crossed.
        let epoch = Arc::new(AtomicU64::new(
            handle.mem_report().map(|m| m.params_epoch).unwrap_or(0),
        ));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::spawn(move || replica_accept(listener, handle, epoch, stop, conns))
        };
        Ok(ReplicaServer { addr, stop, conns, accept: Some(accept) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful stop: no new connections; live ones run to completion.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Abortive stop: severs every live connection mid-frame. Stands in
    /// for a worker-process death in the e2e tests — the router must see
    /// exactly what a crashed replica would produce (truncated streams,
    /// refused connects).
    pub fn kill(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Ok(cs) = self.conns.lock() {
            for (_, c) in cs.iter() {
                let _ = c.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

fn replica_accept(
    listener: TcpListener,
    handle: ServerHandle,
    epoch: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<(u64, TcpStream)>>>,
) {
    let mut seq: u64 = 0;
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                seq += 1;
                let id = seq;
                if let Ok(dup) = stream.try_clone() {
                    if let Ok(mut cs) = conns.lock() {
                        cs.push((id, dup));
                    }
                }
                let handle = handle.clone();
                let epoch = Arc::clone(&epoch);
                let conns = Arc::clone(&conns);
                std::thread::spawn(move || {
                    replica_conn(handle, epoch, stream);
                    if let Ok(mut cs) = conns.lock() {
                        cs.retain(|(i, _)| *i != id);
                    }
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

fn replica_conn(handle: ServerHandle, epoch: Arc<AtomicU64>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let mut rd = JsonReader::new(FRAME_CAP);
    loop {
        let v = match read_frame(&mut stream, &mut rd) {
            Ok(v) => v,
            Err(_) => return, // router hung up (or sent garbage): drop conn
        };
        let op = v.get("op").and_then(|x| x.as_str()).unwrap_or("").to_string();
        let keep = match op.as_str() {
            "gen" => replica_gen(&handle, &epoch, &mut stream, &v),
            "health" => replica_health(&handle, &epoch, &mut stream),
            "mem" => replica_mem(&handle, &mut stream),
            "metrics" => replica_metrics(&mut stream),
            "set_params" => replica_set_params(&handle, &epoch, &mut stream, &v),
            "drain" => replica_drain(&handle, &mut stream, &v),
            other => write_frame(&mut stream, &ev_err(&format!("unknown op `{other}`"), 0)).is_ok(),
        };
        if !keep {
            return;
        }
    }
}

/// Serve one `gen` frame: admit, ack with the current epoch, then pump
/// engine stream events to the router until the terminal frame. A write
/// failure drops the engine receiver, which retires the session — a dead
/// router connection never leaks a decode session.
fn replica_gen(
    handle: &ServerHandle,
    epoch: &AtomicU64,
    stream: &mut TcpStream,
    v: &Json,
) -> bool {
    let (req, _stream_flag, _session) = match crate::net::server::parse_generate(v, 0) {
        Ok(p) => p,
        Err(msg) => return write_frame(stream, &ev_err(&msg, 0)).is_ok(),
    };
    // The router forwards its trace id in the frame; this process opens
    // its own trace under the same id, so the engine-side spans (queue
    // wait, prefill, decode rounds) land in this replica's `/trace` ring
    // and correlate with the front end's by the printed hex.
    let trace_id = req.trace_id;
    trace::begin(trace_id);
    let token_buf = v.get("token_buf").and_then(|x| x.as_usize()).unwrap_or(128).max(1);
    let rx = match handle.try_submit_stream(req, token_buf) {
        Ok(rx) => rx,
        Err(AdmitError::Busy { retry_after }) => {
            trace::finish(trace_id, "rejected");
            let f = Json::obj(vec![
                ("ev", Json::str("busy")),
                ("retry_ms", Json::num(retry_after.as_millis() as f64)),
            ]);
            return write_frame(stream, &f).is_ok();
        }
        Err(AdmitError::Draining) => {
            trace::finish(trace_id, "rejected");
            return write_frame(stream, &Json::obj(vec![("ev", Json::str("draining"))])).is_ok();
        }
    };
    let ok = Json::obj(vec![
        ("ev", Json::str("ok")),
        ("epoch", Json::num(epoch.load(Ordering::SeqCst) as f64)),
    ]);
    if write_frame(stream, &ok).is_err() {
        return false;
    }
    loop {
        match rx.recv() {
            Ok(StreamEvent::Token(t)) => {
                let f = Json::obj(vec![("ev", Json::str("tok")), ("t", Json::num(t as f64))]);
                if write_frame(stream, &f).is_err() {
                    trace::finish(trace_id, "error");
                    return false;
                }
            }
            Ok(StreamEvent::Done(resp)) => {
                let f = Json::obj(vec![
                    ("ev", Json::str("done")),
                    (
                        "tokens",
                        Json::Arr(resp.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                    ),
                    ("bucket_len", Json::num(resp.bucket_len as f64)),
                    ("batch_occupancy", Json::num(resp.batch_occupancy as f64)),
                    ("queue_ms", Json::num(resp.queue_time.as_secs_f64() * 1e3)),
                    ("total_ms", Json::num(resp.total_time.as_secs_f64() * 1e3)),
                    ("epoch", Json::num(epoch.load(Ordering::SeqCst) as f64)),
                ]);
                trace::finish(trace_id, "done");
                return write_frame(stream, &f).is_ok();
            }
            Ok(StreamEvent::Error { message, partial }) => {
                trace::finish(trace_id, "error");
                return write_frame(stream, &ev_err(&message, partial)).is_ok();
            }
            Err(_) => {
                trace::finish(trace_id, "error");
                return write_frame(stream, &ev_err("engine stream closed unexpectedly", 0))
                    .is_ok();
            }
        }
    }
}

fn replica_health(handle: &ServerHandle, epoch: &AtomicU64, stream: &mut TcpStream) -> bool {
    // Re-read the authoritative engine epoch on every probe: parameters
    // can change out-of-band (a local reload, not our set_params RPC) and
    // a stale cache would hold this replica out of the fleet forever.
    if let Some(m) = handle.mem_report() {
        epoch.store(m.params_epoch, Ordering::SeqCst);
    }
    let f = Json::obj(vec![
        ("ev", Json::str("health")),
        ("ok", Json::Bool(true)),
        ("capacity", Json::num(handle.capacity() as f64)),
        ("inflight", Json::num(handle.inflight() as f64)),
        ("epoch", Json::num(epoch.load(Ordering::SeqCst) as f64)),
        ("draining", Json::Bool(handle.is_draining())),
    ]);
    write_frame(stream, &f).is_ok()
}

fn replica_mem(handle: &ServerHandle, stream: &mut TcpStream) -> bool {
    let f = match handle.mem_report() {
        Some(m) => Json::obj(vec![("ev", Json::str("mem")), ("mem", mem_to_json(&m))]),
        None => ev_err("engine has no mem report", 0),
    };
    write_frame(stream, &f).is_ok()
}

/// Serve one `metrics` frame: this process's telemetry snapshot. The
/// router folds replica snapshots into the fleet-level `GET /metrics`
/// (aggregate sums plus per-replica `replica="K"` labeled series).
fn replica_metrics(stream: &mut TcpStream) -> bool {
    let f = Json::obj(vec![
        ("ev", Json::str("metrics")),
        ("metrics", obs::snapshot_to_json(&obs::snapshot())),
    ]);
    write_frame(stream, &f).is_ok()
}

fn replica_set_params(
    handle: &ServerHandle,
    epoch: &AtomicU64,
    stream: &mut TcpStream,
    v: &Json,
) -> bool {
    let params = match parse_params(v) {
        Ok(p) => p,
        Err(e) => return write_frame(stream, &ev_err(&e.to_string(), 0)).is_ok(),
    };
    if let Err(e) = handle.set_params(params) {
        return write_frame(stream, &ev_err(&e.to_string(), 0)).is_ok();
    }
    // Re-read the authoritative epoch from the engine so the ack carries
    // the post-install value the router will gate on.
    let new_epoch = handle.mem_report().map(|m| m.params_epoch).unwrap_or(0);
    epoch.store(new_epoch, Ordering::SeqCst);
    let f = Json::obj(vec![
        ("ev", Json::str("params_ack")),
        ("epoch", Json::num(new_epoch as f64)),
    ]);
    write_frame(stream, &f).is_ok()
}

fn replica_drain(handle: &ServerHandle, stream: &mut TcpStream, v: &Json) -> bool {
    let budget_ms = v.get("budget_ms").and_then(|x| x.as_f64()).unwrap_or(5_000.0).max(0.0);
    let rep = handle.drain(Duration::from_millis(budget_ms as u64)).unwrap_or_default();
    let leaked = handle.mem_report().map(|m| m.decode_sessions_live).unwrap_or(0);
    let f = Json::obj(vec![
        ("ev", Json::str("drained")),
        ("finished", Json::num(rep.finished as f64)),
        ("aborted", Json::num(rep.aborted as f64)),
        ("dropped", Json::num(rep.dropped_queued as f64)),
        ("leaked", Json::num(leaked as f64)),
    ]);
    write_frame(stream, &f).is_ok()
}

// ---------------------------------------------------------------------------
// Router side: the fleet
// ---------------------------------------------------------------------------

/// Router-side tunables.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Per-connection read/write timeout on replica sockets, ms.
    pub io_timeout_ms: u64,
    /// Health-probe period, ms.
    pub probe_ms: u64,
    /// Health-probe connect+RPC timeout, ms.
    pub probe_timeout_ms: u64,
    /// Peer retries for a prompt whose replica died before the first
    /// token (re-prefill is safe: nothing was delivered).
    pub gen_retries: usize,
    /// Suppress router log lines.
    pub quiet: bool,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            io_timeout_ms: 10_000,
            probe_ms: 150,
            probe_timeout_ms: 500,
            gen_retries: 2,
            quiet: false,
        }
    }
}

/// Router-side record of one worker.
struct Replica {
    id: usize,
    /// Mutable: the supervisor rewrites this when it respawns a dead
    /// worker process on a fresh port.
    addr: Mutex<SocketAddr>,
    /// In the candidate set? Probes flip this down after consecutive
    /// failures (or a stale epoch) and back up on recovery.
    up: AtomicBool,
    /// Admission-gated during a parameter broadcast (down for dispatch,
    /// but not "failed" — probes do not touch it).
    gated: AtomicBool,
    /// Streams the router currently has open against this replica — the
    /// least-loaded dispatch key.
    inflight: AtomicUsize,
    capacity: AtomicUsize,
    /// Last epoch observed (probe, admission ack, or params ack).
    epoch: AtomicU64,
    /// Consecutive probe failures.
    fails: AtomicUsize,
}

fn addr_of(r: &Replica) -> SocketAddr {
    match r.addr.lock() {
        Ok(a) => *a,
        Err(p) => *p.into_inner(),
    }
}

struct FleetInner {
    replicas: Vec<Arc<Replica>>,
    /// session key -> replica id holding that session's decode state.
    sessions: Mutex<HashMap<String, usize>>,
    draining: AtomicBool,
    stop: AtomicBool,
    /// Epoch every replica must serve at. Replicas observed below this
    /// are kept out of the candidate set (mixed-epoch guard).
    target_epoch: AtomicU64,
    cfg: FleetConfig,
}

/// Handle to a replica fleet; implements [`Engine`] so the HTTP front
/// end drives it exactly like the in-process worker.
#[derive(Clone)]
pub struct FleetHandle {
    inner: Arc<FleetInner>,
}

impl FleetHandle {
    /// Connect to already-listening replicas and start the health-probe
    /// thread. Fails hard if any replica refuses its startup probe: a
    /// fleet that boots degraded is a misconfiguration, not a failover.
    pub fn connect(addrs: &[SocketAddr], cfg: FleetConfig) -> Result<FleetHandle> {
        if addrs.is_empty() {
            bail!("replica fleet needs at least one worker address");
        }
        let probe_to = Duration::from_millis(cfg.probe_timeout_ms.max(1));
        let mut replicas = Vec::with_capacity(addrs.len());
        let mut max_epoch = 0u64;
        for (id, &addr) in addrs.iter().enumerate() {
            let h = health_rpc(addr, probe_to)
                .with_context(|| format!("replica {id} at {addr}: startup health probe"))?;
            max_epoch = max_epoch.max(h.epoch);
            replicas.push(Arc::new(Replica {
                id,
                addr: Mutex::new(addr),
                up: AtomicBool::new(true),
                gated: AtomicBool::new(false),
                inflight: AtomicUsize::new(0),
                capacity: AtomicUsize::new(h.capacity),
                epoch: AtomicU64::new(h.epoch),
                fails: AtomicUsize::new(0),
            }));
        }
        let inner = Arc::new(FleetInner {
            replicas,
            sessions: Mutex::new(HashMap::new()),
            draining: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            target_epoch: AtomicU64::new(max_epoch),
            cfg,
        });
        {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || probe_loop(inner));
        }
        Ok(FleetHandle { inner })
    }

    /// Stop the probe thread (the fleet itself holds no sockets open).
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
    }

    /// Point replica `id` at a new address (supervisor respawned the
    /// worker). Resets the failure counter so probes can mark it up.
    pub fn set_replica_addr(&self, id: usize, addr: SocketAddr) {
        if let Some(r) = self.inner.replicas.get(id) {
            match r.addr.lock() {
                Ok(mut a) => *a = addr,
                Err(p) => *p.into_inner() = addr,
            }
            r.fails.store(0, Ordering::SeqCst);
        }
    }

    /// Is replica `id` currently in the candidate set? (test hook)
    pub fn replica_up(&self, id: usize) -> bool {
        self.inner.replicas.get(id).map(|r| r.up.load(Ordering::SeqCst)).unwrap_or(false)
    }

    /// Live session-affinity pins (test hook: zero after a full drain).
    pub fn pinned_sessions(&self) -> usize {
        match self.inner.sessions.lock() {
            Ok(s) => s.len(),
            Err(p) => p.into_inner().len(),
        }
    }
}

/// One health-probe reply.
struct Health {
    capacity: usize,
    inflight: usize,
    epoch: u64,
    draining: bool,
}

fn health_rpc(addr: SocketAddr, timeout: Duration) -> io::Result<Health> {
    let mut s = TcpStream::connect_timeout(&addr, timeout)?;
    let _ = s.set_nodelay(true);
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    write_frame(&mut s, &Json::obj(vec![("op", Json::str("health"))]))?;
    let mut rd = JsonReader::new(1 << 16);
    let v = read_frame(&mut s, &mut rd)?;
    if v.get("ev").and_then(|x| x.as_str()) != Some("health") {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "expected health frame"));
    }
    let n = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
    Ok(Health {
        capacity: n("capacity") as usize,
        inflight: n("inflight") as usize,
        epoch: n("epoch") as u64,
        draining: v.get("draining").and_then(|x| x.as_bool()).unwrap_or(false),
    })
}

fn probe_loop(inner: Arc<FleetInner>) {
    let period = Duration::from_millis(inner.cfg.probe_ms.max(10));
    let probe_to = Duration::from_millis(inner.cfg.probe_timeout_ms.max(1));
    loop {
        std::thread::sleep(period);
        if inner.stop.load(Ordering::SeqCst) {
            return;
        }
        let target = inner.target_epoch.load(Ordering::SeqCst);
        for r in &inner.replicas {
            let addr = addr_of(r);
            match health_rpc(addr, probe_to) {
                Ok(h) => {
                    r.fails.store(0, Ordering::SeqCst);
                    r.capacity.store(h.capacity, Ordering::SeqCst);
                    r.epoch.store(h.epoch, Ordering::SeqCst);
                    let _ = h.inflight; // router-side count is authoritative
                    if h.epoch < target {
                        // Alive but serving stale weights (missed a
                        // broadcast): keep it out of the candidate set
                        // until re-broadcast — never mix epochs.
                        if r.up.swap(false, Ordering::SeqCst) && !inner.cfg.quiet {
                            eprintln!(
                                "[router] replica {} marked down: stale epoch {} < {}",
                                r.id, h.epoch, target
                            );
                        }
                    } else if h.draining {
                        r.up.store(false, Ordering::SeqCst);
                    } else if !r.up.swap(true, Ordering::SeqCst) && !inner.cfg.quiet {
                        eprintln!("[router] replica {} marked up ({addr})", r.id);
                    }
                }
                Err(e) => {
                    let fails = r.fails.fetch_add(1, Ordering::SeqCst) + 1;
                    if fails >= MARK_DOWN_FAILS
                        && r.up.swap(false, Ordering::SeqCst)
                        && !inner.cfg.quiet
                    {
                        eprintln!(
                            "[router] replica {} marked down after {fails} failed probes: {e}",
                            r.id
                        );
                    }
                }
            }
        }
    }
}

/// Record an admission/stream transport failure against a replica —
/// faster than waiting out the probe period.
fn note_fail(inner: &FleetInner, r: &Replica) {
    let fails = r.fails.fetch_add(1, Ordering::SeqCst) + 1;
    if fails >= MARK_DOWN_FAILS && r.up.swap(false, Ordering::SeqCst) && !inner.cfg.quiet {
        eprintln!("[router] replica {} marked down after transport failure", r.id);
    }
}

/// Dispatch candidates, best first. A live pin wins outright (decode
/// state is replica-resident — balancing cannot move it); a pin whose
/// replica is down falls through to the peers, and the caller re-pins
/// wherever the re-prefill lands. Otherwise: up, ungated, not excluded,
/// least-loaded first (ties by id for determinism).
fn candidates(
    inner: &FleetInner,
    pinned: Option<usize>,
    exclude: Option<usize>,
) -> Vec<Arc<Replica>> {
    if let Some(p) = pinned {
        if let Some(r) = inner.replicas.get(p) {
            if r.up.load(Ordering::SeqCst)
                && !r.gated.load(Ordering::SeqCst)
                && Some(p) != exclude
            {
                return vec![Arc::clone(r)];
            }
        }
    }
    let mut out: Vec<Arc<Replica>> = inner
        .replicas
        .iter()
        .filter(|r| {
            r.up.load(Ordering::SeqCst)
                && !r.gated.load(Ordering::SeqCst)
                && Some(r.id) != exclude
        })
        .map(Arc::clone)
        .collect();
    out.sort_by_key(|r| (r.inflight.load(Ordering::SeqCst), r.id));
    out
}

/// Outcome of an admission handshake against one replica.
enum Admit {
    Ok(TcpStream, JsonReader),
    Busy(Duration),
    Draining,
    Transport(io::Error),
}

fn gen_frame(req: &GenerateRequest, token_buf: usize) -> Json {
    let mut kv = vec![
        ("op", Json::str("gen")),
        (
            "prompt",
            Json::Arr(req.prompt.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("max_new", Json::num(req.max_new as f64)),
        ("token_buf", Json::num(token_buf as f64)),
        ("stream", Json::Bool(true)),
    ];
    if let Sampling::Temperature { t, top_k } = req.sampling {
        kv.push(("temperature", Json::num(t as f64)));
        kv.push(("top_k", Json::num(top_k as f64)));
    }
    if let Some(d) = req.deadline {
        kv.push(("timeout_ms", Json::num(d.as_millis() as f64)));
    }
    if req.trace_id != 0 {
        // Full 16-hex id (not the short log form) so the replica traces
        // under exactly the router's id.
        kv.push(("trace_id", Json::str(&format!("{:016x}", req.trace_id))));
    }
    Json::obj(kv)
}

/// Connect, send the `gen` frame, and read the admission reply.
fn gen_handshake(r: &Replica, req: &GenerateRequest, token_buf: usize, io_to: Duration) -> Admit {
    let addr = addr_of(r);
    let mut s = match TcpStream::connect_timeout(&addr, io_to) {
        Ok(s) => s,
        Err(e) => return Admit::Transport(e),
    };
    let _ = s.set_nodelay(true);
    if let Err(e) = s.set_read_timeout(Some(io_to)).and(s.set_write_timeout(Some(io_to))) {
        return Admit::Transport(e);
    }
    if let Err(e) = write_frame(&mut s, &gen_frame(req, token_buf)) {
        return Admit::Transport(e);
    }
    let mut rd = JsonReader::new(FRAME_CAP);
    let v = match read_frame(&mut s, &mut rd) {
        Ok(v) => v,
        Err(e) => return Admit::Transport(e),
    };
    match v.get("ev").and_then(|x| x.as_str()) {
        Some("ok") => {
            if let Some(e) = v.get("epoch").and_then(|x| x.as_f64()) {
                r.epoch.store(e as u64, Ordering::SeqCst);
            }
            Admit::Ok(s, rd)
        }
        Some("busy") => {
            let ms = v.get("retry_ms").and_then(|x| x.as_f64()).unwrap_or(1_000.0).max(0.0);
            Admit::Busy(Duration::from_millis(ms as u64))
        }
        Some("draining") => Admit::Draining,
        other => Admit::Transport(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unexpected admission reply: {other:?}"),
        )),
    }
}

fn done_to_response(v: &Json) -> GenerateResponse {
    let n = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
    let tokens = v
        .get("tokens")
        .and_then(|x| x.as_arr())
        .map(|a| a.iter().filter_map(|e| e.as_f64()).map(|f| f as i32).collect())
        .unwrap_or_default();
    GenerateResponse {
        tokens,
        queue_time: Duration::from_secs_f64(n("queue_ms") / 1e3),
        total_time: Duration::from_secs_f64(n("total_ms") / 1e3),
        batch_occupancy: n("batch_occupancy") as usize,
        bucket_len: n("bucket_len") as usize,
    }
}

fn dec_inflight(inner: &FleetInner, rid: usize) {
    if let Some(r) = inner.replicas.get(rid) {
        r.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

fn pin_session(inner: &FleetInner, session: &Option<String>, rid: usize) {
    if let Some(key) = session {
        let mut map = match inner.sessions.lock() {
            Ok(m) => m,
            Err(p) => p.into_inner(),
        };
        map.insert(key.clone(), rid);
    }
}

/// Forward one admitted replica stream to the front end's event channel.
///
/// Failover rule: a transport error *before the first forwarded token*
/// re-runs the whole prompt on a peer (nothing was delivered, so the
/// re-prefill is invisible to the client, modulo latency); after any
/// token was forwarded the stream terminates with a clean error — tokens
/// cannot be unsent and the dead replica took the decode state with it.
#[allow(clippy::too_many_arguments)]
fn pump(
    inner: Arc<FleetInner>,
    mut rid: usize,
    mut stream: TcpStream,
    mut rd: JsonReader,
    tx: SyncSender<StreamEvent>,
    req: GenerateRequest,
    token_buf: usize,
    session: Option<String>,
) {
    let io_to = Duration::from_millis(inner.cfg.io_timeout_ms.max(1));
    let mut retries = inner.cfg.gen_retries;
    let mut forwarded: usize = 0;
    loop {
        let v = match read_frame(&mut stream, &mut rd) {
            Ok(v) => v,
            Err(e) => {
                if let Some(r) = inner.replicas.get(rid) {
                    note_fail(&inner, r);
                }
                if forwarded == 0 && retries > 0 {
                    retries -= 1;
                    dec_inflight(&inner, rid);
                    let mut next: Option<(usize, TcpStream, JsonReader)> = None;
                    for cand in candidates(&inner, None, Some(rid)) {
                        match gen_handshake(&cand, &req, token_buf, io_to) {
                            Admit::Ok(s2, rd2) => {
                                cand.inflight.fetch_add(1, Ordering::SeqCst);
                                next = Some((cand.id, s2, rd2));
                                break;
                            }
                            Admit::Busy(_) | Admit::Draining => continue,
                            Admit::Transport(_) => note_fail(&inner, &cand),
                        }
                    }
                    match next {
                        Some((nid, s2, rd2)) => {
                            if !inner.cfg.quiet {
                                eprintln!(
                                    "[router] replica {rid} died before first token; \
                                     re-prefilled on replica {nid} trace={}",
                                    trace::id_hex(req.trace_id)
                                );
                            }
                            pin_session(&inner, &session, nid);
                            rid = nid;
                            stream = s2;
                            rd = rd2;
                            continue;
                        }
                        None => {
                            let _ = tx.send(StreamEvent::Error {
                                message: format!(
                                    "replica {rid} failed before first token and no peer \
                                     could take the request: {e}"
                                ),
                                partial: 0,
                            });
                            return; // inflight already released above
                        }
                    }
                }
                let _ = tx.send(StreamEvent::Error {
                    message: format!("replica {rid} connection lost mid-stream: {e}"),
                    partial: forwarded,
                });
                break;
            }
        };
        match v.get("ev").and_then(|x| x.as_str()) {
            Some("tok") => {
                let t = v.get("t").and_then(|x| x.as_f64()).unwrap_or(0.0) as i32;
                forwarded += 1;
                match tx.try_send(StreamEvent::Token(t)) {
                    Ok(()) => {}
                    // Client stopped draining (slow or gone): sever the
                    // replica connection so the worker retires the
                    // session instead of blocking on a full pipe.
                    Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => break,
                }
            }
            Some("done") => {
                let _ = tx.send(StreamEvent::Done(done_to_response(&v)));
                break;
            }
            Some("err") => {
                let message = v
                    .get("message")
                    .and_then(|x| x.as_str())
                    .unwrap_or("replica error")
                    .to_string();
                let partial =
                    v.get("partial").and_then(|x| x.as_f64()).unwrap_or(forwarded as f64) as usize;
                let _ = tx.send(StreamEvent::Error { message, partial });
                break;
            }
            other => {
                let _ = tx.send(StreamEvent::Error {
                    message: format!("unexpected replica frame: {other:?}"),
                    partial: forwarded,
                });
                break;
            }
        }
    }
    dec_inflight(&inner, rid);
}

fn fetch_mem(addr: SocketAddr, timeout: Duration) -> io::Result<MemReport> {
    let mut s = TcpStream::connect_timeout(&addr, timeout)?;
    let _ = s.set_nodelay(true);
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    write_frame(&mut s, &Json::obj(vec![("op", Json::str("mem"))]))?;
    let mut rd = JsonReader::new(1 << 20);
    let v = read_frame(&mut s, &mut rd)?;
    match (v.get("ev").and_then(|x| x.as_str()), v.get("mem")) {
        (Some("mem"), Some(m)) => Ok(mem_from_json(m)),
        _ => Err(io::Error::new(io::ErrorKind::InvalidData, "expected mem frame")),
    }
}

fn fetch_metrics(addr: SocketAddr, timeout: Duration) -> io::Result<obs::Snapshot> {
    let mut s = TcpStream::connect_timeout(&addr, timeout)?;
    let _ = s.set_nodelay(true);
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    write_frame(&mut s, &Json::obj(vec![("op", Json::str("metrics"))]))?;
    let mut rd = JsonReader::new(1 << 22);
    let v = read_frame(&mut s, &mut rd)?;
    match (v.get("ev").and_then(|x| x.as_str()), v.get("metrics")) {
        (Some("metrics"), Some(m)) => obs::snapshot_from_json(m)
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad metrics payload")),
        _ => Err(io::Error::new(io::ErrorKind::InvalidData, "expected metrics frame")),
    }
}

fn set_params_rpc(addr: SocketAddr, frame: &Json, timeout: Duration) -> io::Result<u64> {
    let mut s = TcpStream::connect_timeout(&addr, timeout)?;
    let _ = s.set_nodelay(true);
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    write_frame(&mut s, frame)?;
    let mut rd = JsonReader::new(1 << 16);
    let v = read_frame(&mut s, &mut rd)?;
    match v.get("ev").and_then(|x| x.as_str()) {
        Some("params_ack") => {
            Ok(v.get("epoch").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64)
        }
        Some("err") => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            v.get("message").and_then(|x| x.as_str()).unwrap_or("set_params failed").to_string(),
        )),
        _ => Err(io::Error::new(io::ErrorKind::InvalidData, "expected params_ack frame")),
    }
}

fn drain_replica(
    addr: SocketAddr,
    budget: Duration,
    io_to: Duration,
) -> io::Result<(DrainReport, u64)> {
    let mut s = TcpStream::connect_timeout(&addr, io_to)?;
    let _ = s.set_nodelay(true);
    // The reply lands only after the worker's drain completes, so the
    // read timeout must cover the full budget plus normal IO slack.
    s.set_read_timeout(Some(budget + io_to))?;
    s.set_write_timeout(Some(io_to))?;
    let f = Json::obj(vec![
        ("op", Json::str("drain")),
        ("budget_ms", Json::num(budget.as_millis() as f64)),
    ]);
    write_frame(&mut s, &f)?;
    let mut rd = JsonReader::new(1 << 16);
    let v = read_frame(&mut s, &mut rd)?;
    if v.get("ev").and_then(|x| x.as_str()) != Some("drained") {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "expected drained frame"));
    }
    let n = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
    Ok((
        DrainReport {
            finished: n("finished") as usize,
            aborted: n("aborted") as usize,
            dropped_queued: n("dropped") as usize,
        },
        n("leaked") as u64,
    ))
}

impl FleetHandle {
    /// Epoch-synchronized weight broadcast. Per live replica: gate
    /// admission, push the tensors, ungate only on an epoch ack. A
    /// replica that fails the push is marked down (its next health probes
    /// show a stale epoch, keeping it out until re-broadcast). Returns
    /// the fleet's new target epoch; errors only if *no* replica acked.
    pub fn broadcast_params(&self, params: &[Tensor]) -> Result<u64> {
        let inner = &self.inner;
        let frame = Json::obj(vec![
            ("op", Json::str("set_params")),
            ("params", params_to_json(params)?),
        ]);
        let io_to = Duration::from_millis(inner.cfg.io_timeout_ms.max(1));
        let mut acked = 0usize;
        let mut max_epoch = inner.target_epoch.load(Ordering::SeqCst);
        for r in &inner.replicas {
            if !r.up.load(Ordering::SeqCst) {
                continue;
            }
            r.gated.store(true, Ordering::SeqCst);
            match set_params_rpc(addr_of(r), &frame, io_to) {
                Ok(e) => {
                    r.epoch.store(e, Ordering::SeqCst);
                    r.gated.store(false, Ordering::SeqCst);
                    acked += 1;
                    max_epoch = max_epoch.max(e);
                }
                Err(e) => {
                    r.up.store(false, Ordering::SeqCst);
                    r.gated.store(false, Ordering::SeqCst);
                    if !inner.cfg.quiet {
                        eprintln!(
                            "[router] replica {} marked down: parameter broadcast failed: {e}",
                            r.id
                        );
                    }
                }
            }
        }
        inner.target_epoch.store(max_epoch, Ordering::SeqCst);
        if acked == 0 {
            bail!("parameter broadcast reached no replica");
        }
        Ok(max_epoch)
    }
}

impl Engine for FleetHandle {
    fn try_submit_stream(
        &self,
        req: GenerateRequest,
        token_buf: usize,
        session: Option<&str>,
    ) -> std::result::Result<StreamSubmission, AdmitError> {
        let inner = &self.inner;
        if inner.draining.load(Ordering::SeqCst) {
            return Err(AdmitError::Draining);
        }
        let pinned = session.and_then(|k| {
            let map = match inner.sessions.lock() {
                Ok(m) => m,
                Err(p) => p.into_inner(),
            };
            map.get(k).copied()
        });
        let io_to = Duration::from_millis(inner.cfg.io_timeout_ms.max(1));
        let mut retry_hint = Duration::from_millis(1_000);
        let mut admitted: Option<(Arc<Replica>, TcpStream, JsonReader)> = None;
        for r in candidates(inner, pinned, None) {
            match gen_handshake(&r, &req, token_buf, io_to) {
                Admit::Ok(s, rd) => {
                    admitted = Some((r, s, rd));
                    break;
                }
                Admit::Busy(d) => retry_hint = retry_hint.min(d.max(Duration::from_millis(1))),
                Admit::Draining => {}
                Admit::Transport(_) => note_fail(inner, &r),
            }
        }
        let (r, stream, rd) = match admitted {
            Some(t) => t,
            None => return Err(AdmitError::Busy { retry_after: retry_hint }),
        };
        r.inflight.fetch_add(1, Ordering::SeqCst);
        let rid = r.id;
        if !inner.cfg.quiet && req.trace_id != 0 {
            eprintln!("[router] dispatch replica={rid} trace={}", trace::id_hex(req.trace_id));
        }
        let skey = session.map(|s| s.to_string());
        pin_session(inner, &skey, rid);
        let (tx, rx) = sync_channel(token_buf.max(2));
        let inner2 = Arc::clone(&self.inner);
        std::thread::spawn(move || pump(inner2, rid, stream, rd, tx, req, token_buf, skey));
        Ok(StreamSubmission { rx, replica: Some(rid) })
    }

    /// Aggregated fleet report. Queries every replica (down ones too —
    /// observability must still see a draining/stale worker's sessions)
    /// and folds with [`MemReport::merge`].
    fn mem_report(&self) -> Option<MemReport> {
        let inner = &self.inner;
        let io_to = Duration::from_millis(inner.cfg.io_timeout_ms.max(1));
        let mut agg: Option<MemReport> = None;
        for r in &inner.replicas {
            if let Ok(m) = fetch_mem(addr_of(r), io_to) {
                match agg.as_mut() {
                    Some(a) => a.merge(&m),
                    None => agg = Some(m),
                }
            }
        }
        agg
    }

    fn capacity(&self) -> usize {
        self.inner
            .replicas
            .iter()
            .filter(|r| r.up.load(Ordering::SeqCst))
            .map(|r| r.capacity.load(Ordering::SeqCst))
            .sum()
    }

    fn inflight(&self) -> usize {
        self.inner.replicas.iter().map(|r| r.inflight.load(Ordering::SeqCst)).sum()
    }

    fn begin_drain(&self) {
        self.inner.draining.store(true, Ordering::SeqCst);
    }

    fn is_draining(&self) -> bool {
        self.inner.draining.load(Ordering::SeqCst)
    }

    /// Fleet-wide drain: every replica drains in parallel under the same
    /// budget; reports sum. Session pins are cleared afterwards — every
    /// pinned session either finished or was aborted by its worker.
    fn drain(&self, budget: Duration) -> Option<DrainReport> {
        self.begin_drain();
        let inner = &self.inner;
        let io_to = Duration::from_millis(inner.cfg.io_timeout_ms.max(1));
        let (tx, rx) = channel();
        let mut live = 0usize;
        for r in &inner.replicas {
            let addr = addr_of(r);
            let tx = tx.clone();
            live += 1;
            std::thread::spawn(move || {
                let _ = tx.send(drain_replica(addr, budget, io_to).ok());
            });
        }
        drop(tx);
        let mut rep = DrainReport::default();
        for _ in 0..live {
            if let Ok(Some((d, _leaked))) = rx.recv() {
                rep.finished += d.finished;
                rep.aborted += d.aborted;
                rep.dropped_queued += d.dropped_queued;
            }
        }
        match inner.sessions.lock() {
            Ok(mut m) => m.clear(),
            Err(p) => p.into_inner().clear(),
        }
        Some(rep)
    }

    fn replicas(&self) -> usize {
        self.inner.replicas.len()
    }

    /// Fleet metrics: the router's own snapshot (front-end counters: HTTP
    /// classes, admission, tokens delivered) merged with every reachable
    /// replica's (engine histograms: queue wait, prefill, decode rounds)
    /// — aggregate sums plus per-replica `replica="K"` labeled series.
    /// Down replicas are still queried, same policy as [`mem_report`]:
    /// observability must see a draining or stale worker.
    ///
    /// [`mem_report`]: Engine::mem_report
    fn metrics(&self) -> obs::Snapshot {
        let inner = &self.inner;
        let io_to = Duration::from_millis(inner.cfg.io_timeout_ms.max(1));
        let mut reps: Vec<(usize, obs::Snapshot)> = Vec::new();
        for r in &inner.replicas {
            if let Ok(s) = fetch_metrics(addr_of(r), io_to) {
                reps.push((r.id, s));
            }
        }
        obs::merge_fleet(obs::snapshot(), &reps)
    }
}
