//! The HTTP/1.1 listener: routes, per-request deadlines, backpressure,
//! slow-client eviction, graceful drain, access logs (DESIGN.md
//! §Serving-Net).
//!
//! Threading: one non-blocking accept thread hands sockets to
//! `conn_threads` long-lived connection workers running on a *dedicated*
//! `util::pool::WorkerPool` (dedicated so blocked socket reads can never
//! starve the engine's compute pool). The hand-off channel is bounded at
//! `conn_threads`: when every worker is busy and the lane is full the
//! accept thread answers `503` inline instead of queueing connections
//! without bound — backpressure starts at the front door.
//!
//! Routes:
//! * `POST /generate` — body is framed by `net::jsonrd` (bounded,
//!   incremental); `{"prompt":[...],"max_new":N}` plus optional
//!   `temperature`/`top_k`, `timeout_ms` (deadline), `stream:false` for a
//!   single JSON reply. The default reply is an SSE stream: one `token`
//!   event per decoded token, then exactly one `done` or `error` event.
//! * `GET /healthz` — liveness + drain state.
//! * `GET /mem` — the engine's `MemReport` (session/leak accounting).
//! * `GET /metrics` — Prometheus text exposition of the telemetry registry
//!   (`obs`); in fleet mode the engine merges replica snapshots.
//! * `GET /trace?n=K` — the newest K finished request traces as JSON
//!   (spans per stage: admission, queue, prefill, decode rounds, stream).
//!
//! Resilience state machine per request: `admitted → streaming →
//! (done | deadline | evicted | disconnected | drained)`; every terminal
//! state frees the decode session (the loopback chaos tests assert
//! `decode_sessions_live == 0` afterwards) and, when the socket still
//! works, says what happened (`error` event / 4xx / 5xx) rather than
//! vanishing.
//!
//! Drain: SIGINT/SIGTERM (via [`install_drain_signals`]) or
//! [`NetServer::trigger_drain`] stops the accept loop, rejects new
//! submissions (`503`), lets live streams finish within `drain_ms`,
//! force-retires the rest with `error` events, then reports leak counts
//! from `mem_report` — the worker outlives the drain precisely so that
//! report stays answerable.

use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::backend::MemReport;
use crate::coordinator::server::{
    AdmitError, DrainReport, Engine, GenerateRequest, ServerHandle, StreamEvent,
};
use crate::coordinator::generation::Sampling;
use crate::net::http::{
    self, read_exact_body, read_head, HeadError, RequestHead, SseWriter,
};
use crate::net::jsonrd::{Frame, JsonReader};
use crate::net::{epoch_ms, iso8601, NetConfig};
use crate::obs::{self, clock, trace};
use crate::util::json::Json;
use crate::util::pool::WorkerPool;

/// Process-wide drain request set by the SIGINT/SIGTERM handlers. Kept
/// separate from the per-server flag so concurrent test servers cannot
/// drain each other; production runs one listener per process.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Install SIGINT (ctrl-c) and SIGTERM handlers that request a graceful
/// drain. Hand-rolled `signal(2)` binding — the only libc symbol needed,
/// and the handler body (one atomic store) is async-signal-safe.
pub fn install_drain_signals() {
    #[cfg(unix)]
    {
        extern "C" fn on_sig(_signum: i32) {
            SIGNAL_DRAIN.store(true, Ordering::SeqCst);
        }
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        // SAFETY: libc `signal` is called with valid signal numbers and a
        // handler that is a plain `extern "C" fn` doing one atomic store —
        // async-signal-safe per the module doc above. No Rust state is
        // touched from the handler.
        unsafe {
            signal(2, on_sig as usize); // SIGINT
            signal(15, on_sig as usize); // SIGTERM
        }
    }
}

/// Has a drain been requested by signal?
pub fn drain_signalled() -> bool {
    SIGNAL_DRAIN.load(Ordering::SeqCst)
}

/// Wire counters, all monotone (snapshot via [`StatsSnapshot`]).
#[derive(Default)]
struct Stats {
    conns: AtomicU64,
    requests: AtomicU64,
    s2xx: AtomicU64,
    s4xx: AtomicU64,
    s429: AtomicU64,
    s5xx: AtomicU64,
    streams: AtomicU64,
    tokens: AtomicU64,
    chaos_disconnects: AtomicU64,
    chaos_stalls: AtomicU64,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub conns: u64,
    pub requests: u64,
    pub s2xx: u64,
    pub s4xx: u64,
    pub s429: u64,
    pub s5xx: u64,
    pub streams: u64,
    pub tokens: u64,
    pub chaos_disconnects: u64,
    pub chaos_stalls: u64,
}

impl Stats {
    fn count_status(&self, status: u16) {
        self.requests.fetch_add(1, Ordering::SeqCst);
        // Mirror into the telemetry registry. 429 only ever comes from the
        // admission refusal path, so it doubles as the admission-rejected
        // counter the loadgen `--scrape` invariant checks.
        let m = obs::serving();
        m.http_requests.inc();
        match status {
            429 => {
                self.s429.fetch_add(1, Ordering::SeqCst);
                m.http_4xx.inc();
                m.admission_rejected.inc();
            }
            200..=299 => {
                self.s2xx.fetch_add(1, Ordering::SeqCst);
                m.http_2xx.inc();
            }
            400..=499 => {
                self.s4xx.fetch_add(1, Ordering::SeqCst);
                m.http_4xx.inc();
            }
            _ => {
                self.s5xx.fetch_add(1, Ordering::SeqCst);
                m.http_5xx.inc();
            }
        };
    }

    fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            conns: self.conns.load(Ordering::SeqCst),
            requests: self.requests.load(Ordering::SeqCst),
            s2xx: self.s2xx.load(Ordering::SeqCst),
            s4xx: self.s4xx.load(Ordering::SeqCst),
            s429: self.s429.load(Ordering::SeqCst),
            s5xx: self.s5xx.load(Ordering::SeqCst),
            streams: self.streams.load(Ordering::SeqCst),
            tokens: self.tokens.load(Ordering::SeqCst),
            chaos_disconnects: self.chaos_disconnects.load(Ordering::SeqCst),
            chaos_stalls: self.chaos_stalls.load(Ordering::SeqCst),
        }
    }
}

struct Shared {
    handle: Box<dyn Engine>,
    cfg: NetConfig,
    drain: AtomicBool,
    stats: Stats,
    conn_seq: AtomicU64,
}

impl Shared {
    fn draining(&self) -> bool {
        self.drain.load(Ordering::SeqCst) || drain_signalled()
    }
}

/// What the listener did over its lifetime, produced by
/// [`NetServer::finish`] after the drain completes.
#[derive(Debug)]
pub struct NetReport {
    pub drain: DrainReport,
    /// `decode_sessions_live` after the drain — the leak gate; must be 0.
    pub leaked_sessions: usize,
    pub mem: Option<MemReport>,
    pub stats: StatsSnapshot,
}

/// A running listener bound to a socket address.
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    conn_sup: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `cfg.addr` and start serving the in-process worker. Port 0
    /// binds a free port — read the result from [`NetServer::addr`].
    pub fn start(handle: ServerHandle, cfg: NetConfig) -> Result<NetServer> {
        NetServer::start_engine(Box::new(handle), cfg)
    }

    /// Same listener over any [`Engine`] — the in-process worker or a
    /// replica fleet (`net::router::FleetHandle`). `serve --listen` and
    /// `serve --listen --replicas N` share this front end verbatim.
    pub fn start_engine(handle: Box<dyn Engine>, mut cfg: NetConfig) -> Result<NetServer> {
        if cfg.queue_cap == 0 {
            cfg.queue_cap = handle.capacity();
        }
        handle.set_queue_cap(cfg.queue_cap);
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr().context("local_addr")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let conn_threads = cfg.conn_threads.max(1);
        let (disp_tx, disp_rx) = sync_channel::<TcpStream>(conn_threads);
        let disp_rx = Arc::new(Mutex::new(disp_rx));
        let shared = Arc::new(Shared {
            handle,
            cfg,
            drain: AtomicBool::new(false),
            stats: Stats::default(),
            conn_seq: AtomicU64::new(0),
        });
        let accept = std::thread::Builder::new()
            .name("hyena-net-accept".into())
            .spawn({
                let shared = Arc::clone(&shared);
                move || accept_loop(listener, disp_tx, &shared)
            })
            .context("spawn accept thread")?;
        let conn_sup = std::thread::Builder::new()
            .name("hyena-net-conns".into())
            .spawn({
                let shared = Arc::clone(&shared);
                move || {
                    // Dedicated pool: connection workers block on sockets,
                    // which must never occupy the engine's compute threads.
                    let pool = WorkerPool::new(conn_threads);
                    let mut tasks: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
                    for _ in 0..conn_threads {
                        let shared = Arc::clone(&shared);
                        let rx = Arc::clone(&disp_rx);
                        tasks.push(Box::new(move || conn_loop(&shared, &rx)));
                    }
                    pool.scope_run(tasks);
                }
            })
            .context("spawn connection supervisor")?;
        Ok(NetServer { addr, shared, accept: Some(accept), conn_sup: Some(conn_sup) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful drain (what SIGTERM does, but scoped to this
    /// server — tests use this).
    pub fn trigger_drain(&self) {
        self.shared.drain.store(true, Ordering::SeqCst);
    }

    pub fn draining(&self) -> bool {
        self.shared.draining()
    }

    /// Block until a drain is requested (signal or trigger), then drain
    /// and report.
    pub fn run_until_drained(self) -> Result<NetReport> {
        while !self.shared.draining() {
            std::thread::sleep(Duration::from_millis(20));
        }
        self.finish()
    }

    /// Execute the drain protocol: stop accepting, stop admitting, finish
    /// live streams within `drain_ms`, force-retire the rest, join every
    /// wire thread, then prove session accounting via `mem_report`.
    pub fn finish(mut self) -> Result<NetReport> {
        self.shared.drain.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            a.join().map_err(|_| anyhow!("accept thread panicked"))?;
        }
        self.shared.handle.begin_drain();
        let drain = self
            .shared
            .handle
            .drain(Duration::from_millis(self.shared.cfg.drain_ms))
            .unwrap_or_default();
        if let Some(c) = self.conn_sup.take() {
            c.join().map_err(|_| anyhow!("connection workers panicked"))?;
        }
        let mem = self.shared.handle.mem_report();
        let leaked = mem.as_ref().map_or(0, |m| m.decode_sessions_live) as usize;
        Ok(NetReport { drain, leaked_sessions: leaked, mem, stats: self.shared.stats.snapshot() })
    }
}

fn accept_loop(listener: TcpListener, disp: SyncSender<TcpStream>, shared: &Shared) {
    loop {
        if shared.draining() {
            return; // drops the listener and the dispatch sender
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.stats.conns.fetch_add(1, Ordering::SeqCst);
                match disp.try_send(stream) {
                    Ok(()) => {}
                    Err(TrySendError::Full(stream)) => {
                        // Every worker busy and the hand-off lane full:
                        // refuse inline, never queue without bound.
                        let mut s = stream;
                        let _ = s.set_write_timeout(Some(Duration::from_millis(500)));
                        let body = err_body("server overloaded: all connection workers busy");
                        let _ = http::write_response(
                            &mut s,
                            503,
                            &[("Retry-After", "1")],
                            body.as_bytes(),
                            false,
                        );
                        shared.stats.count_status(503);
                    }
                    Err(TrySendError::Disconnected(_)) => return,
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn conn_loop(shared: &Shared, rx: &Mutex<Receiver<TcpStream>>) {
    loop {
        // Lock scope ends before serving, so other workers can pick up.
        let stream = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return,
        };
        match stream {
            Ok(s) => serve_conn(shared, s),
            Err(_) => return, // accept loop gone: shutdown
        }
    }
}

fn serve_conn(shared: &Shared, mut stream: TcpStream) {
    let conn_id = shared.conn_seq.fetch_add(1, Ordering::SeqCst);
    let chaos = shared.cfg.chaos;
    if !chaos.is_off() {
        // Listener-side fault injection; participant ids offset so loadgen
        // clients (participant = client index) draw independent streams.
        let mut crng = chaos.rng((1u64 << 32) | conn_id);
        if crng.f32() < chaos.disconnect {
            shared.stats.chaos_disconnects.fetch_add(1, Ordering::SeqCst);
            return; // abortive close before any byte
        }
        if crng.f32() < chaos.stall {
            shared.stats.chaos_stalls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(chaos.stall_ms));
        }
    }
    let _ = stream.set_nodelay(true);
    let io_to = Duration::from_millis(shared.cfg.io_timeout_ms.max(1));
    let _ = stream.set_read_timeout(Some(io_to));
    let _ = stream.set_write_timeout(Some(io_to));
    let mut carry: Vec<u8> = Vec::new();
    loop {
        match read_head(&mut stream, &mut carry) {
            Ok(head) => {
                let keep = handle_request(shared, &mut stream, &mut carry, &head);
                if !keep || shared.draining() {
                    return;
                }
            }
            Err(HeadError::Closed) => return,
            Err(HeadError::Io(e))
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                // Idle keep-alive tick (carry is preserved, so a head split
                // across the timeout still reassembles). Close on drain.
                if shared.draining() {
                    return;
                }
            }
            Err(HeadError::Io(_)) => return,
            Err(HeadError::TooLarge) => {
                respond(shared, &mut stream, 413, &[], &err_body("request head too large"), false, "-");
                return;
            }
            Err(HeadError::Bad(m)) => {
                respond(shared, &mut stream, 400, &[], &err_body(&m), false, "-");
                return;
            }
        }
    }
}

/// Write a fixed response, bump counters, log. Returns nothing; callers
/// decide keep-alive separately (a failed write just closes the socket).
fn respond(
    shared: &Shared,
    stream: &mut TcpStream,
    status: u16,
    extra: &[(&str, &str)],
    body: &str,
    keep_alive: bool,
    route: &str,
) {
    let _ = http::write_response(stream, status, extra, body.as_bytes(), keep_alive);
    shared.stats.count_status(status);
    access_log(shared, route, status, 0, 0, 0, None, None, Duration::ZERO, 0);
}

fn err_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// One structured line per request: ts, route, prompt/gen lens, bucket,
/// replica (which worker served it; `-` for the in-process engine),
/// status, ttfb, total, trace id (`-` for untraced requests) — the fields
/// the ISSUE's access-log gate names. The same trace id appears in SSE
/// `error` events, router dispatch logs, and `GET /trace`, so one request
/// can be followed across processes.
#[allow(clippy::too_many_arguments)]
fn access_log(
    shared: &Shared,
    route: &str,
    status: u16,
    prompt: usize,
    gen: usize,
    bucket: usize,
    replica: Option<usize>,
    ttfb: Option<Duration>,
    total: Duration,
    trace_id: u64,
) {
    if shared.cfg.quiet {
        return;
    }
    let ttfb_ms = ttfb.map_or_else(|| "-".to_string(), |d| format!("{:.1}", d.as_secs_f64() * 1e3));
    let replica = replica.map_or_else(|| "-".to_string(), |r| r.to_string());
    let trace = if trace_id == 0 { "-".to_string() } else { trace::id_hex(trace_id) };
    println!(
        "[serve-net] {} route={} status={} prompt={} gen={} bucket={} replica={} ttfb_ms={} total_ms={:.1} trace={}",
        iso8601(epoch_ms()),
        route,
        status,
        prompt,
        gen,
        bucket,
        replica,
        ttfb_ms,
        total.as_secs_f64() * 1e3,
        trace,
    );
}

/// Serve one parsed request head. Returns whether to keep the connection.
fn handle_request(
    shared: &Shared,
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    head: &RequestHead,
) -> bool {
    // Routes may carry a query string (`/trace?n=K`); match on the path.
    let (path, query) = match head.target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (head.target.as_str(), ""),
    };
    match (head.method.as_str(), path) {
        ("POST", "/generate") => generate_route(shared, stream, carry, head),
        ("GET", "/healthz") => {
            let body = Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(shared.draining())),
                ("capacity", Json::num(shared.handle.capacity() as f64)),
                ("inflight", Json::num(shared.handle.inflight() as f64)),
                ("replicas", Json::num(shared.handle.replicas() as f64)),
            ])
            .to_string();
            respond(shared, stream, 200, &[], &body, head.keep_alive, "/healthz");
            head.keep_alive
        }
        ("GET", "/mem") => {
            let body = match shared.handle.mem_report() {
                Some(m) => mem_json(&m, shared.handle.replicas()),
                None => Json::obj(vec![("available", Json::Bool(false))]).to_string(),
            };
            respond(shared, stream, 200, &[], &body, head.keep_alive, "/mem");
            head.keep_alive
        }
        ("GET", "/metrics") => {
            // The engine seam decides the scope: the in-process worker
            // returns this process's registry, a fleet front merges its
            // own snapshot with every reachable replica's.
            let body = obs::render_prometheus(&shared.handle.metrics());
            respond(
                shared,
                stream,
                200,
                &[("Content-Type", "text/plain; version=0.0.4; charset=utf-8")],
                &body,
                head.keep_alive,
                "/metrics",
            );
            head.keep_alive
        }
        ("GET", "/trace") => {
            let n = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("n="))
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(32)
                .min(trace::RING_CAP);
            let body = trace::dump(n).to_string();
            respond(shared, stream, 200, &[], &body, head.keep_alive, "/trace");
            head.keep_alive
        }
        (_, "/generate") | (_, "/healthz") | (_, "/mem") | (_, "/metrics") | (_, "/trace") => {
            drop_body(stream, carry, head);
            respond(
                shared,
                stream,
                405,
                &[],
                &err_body(&format!("method {} not allowed", head.method)),
                head.keep_alive,
                head.target.as_str(),
            );
            head.keep_alive
        }
        _ => {
            drop_body(stream, carry, head);
            respond(
                shared,
                stream,
                404,
                &[],
                &err_body(&format!("no route {}", head.target)),
                head.keep_alive,
                head.target.as_str(),
            );
            head.keep_alive
        }
    }
}

/// Consume a declared body we are not going to use, keeping pipeline sync.
fn drop_body(stream: &mut TcpStream, carry: &mut Vec<u8>, head: &RequestHead) {
    if let Some(n) = head.content_length {
        let _ = read_exact_body(stream, carry, n);
    }
}

fn mem_json(m: &MemReport, replicas: usize) -> String {
    Json::obj(vec![
        ("replicas", Json::num(replicas as f64)),
        ("params_epoch", Json::num(m.params_epoch as f64)),
        ("decode_sessions_live", Json::num(m.decode_sessions_live as f64)),
        ("decode_sessions_total", Json::num(m.decode_sessions_total as f64)),
        ("decode_steps", Json::num(m.decode_steps as f64)),
        ("decode_step_batches", Json::num(m.decode_step_batches as f64)),
        ("decode_state_bytes", Json::num(m.decode_state_bytes as f64)),
        ("serve_forwards", Json::num(m.serve_forwards as f64)),
        ("max_context", Json::num(m.max_context as f64)),
        ("kernel", Json::str(&m.kernel)),
        (
            "bucket_lens",
            Json::Arr(m.bucket_lens.iter().map(|&b| Json::num(b as f64)).collect()),
        ),
    ])
    .to_string()
}

/// Read and frame the request body (bounded, incremental), parse the
/// generation fields, admit, and stream or block-reply.
fn generate_route(
    shared: &Shared,
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    head: &RequestHead,
) -> bool {
    let t_start = Instant::now();
    let body = match read_request_json(stream, carry, head, shared.cfg.max_body_bytes) {
        Ok(v) => v,
        Err((status, msg)) => {
            // Byte sync with the peer is lost (or the body was hostile):
            // answer and close.
            respond(shared, stream, status, &[], &err_body(&msg), false, "/generate");
            return false;
        }
    };
    let (mut req, want_stream, session) = match parse_generate(&body, shared.cfg.deadline_ms) {
        Ok(x) => x,
        Err(msg) => {
            respond(shared, stream, 400, &[], &err_body(&msg), head.keep_alive, "/generate");
            return head.keep_alive;
        }
    };
    // Mint the trace here — the first point the request exists as a
    // request — unless the caller already carries one (the fleet router
    // forwards its id so replica-side spans land under the same trace).
    if req.trace_id == 0 {
        req.trace_id = trace::mint();
    }
    trace::begin(req.trace_id);
    let prompt_len = req.prompt.len();
    // The read+parse span starts where the request did.
    let t0_us = clock::now_us().saturating_sub(t_start.elapsed().as_micros() as u64);
    trace::span_since(req.trace_id, "parse", t0_us, prompt_len as u64);
    if want_stream {
        stream_generate(shared, stream, head, req, session, prompt_len, t_start)
    } else {
        block_generate(shared, stream, head, req, session, prompt_len, t_start)
    }
}

/// Map an admission refusal to its wire shape. Returns keep-alive.
fn refuse(
    shared: &Shared,
    stream: &mut TcpStream,
    head: &RequestHead,
    e: AdmitError,
) -> bool {
    match e {
        AdmitError::Busy { retry_after } => {
            let secs = retry_after.as_secs().max(1).to_string();
            respond(
                shared,
                stream,
                429,
                &[("Retry-After", secs.as_str())],
                &err_body("server busy: inflight cap reached"),
                head.keep_alive,
                "/generate",
            );
            head.keep_alive
        }
        AdmitError::Draining => {
            obs::serving().draining_rejected.inc();
            respond(
                shared,
                stream,
                503,
                &[("Retry-After", "1")],
                &err_body("server draining"),
                false,
                "/generate",
            );
            false
        }
    }
}

fn stream_generate(
    shared: &Shared,
    stream: &mut TcpStream,
    head: &RequestHead,
    req: GenerateRequest,
    session: Option<String>,
    prompt_len: usize,
    t_start: Instant,
) -> bool {
    let trace_id = req.trace_id;
    let sub_t0 = clock::now_us();
    let sub = match shared.handle.try_submit_stream(req, shared.cfg.token_buf, session.as_deref())
    {
        Ok(sub) => sub,
        Err(e) => {
            trace::finish(trace_id, "rejected");
            return refuse(shared, stream, head, e);
        }
    };
    trace::span_since(trace_id, "admission", sub_t0, 0);
    let replica = sub.replica;
    let rx = sub.rx;
    let m = obs::serving();
    shared.stats.streams.fetch_add(1, Ordering::SeqCst);
    let mut ttfb: Option<Duration> = None;
    let mut gen = 0usize;
    let mut bucket = 0usize;
    let mut clean = false;
    let mut errored = false;
    let io_res: io::Result<()> = (|| {
        let mut sse = SseWriter::start(&mut *stream, head.keep_alive)?;
        loop {
            match rx.recv() {
                Ok(StreamEvent::Token(t)) => {
                    if ttfb.is_none() {
                        let d = t_start.elapsed();
                        m.ttfb_us.observe_us(d);
                        ttfb = Some(d);
                    }
                    gen += 1;
                    shared.stats.tokens.fetch_add(1, Ordering::SeqCst);
                    m.tokens_generated.inc();
                    // Time the wire write: a slow client shows up here as
                    // a stall (the bounded token buffer upstream is what
                    // eventually evicts it).
                    let w0 = clock::now_us();
                    sse.event("token", &format!("{{\"t\":{t}}}"))?;
                    let w_us = clock::now_us().saturating_sub(w0);
                    if w_us > 1_000 {
                        m.write_stall_us.observe(w_us);
                        trace::span(trace_id, "write_stall", w0, w_us, gen as u64);
                    }
                }
                Ok(StreamEvent::Done(resp)) => {
                    bucket = resp.bucket_len;
                    let mut kv = vec![
                        (
                            "tokens",
                            Json::Arr(
                                resp.tokens.iter().map(|&t| Json::num(t as f64)).collect(),
                            ),
                        ),
                        ("bucket_len", Json::num(resp.bucket_len as f64)),
                        ("batch_occupancy", Json::num(resp.batch_occupancy as f64)),
                        ("queue_ms", Json::num(resp.queue_time.as_secs_f64() * 1e3)),
                        ("total_ms", Json::num(resp.total_time.as_secs_f64() * 1e3)),
                    ];
                    if let Some(r) = replica {
                        kv.push(("replica", Json::num(r as f64)));
                    }
                    let data = Json::obj(kv).to_string();
                    sse.event("done", &data)?;
                    clean = true;
                    return sse.finish();
                }
                Ok(StreamEvent::Error { message, partial }) => {
                    errored = true;
                    let data = Json::obj(vec![
                        ("message", Json::str(&message)),
                        ("partial", Json::num(partial as f64)),
                        ("trace_id", Json::str(&trace::id_hex(trace_id))),
                    ])
                    .to_string();
                    sse.event("error", &data)?;
                    clean = true;
                    return sse.finish();
                }
                // Engine worker terminated: end the stream explicitly.
                Err(_) => {
                    errored = true;
                    let data = Json::obj(vec![
                        ("message", Json::str("server worker terminated")),
                        ("partial", Json::num(0.0)),
                        ("trace_id", Json::str(&trace::id_hex(trace_id))),
                    ])
                    .to_string();
                    let _ = sse.event("error", &data);
                    return sse.finish();
                }
            }
        }
    })();
    // A write failure means the client stalled past its timeout or hung
    // up; dropping `rx` is the recovery — the worker's next push observes
    // a dead channel and retires the session.
    drop(rx);
    let total = t_start.elapsed();
    m.request_us.observe_us(total);
    if errored || io_res.is_err() || !clean {
        m.stream_errors.inc();
    } else {
        m.streams_completed.inc();
    }
    trace::span(trace_id, "stream", sub_t0, clock::now_us().saturating_sub(sub_t0), gen as u64);
    trace::finish(trace_id, if errored || io_res.is_err() || !clean { "error" } else { "done" });
    shared.stats.count_status(200);
    access_log(
        shared,
        "/generate",
        200,
        prompt_len,
        gen,
        bucket,
        replica,
        ttfb,
        total,
        trace_id,
    );
    io_res.is_ok() && clean && head.keep_alive
}

fn block_generate(
    shared: &Shared,
    stream: &mut TcpStream,
    head: &RequestHead,
    req: GenerateRequest,
    session: Option<String>,
    prompt_len: usize,
    t_start: Instant,
) -> bool {
    let trace_id = req.trace_id;
    let sub_t0 = clock::now_us();
    let sub = match shared.handle.try_submit_stream(req, shared.cfg.token_buf, session.as_deref())
    {
        Ok(sub) => sub,
        Err(e) => {
            trace::finish(trace_id, "rejected");
            return refuse(shared, stream, head, e);
        }
    };
    trace::span_since(trace_id, "admission", sub_t0, 0);
    let replica = sub.replica;
    // Blocking replies ride the streaming admission seam (the only one
    // the Engine trait exposes): drain token events, answer from the
    // terminal `Done` — it repeats the full sequence by construction.
    let outcome = loop {
        match sub.rx.recv() {
            Ok(StreamEvent::Token(_)) => {}
            Ok(StreamEvent::Done(resp)) => break Some(Ok(resp)),
            Ok(StreamEvent::Error { message, .. }) => break Some(Err(message)),
            Err(_) => break None,
        }
    };
    let (status, body, gen, bucket) = match outcome {
        Some(Ok(resp)) => {
            let mut kv = vec![
                (
                    "tokens",
                    Json::Arr(resp.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                ),
                ("bucket_len", Json::num(resp.bucket_len as f64)),
                ("batch_occupancy", Json::num(resp.batch_occupancy as f64)),
                ("queue_ms", Json::num(resp.queue_time.as_secs_f64() * 1e3)),
                ("total_ms", Json::num(resp.total_time.as_secs_f64() * 1e3)),
            ];
            if let Some(r) = replica {
                kv.push(("replica", Json::num(r as f64)));
            }
            (200u16, Json::obj(kv).to_string(), resp.tokens.len(), resp.bucket_len)
        }
        Some(Err(msg)) => {
            let status = if msg.contains("deadline exceeded") {
                504
            } else if msg.contains("out of range") {
                400
            } else {
                500
            };
            (status, err_body(&msg), 0, 0)
        }
        None => (500u16, err_body("server worker terminated"), 0, 0),
    };
    let _ = http::write_response(stream, status, &[], body.as_bytes(), head.keep_alive);
    let total = t_start.elapsed();
    let m = obs::serving();
    m.request_us.observe_us(total);
    if status == 200 {
        m.tokens_generated.add(gen as u64);
        m.streams_completed.inc();
    } else {
        m.stream_errors.inc();
    }
    trace::span(trace_id, "stream", sub_t0, clock::now_us().saturating_sub(sub_t0), gen as u64);
    trace::finish(trace_id, if status == 200 { "done" } else { "error" });
    shared.stats.count_status(status);
    access_log(
        shared,
        "/generate",
        status,
        prompt_len,
        gen,
        bucket,
        replica,
        None,
        total,
        trace_id,
    );
    head.keep_alive
}

/// Frame the request body into one JSON object. With a Content-Length the
/// exact bytes are read then framed (bounds still enforced); without one
/// the reader frames straight off the socket and returns surplus bytes to
/// `carry` (keep-alive pipelining).
fn read_request_json(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    head: &RequestHead,
    max: usize,
) -> std::result::Result<Json, (u16, String)> {
    let mut rd = JsonReader::new(max);
    if let Some(n) = head.content_length {
        if n > max {
            return Err((413, format!("request body {n} bytes exceeds cap {max}")));
        }
        let body = read_exact_body(stream, carry, n)
            .map_err(|e| (400, format!("body read failed: {e}")))?;
        return match rd.feed(&body) {
            Ok(Frame::Complete(v)) => {
                if rd.pending() > 0 {
                    return Err((400, "trailing bytes after request object".into()));
                }
                Ok(v)
            }
            Ok(Frame::Incomplete) => {
                Err((400, "request body truncated (content-length too short?)".into()))
            }
            Err(e) => Err((400, e.to_string())),
        };
    }
    // No Content-Length: incremental framing is the only boundary.
    let seed: Vec<u8> = std::mem::take(carry);
    let mut outcome = rd.feed(&seed);
    loop {
        match outcome {
            Ok(Frame::Complete(v)) => {
                *carry = rd.take_rest();
                return Ok(v);
            }
            Ok(Frame::Incomplete) => {}
            Err(e) => return Err((400, e.to_string())),
        }
        let mut buf = [0u8; 2048];
        match stream.read(&mut buf) {
            Ok(0) => return Err((400, "eof inside request body".into())),
            Ok(n) => outcome = rd.feed(&buf[..n]),
            Err(e) => return Err((408, format!("body read stalled: {e}"))),
        }
    }
}

/// `{"prompt":[...], "max_new":N, "temperature":t, "top_k":k,
/// "timeout_ms":N, "stream":bool, "session":"key", "trace_id":"hex"}` →
/// request + stream flag + session-affinity key. Shared with the replica
/// RPC endpoint (`net::router`), whose `gen` frames reuse this grammar.
pub(crate) fn parse_generate(
    v: &Json,
    default_deadline_ms: u64,
) -> std::result::Result<(GenerateRequest, bool, Option<String>), String> {
    let arr = v
        .get("prompt")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| "missing \"prompt\" array".to_string())?;
    let mut prompt = Vec::with_capacity(arr.len());
    for x in arr {
        let f = x.as_f64().ok_or_else(|| "prompt tokens must be numbers".to_string())?;
        if f < 0.0 || f != f.trunc() || f > i32::MAX as f64 {
            return Err(format!("prompt token {f} is not a token id"));
        }
        prompt.push(f as i32);
    }
    let max_new = v.get("max_new").and_then(|x| x.as_usize()).unwrap_or(16);
    let sampling = match v.get("temperature").and_then(|x| x.as_f64()) {
        None => Sampling::Greedy,
        Some(t) if t <= 0.0 => Sampling::Greedy,
        Some(t) => Sampling::Temperature {
            t: t as f32,
            top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0),
        },
    };
    let timeout_ms = v
        .get("timeout_ms")
        .and_then(|x| x.as_f64())
        .map(|f| f.max(0.0) as u64)
        .unwrap_or(default_deadline_ms);
    let deadline = if timeout_ms == 0 { None } else { Some(Duration::from_millis(timeout_ms)) };
    let want_stream = v.get("stream").and_then(|x| x.as_bool()).unwrap_or(true);
    // Optional session-affinity key: a replica fleet pins every request
    // carrying the same key to one worker.
    let session = v.get("session").and_then(|x| x.as_str()).map(|s| s.to_string());
    // Optional trace id (16 hex chars). The fleet router stamps its minted
    // id into the replica-bound frame so both processes trace under one
    // id; absent (the normal client case) the front end mints one.
    let trace_id = v
        .get("trace_id")
        .and_then(|x| x.as_str())
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .unwrap_or(0);
    Ok((GenerateRequest { prompt, max_new, sampling, deadline, trace_id }, want_stream, session))
}
