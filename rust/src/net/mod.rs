//! Network serving front end: HTTP/1.1 + SSE token streaming over the
//! session engine, robust-by-construction (DESIGN.md §Serving-Net).
//!
//! Layering:
//! * [`jsonrd`] — incremental streaming JSON request reader (framing,
//!   bounds, pipelining; property-fuzzed against splits/truncation/garbage).
//! * [`http`] — HTTP/1.1 byte substrate: bounded request heads, fixed
//!   responses, chunked SSE event streams.
//! * [`server`] — the listener: connection workers on a `util::pool`
//!   WorkerPool, request routing, per-request deadlines, admission control
//!   with backpressure (429 + Retry-After), slow-client eviction, graceful
//!   drain (SIGTERM/ctrl-c), structured access logs.
//! * [`client`] — minimal keep-alive HTTP/SSE client + the chaos loadgen
//!   that drives the resilience gates.
//! * [`router`] — replica-parallel serving: framed-RPC worker endpoints
//!   ([`router::ReplicaServer`]) and the least-loaded, session-affine
//!   fleet front ([`router::FleetHandle`]) with health probing, failover
//!   and epoch-synchronized weight broadcast.
//!
//! This module owns the pieces both sides share: [`ChaosConfig`] (seeded
//! fault injection, `HYENA_CHAOS`), [`NetConfig`] (listener tuning) and the
//! access-log timestamp helper. Everything is std-only.

pub mod client;
pub mod http;
pub mod jsonrd;
pub mod router;
pub mod server;

use crate::util::rng::Pcg;

/// Deterministic fault-injection plan, parsed from
/// `HYENA_CHAOS=disconnect:p,stall:p,garbage:p[,stall_ms:N][,seed:N]`.
///
/// The same config drives both sides of the wire (the chaos matrix in
/// DESIGN.md §Serving-Net):
/// * **loadgen clients** inject `garbage` (malformed request bytes → the
///   400 path), `disconnect` (socket closed mid-stream → the worker's
///   token push observes a dead stream and retires the session), and
///   `stall` (the client stops reading for `stall_ms` → the bounded write
///   buffer fills and the server evicts the slow client);
/// * **the listener** injects `disconnect` (abortive close after accept)
///   and `stall` (delayed first write), exercising the client/loadgen
///   recovery paths in turn.
///
/// Decisions come from a seeded [`Pcg`] stream per participant
/// ([`ChaosConfig::rng`]), so a failing chaos run replays exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    /// Probability of dropping the connection mid-request/stream.
    pub disconnect: f32,
    /// Probability of stalling (not reading / delaying a write).
    pub stall: f32,
    /// Probability of sending a malformed request (loadgen only).
    pub garbage: f32,
    /// Stall duration when a stall fires.
    pub stall_ms: u64,
    /// Base seed for the per-participant decision streams.
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig { disconnect: 0.0, stall: 0.0, garbage: 0.0, stall_ms: 200, seed: 0 }
    }
}

impl ChaosConfig {
    /// No faults at all.
    pub fn off() -> ChaosConfig {
        ChaosConfig::default()
    }

    pub fn is_off(&self) -> bool {
        self.disconnect <= 0.0 && self.stall <= 0.0 && self.garbage <= 0.0
    }

    /// Parse the `HYENA_CHAOS` spelling. Unknown keys and malformed pairs
    /// are errors — a chaos run with a silently-ignored typo would "pass"
    /// without injecting anything.
    pub fn parse(spec: &str) -> Result<ChaosConfig, String> {
        let mut c = ChaosConfig::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((k, v)) = part.split_once(':') else {
                return Err(format!("chaos spec {part:?} is not key:value"));
            };
            let (k, v) = (k.trim(), v.trim());
            let prob = |v: &str| -> Result<f32, String> {
                let p: f32 =
                    v.parse().map_err(|_| format!("chaos {k}: bad probability {v:?}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("chaos {k}: probability {p} outside [0,1]"));
                }
                Ok(p)
            };
            match k {
                "disconnect" => c.disconnect = prob(v)?,
                "stall" => c.stall = prob(v)?,
                "garbage" => c.garbage = prob(v)?,
                "stall_ms" => {
                    c.stall_ms =
                        v.parse().map_err(|_| format!("chaos stall_ms: bad value {v:?}"))?
                }
                "seed" => {
                    c.seed = v.parse().map_err(|_| format!("chaos seed: bad value {v:?}"))?
                }
                _ => return Err(format!("chaos spec has unknown key {k:?}")),
            }
        }
        Ok(c)
    }

    /// Read `HYENA_CHAOS` (absent/empty → off; malformed → `Err`, loud).
    pub fn from_env() -> Result<ChaosConfig, String> {
        match std::env::var("HYENA_CHAOS") {
            Ok(v) if !v.trim().is_empty() => ChaosConfig::parse(&v),
            _ => Ok(ChaosConfig::off()),
        }
    }

    /// Decision stream for one participant (a loadgen client index, or the
    /// listener). Distinct participants get independent streams so adding
    /// a draw in one never shifts another — same discipline as the data
    /// generators.
    pub fn rng(&self, participant: u64) -> Pcg {
        Pcg::with_stream(self.seed ^ 0xc0a5_5e11, participant)
    }
}

/// Listener tuning. Everything has a serving-sane default; the CLI maps
/// `serve --listen` flags onto the fields it exposes.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address (`127.0.0.1:8199`; port 0 picks a free port, printed
    /// at startup and exposed via `NetServer::addr`).
    pub addr: String,
    /// Connection worker threads — the hard cap on concurrently *served*
    /// connections; accepts beyond it queue briefly, then get 503.
    pub conn_threads: usize,
    /// Generation requests allowed to wait in the engine queue beyond live
    /// session capacity before submissions bounce with 429 + Retry-After.
    pub queue_cap: usize,
    /// Per-stream bounded token buffer (tokens the engine may run ahead of
    /// a slow client before evicting it).
    pub token_buf: usize,
    /// Default per-request deadline when the request carries no
    /// `timeout_ms` (0 = no default deadline).
    pub deadline_ms: u64,
    /// Budget for finishing live streams after drain begins; sessions
    /// still live at the deadline are force-retired with an error event.
    pub drain_ms: u64,
    /// Socket read timeout (idle keep-alive connections poll drain at this
    /// cadence) and write timeout (a write blocked longer means the client
    /// is gone or hopeless).
    pub io_timeout_ms: u64,
    /// Request body cap handed to the JSON reader.
    pub max_body_bytes: usize,
    /// Listener-side fault injection (off in production).
    pub chaos: ChaosConfig,
    /// Suppress per-request access logs (gates still see the summary).
    pub quiet: bool,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            addr: "127.0.0.1:8199".into(),
            conn_threads: 32,
            queue_cap: 0, // 0 = 2 × session capacity, resolved at start
            token_buf: 128,
            deadline_ms: 30_000,
            drain_ms: 5_000,
            io_timeout_ms: 10_000,
            max_body_bytes: 4 << 20,
            chaos: ChaosConfig::off(),
            quiet: false,
        }
    }
}

/// Milliseconds since the Unix epoch (the access log's `ts`). Delegates to
/// the [`crate::obs::clock`] seam — the telemetry layer's single wall-clock
/// source — so log stamps and trace birth stamps agree.
pub fn epoch_ms() -> u128 {
    crate::obs::clock::epoch_ms()
}

/// UTC ISO-8601 `YYYY-MM-DDTHH:MM:SS.mmmZ` for an epoch-milliseconds
/// stamp (civil-from-days, Howard Hinnant's algorithm) — hand-rolled
/// because the vendored set has no chrono and a raw epoch integer makes
/// access logs needlessly hostile to humans.
pub fn iso8601(epoch_ms: u128) -> String {
    let secs = (epoch_ms / 1000) as i64;
    let ms = (epoch_ms % 1000) as u32;
    let days = secs.div_euclid(86_400);
    let sod = secs.rem_euclid(86_400);
    let (h, m, s) = (sod / 3600, (sod % 3600) / 60, sod % 60);
    // Civil-from-days: shift epoch to 0000-03-01-based eras.
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let month = if mp < 10 { mp + 3 } else { mp - 9 };
    let year = if month <= 2 { y + 1 } else { y };
    format!("{year:04}-{month:02}-{d:02}T{h:02}:{m:02}:{s:02}.{ms:03}Z")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_parses_the_documented_spelling() {
        let c = ChaosConfig::parse("disconnect:0.25,stall:0.5,garbage:0.1").unwrap();
        assert_eq!(c.disconnect, 0.25);
        assert_eq!(c.stall, 0.5);
        assert_eq!(c.garbage, 0.1);
        assert_eq!(c.stall_ms, 200);
        let c = ChaosConfig::parse("garbage:1,seed:42,stall_ms:50").unwrap();
        assert_eq!((c.garbage, c.seed, c.stall_ms), (1.0, 42, 50));
        assert!(ChaosConfig::parse("").unwrap().is_off());
    }

    #[test]
    fn chaos_rejects_typos_loudly() {
        assert!(ChaosConfig::parse("disconect:0.5").is_err());
        assert!(ChaosConfig::parse("disconnect:1.5").is_err());
        assert!(ChaosConfig::parse("disconnect").is_err());
        assert!(ChaosConfig::parse("stall:x").is_err());
    }

    #[test]
    fn chaos_streams_are_deterministic_and_per_participant() {
        let c = ChaosConfig::parse("disconnect:0.5,seed:7").unwrap();
        let a: Vec<u32> = {
            let mut r = c.rng(0);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let a2: Vec<u32> = {
            let mut r = c.rng(0);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = c.rng(1);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, a2, "chaos stream not replayable");
        assert_ne!(a, b, "participants share a chaos stream");
    }

    #[test]
    fn iso8601_known_stamps() {
        assert_eq!(iso8601(0), "1970-01-01T00:00:00.000Z");
        // 2000-03-01 00:00:00 UTC = 951868800s (leap-century boundary).
        assert_eq!(iso8601(951_868_800_000), "2000-03-01T00:00:00.000Z");
        // 2026-08-11 12:34:56.789 UTC.
        assert_eq!(iso8601(1_786_451_696_789), "2026-08-11T12:34:56.789Z");
    }
}
