//! Incremental streaming JSON request reader (the serving wire's framer).
//!
//! A network peer hands the server bytes, not values: a request body can
//! arrive split at any byte boundary, truncated, oversized, or as garbage
//! that was never JSON. [`JsonReader`] turns that byte stream back into
//! `util::json::Json` values without buffering more than one frame:
//!
//! * **Framing** is a byte-level scanner (string/escape/nesting aware) that
//!   runs as bytes arrive and never re-scans a byte: `feed` is O(new bytes).
//!   A frame is one complete top-level JSON *object* — requests are always
//!   objects, so any first significant byte other than `{` is rejected
//!   immediately instead of waiting for a balance that will never come.
//! * **Parsing** reuses [`Json::parse`] on the framed slice, so the wire
//!   path cannot drift from the manifest/report parser's grammar. Balanced
//!   but invalid bytes (`{"a":tru}`) fail there, with a byte position.
//! * **Bounds**: a frame that exceeds `max_bytes` without completing is an
//!   error, so a hostile or broken client cannot grow the buffer without
//!   limit — the reader is the wire's first backpressure point.
//! * **Pipelining**: bytes after a completed frame are kept for the next
//!   call, so keep-alive clients may send back-to-back requests.
//!
//! The scanner is property-fuzzed with `util::prop` below: every serialized
//! value split at every byte offset reassembles to the same value, no
//! strict prefix ever completes, and garbage/oversize inputs error without
//! panicking — the truncation/split/garbage gate of the serving ISSUE.

use crate::util::json::Json;

/// Outcome of feeding bytes to the reader.
#[derive(Debug, PartialEq)]
pub enum Frame {
    /// No complete frame yet — feed more bytes.
    Incomplete,
    /// One complete value (trailing bytes, if any, are retained).
    Complete(Json),
}

/// Why the byte stream cannot be a request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonRdError {
    /// First significant byte was not `{` — not a request object.
    NotAnObject { byte: u8, pos: usize },
    /// The frame grew past the configured size cap before completing.
    TooLarge { cap: usize },
    /// Braces balanced but the bytes are not valid JSON.
    Parse(String),
}

impl std::fmt::Display for JsonRdError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonRdError::NotAnObject { byte, pos } => write!(
                f,
                "request is not a JSON object (byte {byte:#04x} at offset {pos})"
            ),
            JsonRdError::TooLarge { cap } => {
                write!(f, "request body exceeds {cap} bytes")
            }
            JsonRdError::Parse(msg) => write!(f, "request body is not valid JSON: {msg}"),
        }
    }
}

impl std::error::Error for JsonRdError {}

/// Incremental reader for one connection. Reusable across frames (keep the
/// instance for the connection's lifetime); a frame error poisons the
/// stream — callers should close the connection, as byte sync is lost.
#[derive(Debug)]
pub struct JsonReader {
    buf: Vec<u8>,
    max_bytes: usize,
    /// Scan frontier: bytes before `pos` have been classified already.
    pos: usize,
    /// Current brace/bracket nesting depth (strings excluded).
    depth: usize,
    /// Inside a string literal.
    in_str: bool,
    /// Previous in-string byte was a backslash.
    esc: bool,
    /// Seen the opening `{` of the current frame.
    started: bool,
    /// A frame error occurred; the stream is out of sync.
    poisoned: bool,
}

impl JsonReader {
    pub fn new(max_bytes: usize) -> JsonReader {
        JsonReader {
            buf: Vec::new(),
            max_bytes,
            pos: 0,
            depth: 0,
            in_str: false,
            esc: false,
            started: false,
            poisoned: false,
        }
    }

    /// Bytes buffered but not yet consumed by a completed frame.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Hand back the unconsumed residue (bytes past the last completed
    /// frame) and reset the scanner — the connection loop returns these to
    /// its carry buffer so pipelined HTTP requests stay in byte sync.
    pub fn take_rest(&mut self) -> Vec<u8> {
        self.pos = 0;
        self.depth = 0;
        self.in_str = false;
        self.esc = false;
        self.started = false;
        std::mem::take(&mut self.buf)
    }

    /// Append `bytes` and scan for a frame boundary. On `Complete`, the
    /// frame's bytes are consumed; the remainder stays buffered for the
    /// next call (`feed(&[])` continues scanning retained bytes).
    pub fn feed(&mut self, bytes: &[u8]) -> Result<Frame, JsonRdError> {
        if self.poisoned {
            return Err(JsonRdError::Parse("stream poisoned by earlier error".into()));
        }
        self.buf.extend_from_slice(bytes);
        while self.pos < self.buf.len() {
            let c = self.buf[self.pos];
            if !self.started {
                // Leading whitespace is legal between frames; anything else
                // that is not `{` can never frame a request object.
                if c == b' ' || c == b'\t' || c == b'\r' || c == b'\n' {
                    self.pos += 1;
                    continue;
                }
                if c != b'{' {
                    self.poisoned = true;
                    return Err(JsonRdError::NotAnObject { byte: c, pos: self.pos });
                }
                // Drop inter-frame whitespace so the cap measures the frame.
                self.buf.drain(..self.pos);
                self.pos = 0;
                self.started = true;
                self.depth = 1;
                self.pos += 1;
                continue;
            }
            if self.in_str {
                if self.esc {
                    self.esc = false;
                } else if c == b'\\' {
                    self.esc = true;
                } else if c == b'"' {
                    self.in_str = false;
                }
            } else {
                match c {
                    b'"' => self.in_str = true,
                    b'{' | b'[' => self.depth += 1,
                    b'}' | b']' => {
                        // A stray closer below depth 1 is caught by the
                        // parser below once the frame "balances"; depth is
                        // saturating so the scanner itself cannot underflow.
                        self.depth = self.depth.saturating_sub(1);
                    }
                    _ => {}
                }
            }
            self.pos += 1;
            if self.started && self.depth == 0 {
                // Frame closed: hand the exact byte range to the parser.
                let frame_end = self.pos;
                let text = std::str::from_utf8(&self.buf[..frame_end])
                    .map_err(|e| JsonRdError::Parse(e.to_string()));
                let parsed = text.and_then(|t| {
                    Json::parse(t).map_err(|e| JsonRdError::Parse(e.to_string()))
                });
                // Reset for the next frame whether or not parse succeeded —
                // the brace scan consumed a balanced region either way.
                self.buf.drain(..frame_end);
                self.pos = 0;
                self.started = false;
                self.in_str = false;
                self.esc = false;
                return match parsed {
                    Ok(v) => Ok(Frame::Complete(v)),
                    Err(e) => {
                        self.poisoned = true;
                        Err(e)
                    }
                };
            }
        }
        if self.buf.len() > self.max_bytes {
            self.poisoned = true;
            return Err(JsonRdError::TooLarge { cap: self.max_bytes });
        }
        Ok(Frame::Incomplete)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;
    use crate::util::rng::Pcg;

    /// Random JSON value generator for the fuzz properties (depth-bounded).
    fn gen_json(rng: &mut Pcg, depth: usize) -> Json {
        let roll = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match roll {
            0 => Json::Null,
            1 => Json::Bool(rng.f32() < 0.5),
            2 => Json::Num((rng.below(100_000) as f64) - 50_000.0),
            3 => {
                let n = rng.usize_below(8);
                let s: String = (0..n)
                    .map(|_| {
                        // Cover escapes, unicode, and plain ASCII.
                        const POOL: &[char] =
                            &['a', 'Z', '"', '\\', '\n', 'é', '😀', ' ', ':', '{', '}'];
                        POOL[rng.usize_below(POOL.len())]
                    })
                    .collect();
                Json::Str(s)
            }
            4 => {
                let n = rng.usize_below(4);
                Json::Arr((0..n).map(|_| gen_json(rng, depth - 1)).collect())
            }
            _ => gen_obj(rng, depth - 1),
        }
    }

    fn gen_obj(rng: &mut Pcg, depth: usize) -> Json {
        let n = rng.usize_below(5);
        Json::Obj(
            (0..n)
                .map(|i| (format!("k{i}"), gen_json(rng, depth)))
                .collect(),
        )
    }

    #[test]
    fn whole_frame_in_one_feed() {
        let mut r = JsonReader::new(1 << 16);
        let got = r.feed(br#"{"prompt":[1,2,3],"max_new":4}"#).unwrap();
        let Frame::Complete(v) = got else { panic!("expected a complete frame") };
        assert_eq!(v.get("max_new").unwrap().as_usize(), Some(4));
        assert_eq!(r.pending(), 0);
    }

    #[test]
    fn prop_split_at_every_byte_offset_reassembles() {
        // The wire gives no framing guarantees: a request must reassemble
        // identically no matter where the kernel splits the bytes.
        Prop::new("jsonrd split-at-every-byte").cases(60).check(|rng| {
            let v = gen_obj(rng, 2);
            let s = v.to_string();
            let bytes = s.as_bytes();
            for cut in 0..=bytes.len() {
                let mut r = JsonReader::new(1 << 16);
                let first = r.feed(&bytes[..cut]).map_err(|e| format!("{e} at cut {cut}"))?;
                if cut < bytes.len() {
                    prop_assert!(
                        first == Frame::Incomplete,
                        "strict prefix completed at cut {cut} of {s:?}"
                    );
                    let second =
                        r.feed(&bytes[cut..]).map_err(|e| format!("{e} at cut {cut}"))?;
                    prop_assert!(
                        second == Frame::Complete(v.clone()),
                        "split at {cut} reassembled wrong for {s:?}"
                    );
                } else {
                    prop_assert!(
                        first == Frame::Complete(v.clone()),
                        "whole buffer did not complete for {s:?}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_byte_at_a_time_matches_one_shot() {
        Prop::new("jsonrd byte-at-a-time").cases(60).check(|rng| {
            let v = gen_obj(rng, 2);
            let s = v.to_string();
            let mut r = JsonReader::new(1 << 16);
            let mut done = None;
            for (i, b) in s.as_bytes().iter().enumerate() {
                match r.feed(std::slice::from_ref(b)).map_err(|e| format!("{e}"))? {
                    Frame::Incomplete => {
                        prop_assert!(i + 1 < s.len(), "never completed: {s:?}")
                    }
                    Frame::Complete(got) => {
                        prop_assert!(i + 1 == s.len(), "completed early at byte {i}: {s:?}");
                        done = Some(got);
                    }
                }
            }
            prop_assert!(done == Some(v), "value mismatch for {s:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_truncation_never_yields_a_value() {
        Prop::new("jsonrd truncation").cases(60).check(|rng| {
            let v = gen_obj(rng, 2);
            let s = v.to_string();
            let cut = rng.usize_below(s.len().max(1));
            let mut r = JsonReader::new(1 << 16);
            let got = r.feed(&s.as_bytes()[..cut]).map_err(|e| format!("{e}"))?;
            prop_assert!(got == Frame::Incomplete, "truncated frame completed: {s:?}@{cut}");
            Ok(())
        });
    }

    #[test]
    fn prop_garbage_errors_without_panicking() {
        Prop::new("jsonrd garbage").cases(120).check(|rng| {
            let n = 1 + rng.usize_below(64);
            let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let mut r = JsonReader::new(1 << 12);
            // Any outcome but a panic is acceptable; a Complete must at
            // least be an object (the only frame the scanner accepts).
            if let Ok(Frame::Complete(v)) = r.feed(&junk) {
                prop_assert!(
                    matches!(v, Json::Obj(_)),
                    "non-object completed from garbage: {v:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_non_object_first_byte() {
        for body in ["[1,2,3]", "42", "\"hi\"", "GET / HTTP/1.1", "tru"] {
            let mut r = JsonReader::new(1 << 12);
            match r.feed(body.as_bytes()) {
                Err(JsonRdError::NotAnObject { .. }) => {}
                other => panic!("{body:?} should be NotAnObject, got {other:?}"),
            }
        }
    }

    #[test]
    fn rejects_oversized_frames() {
        let mut r = JsonReader::new(16);
        // An unterminated object that keeps growing must hit the cap.
        let mut out = None;
        for _ in 0..8 {
            match r.feed(br#"{"k":"xxxxxxxx"#) {
                Ok(Frame::Incomplete) => continue,
                other => {
                    out = Some(other);
                    break;
                }
            }
        }
        match out {
            Some(Err(JsonRdError::TooLarge { cap: 16 })) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn balanced_but_invalid_is_a_parse_error() {
        let mut r = JsonReader::new(1 << 12);
        match r.feed(b"{\"a\":tru}") {
            Err(JsonRdError::Parse(_)) => {}
            other => panic!("expected Parse error, got {other:?}"),
        }
        // The stream is poisoned afterwards: byte sync is gone.
        assert!(r.feed(b"{}").is_err());
    }

    #[test]
    fn pipelined_frames_come_out_one_per_feed() {
        let mut r = JsonReader::new(1 << 12);
        let two = br#"{"a":1} {"b":2}"#;
        let Frame::Complete(first) = r.feed(two).unwrap() else {
            panic!("first frame incomplete")
        };
        assert_eq!(first.get("a").unwrap().as_usize(), Some(1));
        assert!(r.pending() > 0, "second frame's bytes were dropped");
        let Frame::Complete(second) = r.feed(&[]).unwrap() else {
            panic!("second frame incomplete")
        };
        assert_eq!(second.get("b").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn braces_inside_strings_do_not_frame() {
        let mut r = JsonReader::new(1 << 12);
        let s = br#"{"a":"}{","b":"\"}\""}"#;
        let Frame::Complete(v) = r.feed(s).unwrap() else { panic!("incomplete") };
        assert_eq!(v.get("a").unwrap().as_str(), Some("}{"));
        assert_eq!(v.get("b").unwrap().as_str(), Some("\"}\""));
    }
}
