//! Keep-alive HTTP/SSE client + the chaos loadgen (DESIGN.md
//! §Serving-Net).
//!
//! [`HttpClient`] is the minimal counterpart to `net::server`: persistent
//! connection, fixed-length requests, fixed or chunked/SSE responses. It
//! exists for three consumers — the loopback e2e tests (byte-level
//! assertions against the wire), the `loadgen` CLI subcommand, and
//! `benches/native_serve_net.rs` (ttfb / ms-per-token percentiles).
//!
//! [`run_loadgen`] drives N concurrent keep-alive clients with
//! deterministic fault injection ([`ChaosConfig`]): `garbage` sends bytes
//! that were never JSON (the 400 path), `disconnect` hangs up mid-stream
//! (the server-side silent-retire path), `stall` stops reading mid-stream
//! (the bounded-buffer eviction path). Every client draws its faults from
//! its own seeded Pcg stream, so a failing run replays exactly.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::net::http::{find_crlfcrlf, read_exact_body};
use crate::net::ChaosConfig;
use crate::util::json::Json;

/// A parsed fixed-length response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    /// Lower-cased names, trimmed values.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Server will keep the connection after this response.
    pub keep_alive: bool,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn json(&self) -> Option<Json> {
        std::str::from_utf8(&self.body)
            .ok()
            .and_then(|s| Json::parse(s).ok())
    }
}

/// Outcome of one `/generate` SSE stream.
#[derive(Debug, Default)]
pub struct StreamOutcome {
    pub status: u16,
    /// Tokens received as `token` events, in order.
    pub tokens: Vec<i32>,
    /// The `done` event payload, when the stream completed normally.
    pub done: Option<Json>,
    /// The `error` event payload (deadline / eviction / drain), when the
    /// server terminated the stream abnormally but *explicitly*.
    pub error: Option<Json>,
    /// Time to first token event.
    pub ttfb: Option<Duration>,
    pub total: Duration,
    /// This client aborted the stream on purpose (fault injection).
    pub aborted: bool,
    /// For non-200 statuses: the fixed error body.
    pub reject: Option<Response>,
}

/// Client-side fault to inject into one streaming request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    None,
    /// Hang up (drop the socket) after receiving this many token events.
    DisconnectAfter(usize),
    /// Stop reading for the given duration after this many token events,
    /// then resume — if the pause outruns the server's write timeout plus
    /// token buffer, the server evicts the stream.
    StallAfter(usize, Duration),
}

/// Minimal keep-alive HTTP client over one TCP connection.
pub struct HttpClient {
    stream: TcpStream,
    carry: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr, timeout: Duration) -> io::Result<HttpClient> {
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        Ok(HttpClient { stream, carry: Vec::new() })
    }

    /// Send one request head + body.
    fn send(&mut self, method: &str, path: &str, body: &[u8]) -> io::Result<()> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: hyena\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()
    }

    /// Send raw bytes where a request should be (the garbage fault).
    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    /// Read a complete fixed-length response (after `send`/`send_raw`).
    pub fn read_response(&mut self) -> io::Result<Response> {
        let (status, headers, keep_alive) = self.read_response_head()?;
        let len = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .and_then(|(_, v)| v.parse::<usize>().ok())
            .unwrap_or(0);
        let body = read_exact_body(&mut self.stream, &mut self.carry, len)?;
        Ok(Response { status, headers, body, keep_alive })
    }

    pub fn get(&mut self, path: &str) -> io::Result<Response> {
        self.send("GET", path, b"")?;
        self.read_response()
    }

    pub fn post(&mut self, path: &str, body: &str) -> io::Result<Response> {
        self.send("POST", path, body.as_bytes())?;
        self.read_response()
    }

    /// POST `/generate` and consume the SSE stream (or the fixed rejection
    /// body on 4xx/5xx), optionally injecting a client-side fault.
    pub fn generate_stream(&mut self, body: &str, fault: Fault) -> io::Result<StreamOutcome> {
        let t0 = Instant::now();
        self.send("POST", "/generate", body.as_bytes())?;
        let (status, headers, keep_alive) = self.read_response_head()?;
        let mut out = StreamOutcome { status, ..StreamOutcome::default() };
        let chunked = headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked"));
        if status != 200 || !chunked {
            // Fixed body: a rejection (429/503/400...) or a non-stream 200.
            let len = headers
                .iter()
                .find(|(k, _)| k == "content-length")
                .and_then(|(_, v)| v.parse::<usize>().ok())
                .unwrap_or(0);
            let body = read_exact_body(&mut self.stream, &mut self.carry, len)?;
            out.reject = Some(Response { status, headers, body, keep_alive });
            out.total = t0.elapsed();
            return Ok(out);
        }
        // SSE over chunked encoding: one event per chunk, zero-chunk end.
        loop {
            let payload = match self.read_chunk()? {
                Some(p) => p,
                None => break, // terminating chunk: stream over
            };
            let Some((event, data)) = parse_sse_record(&payload) else {
                continue;
            };
            match event.as_str() {
                "token" => {
                    if out.ttfb.is_none() {
                        out.ttfb = Some(t0.elapsed());
                    }
                    if let Some(t) =
                        Json::parse(&data).ok().and_then(|v| v.get("t").and_then(|x| x.as_f64()))
                    {
                        out.tokens.push(t as i32);
                    }
                    match fault {
                        Fault::DisconnectAfter(k) if out.tokens.len() >= k => {
                            out.aborted = true;
                            out.total = t0.elapsed();
                            // Drop mid-stream: the server's next push sees a
                            // dead channel and retires the session.
                            return Ok(out);
                        }
                        Fault::StallAfter(k, pause) if out.tokens.len() == k => {
                            std::thread::sleep(pause);
                        }
                        _ => {}
                    }
                }
                "done" => out.done = Json::parse(&data).ok(),
                "error" => out.error = Json::parse(&data).ok(),
                _ => {}
            }
        }
        out.total = t0.elapsed();
        Ok(out)
    }

    fn read_response_head(&mut self) -> io::Result<(u16, Vec<(String, String)>, bool)> {
        let mut scanned = 0usize;
        loop {
            if let Some(end) = find_crlfcrlf(&self.carry, scanned) {
                let head: Vec<u8> = self.carry.drain(..end + 4).take(end).collect();
                return parse_response_head(&head);
            }
            scanned = self.carry.len().saturating_sub(3);
            let mut buf = [0u8; 2048];
            let n = self.stream.read(&mut buf)?;
            if n == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside response head",
                ));
            }
            self.carry.extend_from_slice(&buf[..n]);
        }
    }

    /// Read one transfer-encoding chunk. `None` = terminating zero chunk.
    fn read_chunk(&mut self) -> io::Result<Option<String>> {
        let size_line = self.read_line()?;
        let size = usize::from_str_radix(size_line.trim(), 16).map_err(|_| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad chunk size {size_line:?}"),
            )
        })?;
        if size == 0 {
            let _ = self.read_line(); // the blank line after the 0 chunk
            return Ok(None);
        }
        let payload = read_exact_body(&mut self.stream, &mut self.carry, size)?;
        let _ = read_exact_body(&mut self.stream, &mut self.carry, 2)?; // CRLF
        String::from_utf8(payload)
            .map(Some)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
    }

    fn read_line(&mut self) -> io::Result<String> {
        loop {
            if let Some(i) = self.carry.iter().position(|&b| b == b'\n') {
                let mut line: Vec<u8> = self.carry.drain(..=i).collect();
                while line.last().map_or(false, |&b| b == b'\n' || b == b'\r') {
                    line.pop();
                }
                return String::from_utf8(line)
                    .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()));
            }
            let mut buf = [0u8; 1024];
            let n = self.stream.read(&mut buf)?;
            if n == 0 {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof inside chunk"));
            }
            self.carry.extend_from_slice(&buf[..n]);
        }
    }
}

fn parse_response_head(bytes: &[u8]) -> io::Result<(u16, Vec<(String, String)>, bool)> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "head not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad status line {status_line:?}"),
            )
        })?;
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let keep_alive = headers
        .iter()
        .find(|(k, _)| k == "connection")
        .map_or(true, |(_, v)| !v.eq_ignore_ascii_case("close"));
    Ok((status, headers, keep_alive))
}

/// Parse one `event:`/`data:` SSE record.
fn parse_sse_record(payload: &str) -> Option<(String, String)> {
    let mut event = None;
    let mut data = None;
    for line in payload.lines() {
        if let Some(v) = line.strip_prefix("event: ") {
            event = Some(v.to_string());
        } else if let Some(v) = line.strip_prefix("data: ") {
            data = Some(v.to_string());
        }
    }
    Some((event?, data?))
}

/// Loadgen shape: N keep-alive clients, each issuing a request loop with
/// deterministic fault injection.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    pub clients: usize,
    pub requests_per_client: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    /// Prompt token ids are drawn below this.
    pub vocab: usize,
    /// Per-request deadline sent as `timeout_ms` (0 = none).
    pub timeout_ms: u64,
    pub chaos: ChaosConfig,
    /// Fire every client's first request with no stagger (the overload
    /// burst that provokes 429s).
    pub burst: bool,
    /// How many times a 429 is retried (honouring a capped Retry-After).
    pub max_retries: usize,
    pub seed: u64,
    /// Socket timeout for client I/O.
    pub io_timeout_ms: u64,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig {
            clients: 4,
            requests_per_client: 4,
            prompt_len: 8,
            max_new: 8,
            vocab: 64,
            timeout_ms: 30_000,
            chaos: ChaosConfig::off(),
            burst: false,
            max_retries: 8,
            seed: 0,
            io_timeout_ms: 10_000,
        }
    }
}

/// Aggregated loadgen outcome (merged across clients).
#[derive(Debug, Default, Clone)]
pub struct LoadReport {
    /// Requests attempted (retries not counted).
    pub requests: usize,
    /// Streams that ended with a `done` event.
    pub ok: usize,
    /// 429 responses observed (before retries succeeded or gave up).
    pub rejected_429: usize,
    /// 429 responses that carried a Retry-After header (must equal
    /// `rejected_429` — the backpressure gate).
    pub retry_after_present: usize,
    /// 503 responses (draining / overloaded front door).
    pub rejected_503: usize,
    /// Streams terminated by a server `error` event.
    pub stream_errors: usize,
    /// 400s earned by injected garbage (must equal `garbage_injected`).
    pub garbage_rejected: usize,
    /// Transport-level failures (connect/read/write).
    pub io_errors: usize,
    pub disconnects_injected: usize,
    pub stalls_injected: usize,
    pub garbage_injected: usize,
    /// Token events received.
    pub tokens: usize,
    /// Per-completed-stream time-to-first-token, milliseconds.
    pub ttfb_ms: Vec<f64>,
    /// Per-completed-stream decode pace, milliseconds per token.
    pub ms_per_token: Vec<f64>,
}

impl LoadReport {
    /// Fold another report in (cross-client, or cross-target totals).
    pub fn merge(&mut self, o: LoadReport) {
        self.requests += o.requests;
        self.ok += o.ok;
        self.rejected_429 += o.rejected_429;
        self.retry_after_present += o.retry_after_present;
        self.rejected_503 += o.rejected_503;
        self.stream_errors += o.stream_errors;
        self.garbage_rejected += o.garbage_rejected;
        self.io_errors += o.io_errors;
        self.disconnects_injected += o.disconnects_injected;
        self.stalls_injected += o.stalls_injected;
        self.garbage_injected += o.garbage_injected;
        self.tokens += o.tokens;
        self.ttfb_ms.extend(o.ttfb_ms);
        self.ms_per_token.extend(o.ms_per_token);
    }

    pub fn ttfb_percentile(&self, p: f64) -> f64 {
        percentile(&self.ttfb_ms, p)
    }

    pub fn ms_per_token_percentile(&self, p: f64) -> f64 {
        percentile(&self.ms_per_token, p)
    }
}

/// Nearest-rank percentile over an unsorted sample (0.0 for empty).
pub fn percentile(sample: &[f64], p: f64) -> f64 {
    if sample.is_empty() {
        return 0.0;
    }
    let mut s = sample.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((p / 100.0) * s.len() as f64).ceil() as usize;
    s[rank.clamp(1, s.len()) - 1]
}

/// Drive the serving front end with `cfg.clients` concurrent keep-alive
/// clients and merge their reports.
pub fn run_loadgen(addr: SocketAddr, cfg: &LoadGenConfig) -> LoadReport {
    let mut reports = run_loadgen_multi(&[addr], cfg);
    reports.pop().unwrap_or_default()
}

/// Multi-target loadgen: every client round-robins its requests across
/// `addrs` (offset by client id so targets load evenly), with one
/// keep-alive connection per target. Reports come back per target, in
/// `addrs` order, so the caller can assert per-target invariants — e.g.
/// that every 429 carried Retry-After on *each* front end independently.
pub fn run_loadgen_multi(addrs: &[SocketAddr], cfg: &LoadGenConfig) -> Vec<LoadReport> {
    if addrs.is_empty() {
        return Vec::new();
    }
    let mut handles = Vec::new();
    for c in 0..cfg.clients {
        let cfg = cfg.clone();
        let addrs = addrs.to_vec();
        handles.push(
            std::thread::Builder::new()
                .name(format!("hyena-loadgen-{c}"))
                .spawn(move || client_loop(&addrs, &cfg, c as u64))
                .expect("spawn loadgen client"),
        );
    }
    let mut totals: Vec<LoadReport> = addrs.iter().map(|_| LoadReport::default()).collect();
    for h in handles {
        if let Ok(rs) = h.join() {
            for (t, r) in totals.iter_mut().zip(rs) {
                t.merge(r);
            }
        }
    }
    totals
}

fn client_loop(addrs: &[SocketAddr], cfg: &LoadGenConfig, client_id: u64) -> Vec<LoadReport> {
    let mut reports: Vec<LoadReport> = addrs.iter().map(|_| LoadReport::default()).collect();
    let mut conns: Vec<Option<HttpClient>> = addrs.iter().map(|_| None).collect();
    let io_to = Duration::from_millis(cfg.io_timeout_ms.max(1));
    // Two independent streams: chaos decisions and prompt content, so
    // toggling chaos never changes the traffic shape.
    let mut chaos_rng = cfg.chaos.rng(client_id);
    let mut data_rng = crate::util::rng::Pcg::with_stream(cfg.seed ^ 0x10ad, client_id);
    if !cfg.burst {
        // Stagger start-up so steady-state runs interleave naturally.
        std::thread::sleep(Duration::from_millis(client_id * 3));
    }
    for i in 0..cfg.requests_per_client {
        // Round-robin target, offset by client id for even coverage.
        let ti = (client_id as usize + i) % addrs.len();
        let addr = addrs[ti];
        let report = &mut reports[ti];
        let conn = &mut conns[ti];
        report.requests += 1;
        let prompt: Vec<i32> =
            (0..cfg.prompt_len).map(|_| data_rng.usize_below(cfg.vocab.max(2)) as i32).collect();
        let body = generate_body(&prompt, cfg.max_new, cfg.timeout_ms);
        // Chaos draws are per-request, in a fixed order.
        let garbage = chaos_rng.f32() < cfg.chaos.garbage;
        let disconnect = chaos_rng.f32() < cfg.chaos.disconnect;
        let stall = chaos_rng.f32() < cfg.chaos.stall;
        let fault = if disconnect {
            report.disconnects_injected += 1;
            Fault::DisconnectAfter(1 + chaos_rng.usize_below(cfg.max_new.max(1)))
        } else if stall {
            report.stalls_injected += 1;
            Fault::StallAfter(1, Duration::from_millis(cfg.chaos.stall_ms))
        } else {
            Fault::None
        };
        if garbage {
            report.garbage_injected += 1;
            // Bytes that were never JSON, with an honest content-length.
            let junk = b"this was never json {{{";
            let mut c = match take_conn(conn, addr, io_to, report) {
                Some(c) => c,
                None => continue,
            };
            let sent = c
                .send("POST", "/generate", junk)
                .and_then(|_| c.read_response());
            match sent {
                Ok(resp) if resp.status == 400 => report.garbage_rejected += 1,
                Ok(_) => {}
                Err(_) => report.io_errors += 1,
            }
            // The server closes after a 400 (byte sync lost) — reconnect.
            *conn = None;
            continue;
        }
        let mut attempts = 0usize;
        loop {
            let mut c = match take_conn(conn, addr, io_to, report) {
                Some(c) => c,
                None => break,
            };
            match c.generate_stream(&body, fault) {
                Ok(out) => {
                    report.tokens += out.tokens.len();
                    match out.status {
                        200 if out.aborted => {
                            // We hung up on purpose; connection is dead.
                            *conn = None;
                        }
                        200 => {
                            if out.done.is_some() {
                                report.ok += 1;
                                if let Some(ttfb) = out.ttfb {
                                    report.ttfb_ms.push(ttfb.as_secs_f64() * 1e3);
                                    if out.tokens.len() > 1 {
                                        let decode =
                                            out.total.saturating_sub(ttfb).as_secs_f64() * 1e3;
                                        report
                                            .ms_per_token
                                            .push(decode / (out.tokens.len() - 1) as f64);
                                    }
                                }
                            } else if out.error.is_some() {
                                report.stream_errors += 1;
                            }
                            *conn = Some(c);
                        }
                        429 => {
                            report.rejected_429 += 1;
                            let retry_after = out
                                .reject
                                .as_ref()
                                .and_then(|r| r.header("retry-after"))
                                .map(|v| v.to_string());
                            if retry_after.is_some() {
                                report.retry_after_present += 1;
                            }
                            *conn = Some(c);
                            attempts += 1;
                            if attempts <= cfg.max_retries {
                                // Honour Retry-After, capped so tests stay fast.
                                let ms = retry_after
                                    .and_then(|v| v.parse::<u64>().ok())
                                    .map_or(50, |s| (s * 1000).min(100));
                                std::thread::sleep(Duration::from_millis(ms));
                                continue;
                            }
                        }
                        503 => {
                            report.rejected_503 += 1;
                            *conn = None; // server closes draining conns
                        }
                        _ => {
                            *conn = None;
                        }
                    }
                }
                Err(_) => {
                    report.io_errors += 1;
                    *conn = None;
                }
            }
            break;
        }
    }
    reports
}

fn take_conn(
    conn: &mut Option<HttpClient>,
    addr: SocketAddr,
    io_to: Duration,
    report: &mut LoadReport,
) -> Option<HttpClient> {
    match conn.take() {
        Some(c) => Some(c),
        None => match HttpClient::connect(addr, io_to) {
            Ok(c) => Some(c),
            Err(_) => {
                report.io_errors += 1;
                None
            }
        },
    }
}

/// GET `/metrics` from a front end over a one-shot connection (the
/// `loadgen --scrape` invariant check).
pub fn scrape_metrics(addr: SocketAddr, timeout: Duration) -> io::Result<String> {
    let mut c = HttpClient::connect(addr, timeout)?;
    let resp = c.get("/metrics")?;
    if resp.status != 200 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("GET /metrics -> {}", resp.status),
        ));
    }
    String::from_utf8(resp.body)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
}

/// Pull one *unlabeled* counter's value out of Prometheus exposition text.
/// Labeled series of the same family (`name{replica="0"} 5`) are skipped —
/// a fleet scrape's unlabeled line is the aggregate sum, which is what the
/// loadgen invariants compare against.
pub fn scrape_counter(text: &str, name: &str) -> Option<u64> {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ') {
                return v.trim().parse::<u64>().ok();
            }
        }
    }
    None
}

/// The canonical `/generate` request body.
pub fn generate_body(prompt: &[i32], max_new: usize, timeout_ms: u64) -> String {
    Json::obj(vec![
        (
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("max_new", Json::num(max_new as f64)),
        ("timeout_ms", Json::num(timeout_ms as f64)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sse_records_parse() {
        assert_eq!(
            parse_sse_record("event: token\ndata: {\"t\":5}\n\n"),
            Some(("token".into(), "{\"t\":5}".into()))
        );
        assert_eq!(parse_sse_record("data: {}\n"), None);
        assert_eq!(parse_sse_record(""), None);
    }

    #[test]
    fn response_heads_parse() {
        let (status, headers, keep) = parse_response_head(
            b"HTTP/1.1 429 Too Many Requests\r\nRetry-After: 1\r\nConnection: keep-alive",
        )
        .unwrap();
        assert_eq!(status, 429);
        assert_eq!(
            headers.iter().find(|(k, _)| k == "retry-after").map(|(_, v)| v.as_str()),
            Some("1")
        );
        assert!(keep);
        let (_, _, keep) =
            parse_response_head(b"HTTP/1.1 200 OK\r\nConnection: close").unwrap();
        assert!(!keep);
        assert!(parse_response_head(b"garbage").is_err());
    }

    #[test]
    fn percentile_nearest_rank() {
        let s = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&s, 50.0), 3.0);
        assert_eq!(percentile(&s, 99.0), 5.0);
        assert_eq!(percentile(&s, 1.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn scrape_counter_matches_aggregate_line_only() {
        let text = "# HELP hyena_tokens_generated_total Tokens\n\
                    # TYPE hyena_tokens_generated_total counter\n\
                    hyena_tokens_generated_total 42\n\
                    hyena_tokens_generated_total{replica=\"0\"} 40\n\
                    hyena_tokens_generated_totally_other 9\n";
        assert_eq!(scrape_counter(text, "hyena_tokens_generated_total"), Some(42));
        assert_eq!(scrape_counter(text, "hyena_admission_rejected_total"), None);
        // A labeled-only family yields no aggregate value.
        let labeled = "hyena_x_total{replica=\"1\"} 3\n";
        assert_eq!(scrape_counter(labeled, "hyena_x_total"), None);
    }

    #[test]
    fn generate_body_is_valid_json() {
        let b = generate_body(&[1, 2, 3], 4, 500);
        let v = Json::parse(&b).unwrap();
        assert_eq!(v.get("max_new").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("prompt").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("timeout_ms").unwrap().as_usize(), Some(500));
    }
}
