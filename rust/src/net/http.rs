//! Minimal HTTP/1.1 substrate for the serving front end (std::net only).
//!
//! Scope is exactly what the wire needs (DESIGN.md §Serving-Net): request
//! heads with bounded size and keep-alive pipelining, fixed-length JSON
//! responses, and chunked-transfer SSE streams (one chunk per event, so a
//! token can be flushed to the socket the moment `decode_step` produces
//! it). No TLS, no HTTP/2, no request chunked-encoding — those are ROADMAP
//! residue, not silent gaps: unsupported requests get explicit 4xx/5xx.
//!
//! Everything here is pure byte-shuffling over `Read`/`Write`, so the unit
//! tests run against in-memory buffers and the same code serves `TcpStream`
//! in `net::server` and the loadgen client in `net::client`.

use std::io::{self, Read, Write};

/// Hard cap on a request head (request line + headers). 8 KiB matches
/// common proxy defaults; a head that exceeds it is a 431-class error.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// One parsed request head. The body (if any) is read separately so the
/// JSON route can stream it through `net::jsonrd` incrementally.
#[derive(Debug, Clone)]
pub struct RequestHead {
    pub method: String,
    pub target: String,
    /// Lower-cased header names, values trimmed, in arrival order.
    pub headers: Vec<(String, String)>,
    /// `Connection: keep-alive` semantics after this request (HTTP/1.1
    /// default true, `Connection: close` false).
    pub keep_alive: bool,
    pub content_length: Option<usize>,
}

impl RequestHead {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request head could not be read.
#[derive(Debug)]
pub enum HeadError {
    /// Clean EOF before any byte of a new request — keep-alive close.
    Closed,
    /// Socket error mid-head (includes read timeouts).
    Io(io::Error),
    /// Head exceeded [`MAX_HEAD_BYTES`].
    TooLarge,
    /// Malformed request line / header.
    Bad(String),
}

impl std::fmt::Display for HeadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HeadError::Closed => write!(f, "connection closed"),
            HeadError::Io(e) => write!(f, "socket error: {e}"),
            HeadError::TooLarge => write!(f, "request head exceeds {MAX_HEAD_BYTES} bytes"),
            HeadError::Bad(m) => write!(f, "malformed request: {m}"),
        }
    }
}

/// Read one request head from `r`, consuming bytes from `carry` first
/// (keep-alive pipelining: bytes past the previous request's body wait
/// there). On success, `carry` holds any bytes read past the blank line —
/// the start of the body and/or the next pipelined request.
pub fn read_head(r: &mut impl Read, carry: &mut Vec<u8>) -> Result<RequestHead, HeadError> {
    let mut scanned = 0usize;
    loop {
        // Scan only fresh bytes for the head terminator.
        if let Some(end) = find_crlfcrlf(carry, scanned) {
            let head_bytes = carry[..end].to_vec();
            carry.drain(..end + 4);
            return parse_head(&head_bytes);
        }
        scanned = carry.len().saturating_sub(3);
        if carry.len() > MAX_HEAD_BYTES {
            return Err(HeadError::TooLarge);
        }
        let mut buf = [0u8; 2048];
        match r.read(&mut buf) {
            Ok(0) => {
                return if carry.iter().all(|b| b.is_ascii_whitespace()) {
                    Err(HeadError::Closed)
                } else {
                    Err(HeadError::Bad("eof inside request head".into()))
                };
            }
            Ok(n) => carry.extend_from_slice(&buf[..n]),
            Err(e) => return Err(HeadError::Io(e)),
        }
    }
}

pub(crate) fn find_crlfcrlf(buf: &[u8], from: usize) -> Option<usize> {
    if buf.len() < 4 {
        return None;
    }
    (from..=buf.len() - 4).find(|&i| &buf[i..i + 4] == b"\r\n\r\n")
}

fn parse_head(bytes: &[u8]) -> Result<RequestHead, HeadError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| HeadError::Bad("head is not valid UTF-8".into()))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if !m.is_empty() && !t.is_empty() => {
            (m.to_string(), t.to_string(), v)
        }
        _ => return Err(HeadError::Bad(format!("bad request line {request_line:?}"))),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HeadError::Bad(format!("unsupported version {version:?}")));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(HeadError::Bad(format!("bad header line {line:?}")));
        };
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let mut head = RequestHead {
        method,
        target,
        keep_alive: version == "HTTP/1.1",
        content_length: None,
        headers,
    };
    if let Some(c) = head.header("connection") {
        let c = c.to_ascii_lowercase();
        if c.contains("close") {
            head.keep_alive = false;
        } else if c.contains("keep-alive") {
            head.keep_alive = true;
        }
    }
    if head.header("transfer-encoding").is_some() {
        return Err(HeadError::Bad("chunked request bodies unsupported".into()));
    }
    if let Some(cl) = head.header("content-length") {
        let n: usize = cl
            .parse()
            .map_err(|_| HeadError::Bad(format!("bad content-length {cl:?}")))?;
        head.content_length = Some(n);
    }
    Ok(head)
}

/// Read exactly `n` body bytes: from `carry` first, then the socket.
pub fn read_exact_body(
    r: &mut impl Read,
    carry: &mut Vec<u8>,
    n: usize,
) -> io::Result<Vec<u8>> {
    let from_carry = n.min(carry.len());
    let mut body: Vec<u8> = carry.drain(..from_carry).collect();
    while body.len() < n {
        let mut buf = [0u8; 4096];
        let want = (n - body.len()).min(buf.len());
        let got = r.read(&mut buf[..want])?;
        if got == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "eof inside request body",
            ));
        }
        body.extend_from_slice(&buf[..got]);
    }
    Ok(body)
}

/// Canonical reason phrases for the statuses the wire emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a fixed-length response. `extra` headers come after the standard
/// set; bodies are JSON unless a `content-type` override is passed.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    extra: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", status, reason(status));
    if !extra.iter().any(|(k, _)| k.eq_ignore_ascii_case("content-type")) {
        head.push_str("Content-Type: application/json\r\n");
    }
    head.push_str(&format!("Content-Length: {}\r\n", body.len()));
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n"
    } else {
        "Connection: close\r\n"
    });
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Server-sent-events stream over chunked transfer-encoding: one chunk per
/// event, flushed immediately, so each decoded token reaches the client as
/// it is sampled. `finish` writes the terminating zero chunk, which is what
/// lets a keep-alive client reuse the connection after the stream.
pub struct SseWriter<W: Write> {
    w: W,
    events: u64,
    finished: bool,
}

impl<W: Write> SseWriter<W> {
    /// Write the response head and return the event writer.
    pub fn start(mut w: W, keep_alive: bool) -> io::Result<SseWriter<W>> {
        let head = format!(
            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
             Cache-Control: no-store\r\nTransfer-Encoding: chunked\r\n\
             Connection: {}\r\n\r\n",
            if keep_alive { "keep-alive" } else { "close" }
        );
        w.write_all(head.as_bytes())?;
        w.flush()?;
        Ok(SseWriter { w, events: 0, finished: false })
    }

    /// Emit one `event:`/`data:` record as a single chunk and flush.
    pub fn event(&mut self, name: &str, data: &str) -> io::Result<()> {
        debug_assert!(!self.finished, "event after finish");
        let payload = format!("event: {name}\ndata: {data}\n\n");
        let chunk = format!("{:x}\r\n{payload}\r\n", payload.len());
        self.w.write_all(chunk.as_bytes())?;
        self.w.flush()?;
        self.events += 1;
        Ok(())
    }

    /// Events written so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Terminate the chunked stream (zero-length chunk).
    pub fn finish(mut self) -> io::Result<()> {
        self.finished = true;
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_of(raw: &[u8]) -> Result<(RequestHead, Vec<u8>), HeadError> {
        let mut carry = Vec::new();
        let mut r = io::Cursor::new(raw.to_vec());
        let h = read_head(&mut r, &mut carry)?;
        // Drain whatever the cursor still holds into carry, as the server
        // loop would on the next read.
        let mut rest = Vec::new();
        r.read_to_end(&mut rest).unwrap();
        carry.extend_from_slice(&rest);
        Ok((h, carry))
    }

    #[test]
    fn parses_post_with_body_and_pipelined_next_request() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n\
                    {\"a\":1}GET /healthz HTTP/1.1\r\n\r\n";
        let (h, mut carry) = head_of(raw).unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.target, "/generate");
        assert!(h.keep_alive);
        assert_eq!(h.content_length, Some(7));
        let mut empty = io::Cursor::new(Vec::new());
        let body = read_exact_body(&mut empty, &mut carry, 7).unwrap();
        assert_eq!(&body, b"{\"a\":1}");
        // The pipelined GET stays in carry for the next read_head call.
        let mut r2 = io::Cursor::new(Vec::new());
        let h2 = read_head(&mut r2, &mut carry).unwrap();
        assert_eq!(h2.method, "GET");
        assert_eq!(h2.target, "/healthz");
    }

    #[test]
    fn connection_close_and_http10_disable_keep_alive() {
        let (h, _) = head_of(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!h.keep_alive);
        let (h, _) = head_of(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!h.keep_alive);
    }

    #[test]
    fn split_head_across_reads_reassembles() {
        struct TwoPart(Vec<Vec<u8>>);
        impl Read for TwoPart {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                if self.0.is_empty() {
                    return Ok(0);
                }
                let part = self.0.remove(0);
                buf[..part.len()].copy_from_slice(&part);
                Ok(part.len())
            }
        }
        let raw = b"GET /mem HTTP/1.1\r\nHost: y\r\n\r\n";
        for cut in 1..raw.len() - 1 {
            let mut r = TwoPart(vec![raw[..cut].to_vec(), raw[cut..].to_vec()]);
            let mut carry = Vec::new();
            let h = read_head(&mut r, &mut carry).unwrap();
            assert_eq!(h.target, "/mem", "split at {cut}");
            assert!(carry.is_empty());
        }
    }

    #[test]
    fn rejects_malformed_heads() {
        assert!(matches!(head_of(b"\r\n\r\n"), Err(HeadError::Bad(_))));
        assert!(matches!(head_of(b"GET /\r\n\r\n"), Err(HeadError::Bad(_))));
        assert!(matches!(
            head_of(b"GET / HTTP/2.0\r\n\r\n"),
            Err(HeadError::Bad(_))
        ));
        assert!(matches!(
            head_of(b"POST / HTTP/1.1\r\nContent-Length: x\r\n\r\n"),
            Err(HeadError::Bad(_))
        ));
        assert!(matches!(
            head_of(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HeadError::Bad(_))
        ));
    }

    #[test]
    fn clean_eof_is_closed_not_bad() {
        assert!(matches!(head_of(b""), Err(HeadError::Closed)));
        // EOF mid-head is a protocol error, not a clean close.
        assert!(matches!(head_of(b"GET / HT"), Err(HeadError::Bad(_))));
    }

    #[test]
    fn oversized_head_is_rejected() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend_from_slice(format!("X-Pad: {}\r\n", "y".repeat(MAX_HEAD_BYTES)).as_bytes());
        raw.extend_from_slice(b"\r\n");
        assert!(matches!(head_of(&raw), Err(HeadError::TooLarge)));
    }

    #[test]
    fn response_writer_emits_content_length_and_connection() {
        let mut out = Vec::new();
        write_response(&mut out, 429, &[("Retry-After", "1")], b"{\"e\":1}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Content-Length: 7\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("{\"e\":1}"));
    }

    #[test]
    fn sse_writer_chunks_each_event_and_terminates() {
        let mut out = Vec::new();
        {
            let mut sse = SseWriter::start(&mut out, true).unwrap();
            sse.event("token", "{\"t\":5}").unwrap();
            sse.event("done", "{}").unwrap();
            assert_eq!(sse.events(), 2);
            sse.finish().unwrap();
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap_or("");
        // First chunk: hex length, then the SSE record.
        let payload = "event: token\ndata: {\"t\":5}\n\n";
        assert!(
            body.starts_with(&format!("{:x}\r\n{payload}\r\n", payload.len())),
            "chunk framing wrong: {body:?}"
        );
        assert!(text.ends_with("0\r\n\r\n"), "missing terminating chunk");
    }
}
