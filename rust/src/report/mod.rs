//! Table/series emission for the experiment drivers: markdown tables on
//! stdout plus CSV files under `results/` for EXPERIMENTS.md, and the
//! machine-readable bench ledger (`BENCH_native.json`) that tracks the perf
//! trajectory across PRs.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use crate::util::json::Json;

/// Merge `value` under `key` into the JSON object at `path` (created if
/// absent, other keys preserved) — how each bench contributes its section
/// of `BENCH_native.json` without clobbering the others.
pub fn merge_bench_json(path: &Path, key: &str, value: Json) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path).ok();
    let mut root = match existing.as_deref().map(Json::parse) {
        Some(Ok(j @ Json::Obj(_))) => j,
        None => Json::Obj(BTreeMap::new()),
        Some(_) => {
            // Don't silently eat ledger history: a corrupt/non-object file
            // is loud, and starting fresh is the only recovery.
            eprintln!(
                "warning: {} is not a JSON object; starting a fresh ledger",
                path.display()
            );
            Json::Obj(BTreeMap::new())
        }
    };
    if let Json::Obj(m) = &mut root {
        m.insert(key.to_string(), value);
    }
    std::fs::write(path, format!("{root}\n"))
}

/// A simple column-aligned table (markdown-compatible).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {:<w$} |", c, w = w);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths[..ncol] {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print markdown to stdout and persist CSV under `results/<name>.csv`.
    pub fn emit(&self, name: &str) {
        println!("\n{}", self.to_markdown());
        let dir = Path::new("results");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.csv")), self.to_csv());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("T", &["model", "acc"]);
        t.row(vec!["hyena".into(), "100.0".into()]);
        t.row(vec!["h3".into(), "5.3".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| model | acc   |"));
        assert!(md.contains("| h3    | 5.3   |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bench_json_merges_keys_without_clobbering() {
        // Process-unique name: concurrent test runs must not share the file.
        let path = std::env::temp_dir()
            .join(format!("hyena_bench_merge_test_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        merge_bench_json(&path, "fftconv", Json::obj(vec![("l", Json::num(1024.0))])).unwrap();
        merge_bench_json(&path, "train_step", Json::obj(vec![("t", Json::num(2.0))])).unwrap();
        // Re-writing one key leaves the other intact.
        merge_bench_json(&path, "fftconv", Json::obj(vec![("l", Json::num(8192.0))])).unwrap();
        let j = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        assert_eq!(j.get("fftconv").unwrap().get("l").unwrap().as_usize().unwrap(), 8192);
        assert_eq!(j.get("train_step").unwrap().get("t").unwrap().as_usize().unwrap(), 2);
        let _ = std::fs::remove_file(&path);
    }
}
