//! Table/series emission for the experiment drivers: markdown tables on
//! stdout plus CSV files under `results/` for EXPERIMENTS.md.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table (markdown-compatible).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {:<w$} |", c, w = w);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths[..ncol] {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print markdown to stdout and persist CSV under `results/<name>.csv`.
    pub fn emit(&self, name: &str) {
        println!("\n{}", self.to_markdown());
        let dir = Path::new("results");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.csv")), self.to_csv());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("T", &["model", "acc"]);
        t.row(vec!["hyena".into(), "100.0".into()]);
        t.row(vec!["h3".into(), "5.3".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| model | acc   |"));
        assert!(md.contains("| h3    | 5.3   |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
