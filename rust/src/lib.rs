//! # hyena — Hyena Hierarchy reproduction
//!
//! Rust L3 coordinator for the three-layer (Rust + JAX + Pallas) stack
//! reproducing *Hyena Hierarchy: Towards Larger Convolutional Language
//! Models* (Poli et al., ICML 2023). See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layering:
//! * [`backend`] — the [`backend::Backend`] trait and its two engines: the
//!   PJRT artifact runtime and the pure-Rust native Hyena evaluator
//!   (FFT long conv + gating; DESIGN.md §2).
//! * [`runtime`] — PJRT client; loads HLO-text artifacts AOT-compiled by
//!   `python/compile/aot.py` (JAX L2 models calling Pallas L1 kernels).
//! * [`coordinator`] — training loop, dynamic-batching inference server,
//!   decoding, few-shot harness; backend-agnostic via [`backend::Backend`].
//! * [`tasks`], [`data`], [`tokenizer`] — the synthetic substrates standing
//!   in for the paper's datasets (substitution table: DESIGN.md §3).
//! * [`net`] — dependency-free HTTP/1.1 + SSE serving front end over the
//!   coordinator server: deadlines, backpressure, chaos injection, graceful
//!   drain (DESIGN.md §Serving-Net).
//! * [`obs`] — telemetry: lock-light metrics registry with Prometheus
//!   exposition (`GET /metrics`), per-request trace spans (`GET /trace`),
//!   and `HYENA_PROF` hot-path profiling hooks (DESIGN.md §Observability).
//! * [`metrics`], [`report`], [`util`] — FLOP accounting (App. A.2), table
//!   emission, JSON/RNG/CLI/property-test substrates.
pub mod backend;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod tasks;
pub mod tokenizer;
pub mod util;

use std::path::PathBuf;

/// Resolve the artifacts directory: `$HYENA_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("HYENA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Path of one named artifact.
pub fn artifact(name: &str) -> PathBuf {
    artifacts_dir().join(name)
}
