//! Hot-path profiling hooks (DESIGN.md §Observability).
//!
//! Gated by `HYENA_PROF=1`, resolved once and cached in an `AtomicBool`
//! so the disabled check is a single relaxed load — the contract gated by
//! `benches/native_obs.rs` is ≤ 3% decode-throughput overhead enabled and
//! ≈ 0 disabled. Three hook families:
//!
//! * per-kernel call counts + wall time: the `Kernels` dispatcher swaps in
//!   a timing wrapper table ([`crate::backend::native::kernels`]) when
//!   profiling is on, so the off path pays nothing at all;
//! * FFT plan runs ([`FFT`]): one timer around each forward/inverse pass;
//! * batched decode rounds ([`DECODE_BATCH`]): one timer around each
//!   `decode_step_batch` call.
//!
//! Slots are plain atomics folded into every metrics [`Snapshot`](super::Snapshot)
//! (`hyena_prof_*` series), so `GET /metrics` carries them and the fleet
//! merge aggregates them like any other counter.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use super::Value;

static INIT: AtomicBool = AtomicBool::new(false);
static ON: AtomicBool = AtomicBool::new(false);

/// Is profiling on? First call resolves `HYENA_PROF`; later calls are one
/// relaxed load (two during the benign init race, which is idempotent).
pub fn enabled() -> bool {
    if !INIT.load(Ordering::Relaxed) {
        let on = std::env::var("HYENA_PROF").map(|v| v == "1").unwrap_or(false);
        ON.store(on, Ordering::Relaxed);
        INIT.store(true, Ordering::Relaxed);
    }
    ON.load(Ordering::Relaxed)
}

/// Override the env gate (benches toggle the instrumented path in-process;
/// note the kernel wrapper table is chosen once at first dispatch, so only
/// the FFT/decode hooks react to a mid-process toggle).
pub fn set_enabled(on: bool) {
    ON.store(on, Ordering::Relaxed);
    INIT.store(true, Ordering::Relaxed);
}

/// One profiled site: call count + accumulated wall nanoseconds.
pub struct Slot {
    pub calls: AtomicU64,
    pub ns: AtomicU64,
}

impl Slot {
    pub const fn new() -> Slot {
        Slot { calls: AtomicU64::new(0), ns: AtomicU64::new(0) }
    }

    pub fn record(&self, ns: u64) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.ns.store(0, Ordering::Relaxed);
    }
}

/// Kernel-op slot indices (order matches [`KERNEL_OPS`]).
pub const K_AXPY: usize = 0;
pub const K_DOT: usize = 1;
pub const K_GATE_MUL: usize = 2;
pub const K_GELU_FWD: usize = 3;
pub const K_BUTTERFLY: usize = 4;
pub const K_SPEC_MUL: usize = 5;
pub const K_SPEC_MUL_CONJ: usize = 6;

/// `op` label values for the per-kernel series.
pub const KERNEL_OPS: [&str; 7] =
    ["axpy", "dot", "gate_mul", "gelu_fwd", "butterfly_pass", "spec_mul", "spec_mul_conj"];

const SLOT_INIT: Slot = Slot::new();

/// Per-kernel-op slots, filled by the profiled dispatch table.
pub static KERNELS: [Slot; 7] = [SLOT_INIT; 7];
/// FFT plan runs (one forward or inverse pass each).
pub static FFT: Slot = Slot::new();
/// Batched decode rounds (`decode_step_batch` calls).
pub static DECODE_BATCH: Slot = Slot::new();

/// Zero every slot (bench phases).
pub fn reset() {
    for s in &KERNELS {
        s.reset();
    }
    FFT.reset();
    DECODE_BATCH.reset();
}

fn push_slot(series: &mut Vec<super::Series>, base: &str, labels: Vec<(String, String)>, s: &Slot) {
    let mk = |name: String, help: &str, v: u64, labels: Vec<(String, String)>| super::Series {
        name,
        help: help.to_string(),
        labels,
        value: Value::Counter(v),
    };
    series.push(mk(
        format!("{base}_calls_total"),
        "Profiled call count (HYENA_PROF)",
        s.calls.load(Ordering::Relaxed),
        labels.clone(),
    ));
    series.push(mk(
        format!("{base}_ns_total"),
        "Profiled wall nanoseconds (HYENA_PROF)",
        s.ns.load(Ordering::Relaxed),
        labels,
    ));
}

/// Append the `hyena_prof_*` series to a snapshot under construction.
pub fn fold_into(series: &mut Vec<super::Series>) {
    for (i, op) in KERNEL_OPS.iter().enumerate() {
        push_slot(
            series,
            "hyena_prof_kernel",
            vec![("op".to_string(), op.to_string())],
            &KERNELS[i],
        );
    }
    push_slot(series, "hyena_prof_fft_run", Vec::new(), &FFT);
    push_slot(series, "hyena_prof_decode_round", Vec::new(), &DECODE_BATCH);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_accumulate_and_reset() {
        // Private slots so parallel tests cannot interfere.
        let s = Slot::new();
        s.record(100);
        s.record(50);
        assert_eq!(s.calls.load(Ordering::Relaxed), 2);
        assert_eq!(s.ns.load(Ordering::Relaxed), 150);
        s.reset();
        assert_eq!(s.calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn set_enabled_overrides_env() {
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
    }

    #[test]
    fn fold_emits_every_slot_series() {
        let mut series = Vec::new();
        fold_into(&mut series);
        // 7 kernel ops x 2 + fft x 2 + decode x 2.
        assert_eq!(series.len(), KERNEL_OPS.len() * 2 + 4);
        assert!(series.iter().any(|s| {
            s.name == "hyena_prof_kernel_calls_total"
                && s.labels == vec![("op".to_string(), "dot".to_string())]
        }));
        assert!(series.iter().any(|s| s.name == "hyena_prof_fft_run_ns_total"));
        assert!(series.iter().any(|s| s.name == "hyena_prof_decode_round_calls_total"));
    }
}
