//! Per-request trace spans (DESIGN.md §Observability).
//!
//! A `trace_id` is minted when the front end parses a `/generate` request
//! and rides the [`GenerateRequest`](crate::coordinator::server::GenerateRequest)
//! through admission, the coordinator loop, and (in fleet mode) the replica
//! RPC, so every layer appends spans to the same trace without new channel
//! plumbing: the hub is process-global, keyed by id. Spans are coarse —
//! one per request stage or decode round, never per token — so the hub
//! mutex stays off the per-token hot path. Finished traces land in a
//! bounded ring ([`RING_CAP`]) served as JSON at `GET /trace?n=K`; the id
//! is also stamped into access logs, SSE `error` events, and router
//! dispatch logs ([`id_hex`]) so one request can be followed across
//! processes. Traces are per-process: the router's ring holds front-end
//! spans (admission, dispatch, stream), each replica's ring holds the
//! engine spans (queue, prefill, decode rounds) under the same id.
//!
//! Id 0 means "untraced" (benches, direct engine drivers): every hub call
//! is a no-op for it.

use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};

use super::clock;
use crate::util::json::Json;

/// Finished traces kept for `GET /trace`.
pub const RING_CAP: usize = 256;
/// Spans kept per trace; the overflow is counted, not stored.
pub const SPAN_CAP: usize = 512;
/// Traces that began but never finished are evicted beyond this.
pub const INFLIGHT_CAP: usize = 1024;

/// One timed stage. `n` is a stage-specific count (chunk index, tokens in
/// the round, bytes written, …).
#[derive(Debug, Clone)]
pub struct Span {
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
    pub n: u64,
}

/// One request's spans, from mint to finish.
#[derive(Debug, Clone)]
pub struct Trace {
    pub id: u64,
    /// Wall-clock birth stamp (for cross-process correlation by eye).
    pub started_ms: u128,
    pub spans: Vec<Span>,
    /// Spans dropped past [`SPAN_CAP`].
    pub dropped: u64,
    /// Terminal status: `"done"`, `"error"`, `"rejected"`, … (empty while
    /// in flight).
    pub status: &'static str,
    pub end_us: u64,
}

struct Hub {
    inflight: Mutex<Vec<Trace>>,
    finished: Mutex<VecDeque<Trace>>,
}

fn hub() -> &'static Hub {
    static H: OnceLock<Hub> = OnceLock::new();
    H.get_or_init(|| Hub {
        inflight: Mutex::new(Vec::new()),
        finished: Mutex::new(VecDeque::new()),
    })
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Mint a fresh nonzero trace id: a per-process wall-clock salt (so two
/// processes started at different times do not collide) mixed with a
/// sequence counter through splitmix64.
pub fn mint() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(1);
    static SALT: OnceLock<u64> = OnceLock::new();
    let salt = *SALT.get_or_init(|| clock::epoch_ms() as u64);
    let id = splitmix64(salt ^ SEQ.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x2545_f491_4f6c_dd1d));
    if id == 0 {
        1
    } else {
        id
    }
}

/// Short printable form (12 hex chars) used in logs and JSON.
pub fn id_hex(id: u64) -> String {
    format!("{:012x}", id & 0xffff_ffff_ffff)
}

/// Open a trace for `id`. No-op for id 0 or an id already in flight.
pub fn begin(id: u64) {
    if id == 0 {
        return;
    }
    let mut inflight = hub().inflight.lock().unwrap();
    if inflight.iter().any(|t| t.id == id) {
        return;
    }
    if inflight.len() >= INFLIGHT_CAP {
        inflight.remove(0); // oldest leaked trace gives way
    }
    inflight.push(Trace {
        id,
        started_ms: clock::epoch_ms(),
        spans: Vec::new(),
        dropped: 0,
        status: "",
        end_us: 0,
    });
}

/// Append a span to an in-flight trace (no-op for id 0 / unknown ids).
pub fn span(id: u64, name: &'static str, start_us: u64, dur_us: u64, n: u64) {
    if id == 0 {
        return;
    }
    let mut inflight = hub().inflight.lock().unwrap();
    if let Some(t) = inflight.iter_mut().find(|t| t.id == id) {
        if t.spans.len() >= SPAN_CAP {
            t.dropped += 1;
        } else {
            t.spans.push(Span { name, start_us, dur_us, n });
        }
    }
}

/// Convenience: record a span that ends now.
pub fn span_since(id: u64, name: &'static str, start_us: u64, n: u64) {
    span(id, name, start_us, clock::now_us().saturating_sub(start_us), n);
}

thread_local! {
    static CURRENT: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

/// Set the calling thread's ambient trace id. The coordinator sets this
/// around engine calls so layers below the [`Backend`] trait seam (e.g.
/// the chunked-prefill loop) can attach spans without the trait carrying
/// an id parameter. 0 clears it.
///
/// [`Backend`]: crate::backend::Backend
pub fn set_current(id: u64) {
    CURRENT.with(|c| c.set(id));
}

/// The calling thread's ambient trace id (0 = none).
pub fn current() -> u64 {
    CURRENT.with(|c| c.get())
}

/// Record a span on the ambient trace (no-op when none is set).
pub fn span_current(name: &'static str, start_us: u64, dur_us: u64, n: u64) {
    span(current(), name, start_us, dur_us, n);
}

/// Close a trace and move it into the finished ring (no-op for id 0 /
/// unknown ids).
pub fn finish(id: u64, status: &'static str) {
    if id == 0 {
        return;
    }
    let trace = {
        let mut inflight = hub().inflight.lock().unwrap();
        let i = match inflight.iter().position(|t| t.id == id) {
            Some(i) => i,
            None => return,
        };
        let mut t = inflight.remove(i);
        t.status = status;
        t.end_us = clock::now_us();
        t
    };
    let mut finished = hub().finished.lock().unwrap();
    finished.push_back(trace);
    while finished.len() > RING_CAP {
        finished.pop_front();
    }
}

/// The most recent `n` finished traces, newest first.
pub fn recent(n: usize) -> Vec<Trace> {
    let finished = hub().finished.lock().unwrap();
    finished.iter().rev().take(n).cloned().collect()
}

fn trace_json(t: &Trace) -> Json {
    Json::obj(vec![
        ("trace_id", Json::str(&id_hex(t.id))),
        ("started_ms", Json::num(t.started_ms as f64)),
        ("status", Json::str(t.status)),
        ("end_us", Json::num(t.end_us as f64)),
        ("dropped", Json::num(t.dropped as f64)),
        (
            "spans",
            Json::Arr(
                t.spans
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::str(s.name)),
                            ("t_us", Json::num(s.start_us as f64)),
                            ("dur_us", Json::num(s.dur_us as f64)),
                            ("n", Json::num(s.n as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The `GET /trace?n=K` payload: newest-first finished traces as JSON.
pub fn dump(n: usize) -> Json {
    let traces = recent(n);
    Json::obj(vec![
        ("count", Json::num(traces.len() as f64)),
        ("traces", Json::Arr(traces.iter().map(trace_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_nonzero_and_distinct() {
        let a = mint();
        let b = mint();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
        assert_eq!(id_hex(a).len(), 12);
    }

    #[test]
    fn spans_accumulate_and_finish_moves_to_ring() {
        let id = mint();
        begin(id);
        span(id, "admission", 10, 5, 0);
        span(id, "decode_round", 20, 3, 4);
        finish(id, "done");
        let t = recent(RING_CAP)
            .into_iter()
            .find(|t| t.id == id)
            .expect("finished trace in ring");
        assert_eq!(t.status, "done");
        assert_eq!(t.spans.len(), 2);
        assert_eq!(t.spans[0].name, "admission");
        assert_eq!(t.spans[1].n, 4);
        // Finishing removed it from inflight: spans after finish are lost.
        span(id, "late", 0, 0, 0);
        let t2 = recent(RING_CAP).into_iter().find(|t| t.id == id).unwrap();
        assert_eq!(t2.spans.len(), 2);
    }

    #[test]
    fn ambient_current_id_routes_spans() {
        let id = mint();
        begin(id);
        assert_eq!(current(), 0);
        set_current(id);
        span_current("prefill_chunk", 5, 7, 2);
        set_current(0);
        span_current("ignored", 0, 0, 0); // ambient cleared: no-op
        finish(id, "done");
        let t = recent(RING_CAP).into_iter().find(|t| t.id == id).unwrap();
        assert_eq!(t.spans.len(), 1);
        assert_eq!((t.spans[0].name, t.spans[0].n), ("prefill_chunk", 2));
    }

    #[test]
    fn id_zero_is_untraced() {
        begin(0);
        span(0, "x", 0, 0, 0);
        finish(0, "done");
        assert!(recent(RING_CAP).iter().all(|t| t.id != 0));
    }

    #[test]
    fn span_cap_counts_drops() {
        let id = mint();
        begin(id);
        for i in 0..(SPAN_CAP as u64 + 10) {
            span(id, "round", i, 1, 0);
        }
        finish(id, "done");
        let t = recent(RING_CAP).into_iter().find(|t| t.id == id).unwrap();
        assert_eq!(t.spans.len(), SPAN_CAP);
        assert_eq!(t.dropped, 10);
    }

    #[test]
    fn dump_is_valid_json_newest_first() {
        let a = mint();
        begin(a);
        span(a, "prefill", 1, 2, 0);
        finish(a, "done");
        let b = mint();
        begin(b);
        finish(b, "error");
        let d = dump(RING_CAP);
        let reparsed = Json::parse(&d.to_string()).expect("dump is valid json");
        let traces = reparsed.get("traces").unwrap().as_arr().unwrap();
        // Other tests share the ring: find ours by id, check relative order.
        let pos = |id: u64| {
            let hex = id_hex(id);
            traces
                .iter()
                .position(|t| t.get("trace_id").and_then(|v| v.as_str()) == Some(hex.as_str()))
                .expect("trace present")
        };
        let (pa, pb) = (pos(a), pos(b));
        assert!(pb < pa, "newest first: b finished after a");
        assert_eq!(traces[pb].get("status").unwrap().as_str().unwrap(), "error");
        let spans = traces[pa].get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans[0].get("name").unwrap().as_str().unwrap(), "prefill");
    }
}
